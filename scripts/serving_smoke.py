"""Serving-loop smoke: chunked prefill under offered load + greedy
speculative decode, asserting the throughput-grade invariants on CPU
(CI job ``serving-smoke``).

Three scenarios against the stateful (prefill, decode) Program pair:

  1. **Offered-load chunked prefill** — steady short-prompt traffic,
     then a 4x-max_len prompt lands mid-stream with ``chunk_size=16``.
     Asserts the token streams are identical to the whole-prefill
     oracle, the chunk scheduler actually ran (prefill_chunks > 0),
     nothing was ever prefilled twice, and — the point of chunking —
     no live slot missed a decode tick (``starved_ticks == 0``).
  2. **Speculative decode** — the same traffic with a self-draft
     ``spec_k=3`` pair.  Asserts the greedy streams are *exactly* the
     non-speculative streams (accept/rollback never changes a token)
     and that verification accepted draft tokens (accepted > 0).
  3. **Observability** — the same traffic with a flight recorder
     attached.  Asserts observation is not intervention (streams
     identical to the bare run), the flight replay reconstructs every
     request's token stream exactly, the TTFT histogram is populated,
     and reports the obs-on vs obs-off wallclock overhead (the Stage-8
     contract says <= 3%; printed, not hard-asserted — shared-runner
     wallclock is too noisy for a CI gate).

Run: PYTHONPATH=src python scripts/serving_smoke.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def _traffic(cfg, rng):
    """Deterministic request mix: short prompts, one long straggler."""
    lens = [3, 6, 2, 9, 4, 7]
    return [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
            for n in lens]


def _serve(cfg, params, prompts, long_prompt, **kw):
    from repro.serving import Request, ServingEngine
    eng = ServingEngine(cfg, params, slots=4, max_len=32,
                        use_program=True, impl="reference", **kw)
    assert eng.on_program_path, eng.fallback_reason
    for i, p in enumerate(prompts[:4]):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
    done = []
    for _ in range(2):                 # two steady ticks, then the
        done += eng.step()             # long prompt lands mid-stream
    if long_prompt is not None:
        eng.submit(Request(uid=90, prompt=long_prompt, max_new_tokens=8))
    for i, p in enumerate(prompts[4:], start=4):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
    done += eng.run_until_drained()
    return {r.uid: tuple(r.out_tokens) for r in done}, eng


def main() -> None:
    from repro.configs import REGISTRY
    from repro.models import init_params, transformer

    cfg = REGISTRY["smollm-360m"].smoke()
    params = init_params(transformer.param_defs(cfg),
                         jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = _traffic(cfg, rng)
    long_prompt = rng.integers(0, cfg.vocab,
                               size=4 * 32).astype(np.int32)

    base, _ = _serve(cfg, params, prompts, long_prompt)

    # -- 1. chunked prefill under offered load -------------------------------
    got, eng = _serve(cfg, params, prompts, long_prompt, chunk_size=16)
    assert got == base, "chunked streams diverged from whole-prefill"
    assert eng.n_prefill_chunks > 0
    assert eng.n_prefill_recomputes == 0
    assert eng.n_starved_ticks == 0
    print(f"chunked offered-load: streams identical; "
          f"prefill_chunks={eng.n_prefill_chunks} "
          f"starved_ticks={eng.n_starved_ticks}")

    # -- 2. speculative decode: exact parity + real acceptance ---------------
    sgot, seng = _serve(cfg, params, prompts, None, spec_k=3)
    sbase, _ = _serve(cfg, params, prompts, None)
    assert sgot == sbase, "speculative streams diverged from greedy"
    assert seng.n_spec_accepted > 0
    print(f"spec decode: streams identical; "
          f"spec_proposed={seng.n_spec_proposed} "
          f"spec_accepted={seng.n_spec_accepted} "
          f"spec_rollbacks={seng.n_spec_rollbacks}")

    # -- 3. observability: replay parity + overhead --------------------------
    import time

    from repro.obs import Observability, replay_summary

    def timed(**kw):
        t0 = time.perf_counter()
        out = _serve(cfg, params, prompts, long_prompt,
                     chunk_size=16, **kw)
        return time.perf_counter() - t0, out

    obs = Observability(flight_path="/tmp/serving_smoke_flight.jsonl")
    t_obs, (ogot, oeng) = timed(obs=obs)
    obs.close()
    assert ogot == base, "obs-enabled streams diverged from bare run"
    summ = replay_summary(obs.flight.events)
    for uid, toks in ogot.items():
        assert tuple(summ["requests"][uid]["tokens"]) == toks, \
            f"flight replay diverged for uid {uid}"
    snap = obs.registry.snapshot()
    assert snap["histograms"]["ttft_ms"]["count"] == len(ogot)
    assert snap["counters"]["serving_starved_ticks_total"] == 0
    # Overhead: best-of-2 per variant (single tiny runs on a shared
    # host are dominated by scheduler noise).
    t_obs = min(t_obs, timed(obs=Observability(
        flight_path="/tmp/serving_smoke_flight2.jsonl"))[0])
    t_bare = min(timed()[0], timed()[0])
    overhead = (t_obs - t_bare) / t_bare * 100
    print(f"observability: replay matches engine streams exactly; "
          f"ttft_count={snap['histograms']['ttft_ms']['count']} "
          f"tick_count={snap['histograms']['tick_ms']['count']} "
          f"overhead={overhead:+.1f}% (contract: <= 3%)")

    print("serving smoke: all invariants hold")


if __name__ == "__main__":
    main()

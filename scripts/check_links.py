#!/usr/bin/env python3
"""Fail on broken intra-repo markdown links (CI docs job).

Scans README.md and docs/*.md for ``[text](target)`` links, skips
external schemes and pure in-page anchors, resolves each remaining
target relative to the file that contains it (dropping any ``#anchor``
fragment), and exits non-zero listing every target that does not exist.

    python scripts/check_links.py [root]

Stdlib-only on purpose: the docs job runs it before installing jax.
"""
import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:", "#")


def links_in(path: Path):
    for m in LINK.finditer(path.read_text()):
        target = m.group(1)
        if target.startswith(SKIP):
            continue
        yield target


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    files = sorted([root / "README.md", *(root / "docs").glob("*.md")])
    broken, checked = [], 0
    for f in files:
        if not f.exists():
            continue
        for target in links_in(f):
            checked += 1
            resolved = (f.parent / target.split("#", 1)[0]).resolve()
            if not resolved.exists():
                broken.append(f"{f}: {target}")
    if broken:
        print(f"{len(broken)} broken intra-repo link(s):")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"ok: {checked} intra-repo links across {len(files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())

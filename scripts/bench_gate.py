#!/usr/bin/env python
"""Benchmark regression gate: fresh sidecar vs checked-in baseline.

    PYTHONPATH=src python -m benchmarks.run --smoke --json /tmp/fresh.json
    python scripts/bench_gate.py --fresh /tmp/fresh.json

Compares a freshly produced ``benchmarks/run.py --json`` sidecar
against the committed baseline (``benchmarks/baselines/
BENCH_program.json``) and exits non-zero on regression, so CI catches
a suite that silently broke or slowed down.

Three checks, strictest first:

1. **No errored suites** — any ``*/ERROR`` row in the fresh sidecar
   fails the gate outright (an exception inside a suite emits one; the
   runner itself still exits 0 to keep the other suites running).
2. **Row presence** — every baseline row name must appear in the fresh
   run: a benchmark that stopped emitting is a silent coverage loss,
   not a pass.  (New rows in the fresh run are fine — they become
   baseline on the next refresh.)
3. **Per-row timing** — only when both sidecars carry the same
   ``hw_fingerprint`` (hardware model + physical backend): absolute
   microseconds are not comparable across machines, so a mismatch
   skips this check (loudly) rather than failing on noise.  Timing
   rows are compared on *speed-normalized* ratios: each row's
   fresh/baseline ratio is divided by the median ratio across all
   rows, which cancels uniform machine-speed drift; a row is a
   regression when its normalized ratio exceeds ``--tolerance``
   (default 3.0x — generous because smoke runs on shared CI runners
   are noisy; the gate is for order-of-magnitude breakage, e.g. a
   fast path silently falling back, not for 10% perf bookkeeping).

Refresh the baseline after intentional perf changes:

    PYTHONPATH=src python -m benchmarks.run --smoke \\
        --json benchmarks/baselines/BENCH_program.json
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_BASELINE = "benchmarks/baselines/BENCH_program.json"


def _rows_by_name(doc: dict) -> dict[str, dict]:
    return {r["name"]: r for r in doc.get("rows", [])}


def gate(baseline: dict, fresh: dict, tolerance: float) -> list[str]:
    """Returns the list of failure messages (empty = gate passes)."""
    failures: list[str] = []
    base_rows = _rows_by_name(baseline)
    fresh_rows = _rows_by_name(fresh)

    errored = [n for n in fresh_rows if n.endswith("/ERROR")]
    for n in errored:
        failures.append(f"suite errored: {n} "
                        f"({fresh_rows[n].get('derived', '')})")

    missing = [n for n in base_rows
               if n not in fresh_rows and not n.endswith("/ERROR")]
    for n in missing:
        failures.append(f"baseline row missing from fresh run: {n}")

    base_fp = baseline.get("meta", {}).get("hw_fingerprint")
    fresh_fp = fresh.get("meta", {}).get("hw_fingerprint")
    if base_fp != fresh_fp:
        print(f"hw_fingerprint mismatch (baseline {base_fp!r} vs fresh "
              f"{fresh_fp!r}): skipping timing comparisons, structural "
              f"checks only")
        return failures

    # Speed-normalized per-row comparison (same fingerprint): cancel
    # uniform machine drift with the median ratio, then apply the
    # per-row tolerance.
    ratios: dict[str, float] = {}
    for n, b in base_rows.items():
        f = fresh_rows.get(n)
        if f is None or b["us_per_call"] <= 0 or f["us_per_call"] <= 0:
            continue                  # modeled/info rows carry 0.0
        ratios[n] = f["us_per_call"] / b["us_per_call"]
    if not ratios:
        print("no comparable timing rows; structural checks only")
        return failures
    med = sorted(ratios.values())[len(ratios) // 2]
    print(f"{len(ratios)} timing rows, median fresh/baseline ratio "
          f"{med:.2f}, per-row tolerance {tolerance:.1f}x")
    for n, r in sorted(ratios.items()):
        norm = r / max(med, 1e-9)
        if norm > tolerance:
            failures.append(
                f"timing regression: {n} — "
                f"{fresh_rows[n]['us_per_call']:.1f}us vs baseline "
                f"{base_rows[n]['us_per_call']:.1f}us "
                f"({norm:.2f}x over the run median, limit "
                f"{tolerance:.1f}x)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline sidecar")
    ap.add_argument("--fresh", required=True,
                    help="sidecar from the run under test "
                         "(benchmarks/run.py --json PATH)")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="max per-row fresh/baseline ratio after "
                         "median normalization")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    failures = gate(baseline, fresh, args.tolerance)
    if failures:
        print(f"\nbench gate FAILED ({len(failures)} problem(s)):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

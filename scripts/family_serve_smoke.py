"""Registry-wide serving smoke: every LM config through the CLI server
on the stateful Program path (CI job ``serving-smoke``).

Runs ``launch/serve.py --smoke --program`` in-process for every entry
in the LM registry and asserts

  * the server exits 0 and actually serves tokens (> 0), for every
    family with a registered ``state_specs`` hook — dense, moe, ssm,
    hybrid, audio alike; the generic named-state refactor means none
    of them fall back to the legacy loop;
  * the one intentionally gated config (``llama-3.2-vision-11b``: no
    decoder-only graph, gated cross-attention, vision-encoder inputs)
    exits 2 and names *every* blocker, not just the first.

Run: PYTHONPATH=src python scripts/family_serve_smoke.py
"""
import contextlib
import io
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Configs that must refuse --program: family has no state_specs hook /
# Program lowering.  Anything else in the registry must serve.
XFAIL = {
    "llama-3.2-vision-11b": ("family=vlm", "cross-attention",
                             "vision-encoder"),
}


def _serve_one(name):
    """Run serve.main for one arch; return (exit_code, stdout, stderr)."""
    from repro.launch import serve

    argv = ["--arch", name, "--smoke", "--program",
            "--slots", "2", "--max-len", "32",
            "--requests", "3", "--max-new", "4"]
    out, err, code = io.StringIO(), io.StringIO(), 0
    with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
        try:
            serve.main(argv)
        except SystemExit as e:
            code = int(e.code or 0)
    return code, out.getvalue(), err.getvalue()


def main() -> None:
    from repro.configs import REGISTRY

    failures = []
    for name in sorted(REGISTRY):
        code, out, err = _serve_one(name)
        if name in XFAIL:
            if code != 2:
                failures.append(f"{name}: expected exit 2, got {code}")
                continue
            missing = [b for b in XFAIL[name] if b not in err]
            if missing:
                failures.append(
                    f"{name}: fallback reason missing blockers {missing}: "
                    f"{err.strip()}")
                continue
            print(f"  {name}: gated as expected (full blocker list)")
            continue
        if code != 0:
            failures.append(f"{name}: exit {code}\n{err.strip()}")
            continue
        m = re.search(r"served (\d+) requests, (\d+) tokens", out)
        tokens = int(m.group(2)) if m else 0
        if tokens <= 0:
            failures.append(f"{name}: exit 0 but served no tokens")
            continue
        print(f"  {name}: served {tokens} tokens on the Program path")

    if failures:
        print("family serve smoke FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        raise SystemExit(1)
    print(f"family serve smoke: all {len(REGISTRY)} registry configs hold")


if __name__ == "__main__":
    main()

"""Autotune smoke: trace -> calibrate -> tune -> re-compile, asserting
the loop's safety invariants on CPU (CI job ``autotune-smoke``).

For one CNN (default alexnet-owt) and one LM (default
smollm-360m-smoke):

  1. tune with a tiny budget (top-k/repeats from the CLI), pallas
     interpret mode for the CNN so candidate tilings actually execute;
  2. assert the tuner emitted a measured-vs-predicted error table;
  3. assert a second tune pass is a pure cache hit (zero replay
     measurements) — the "second compile" acceptance criterion;
  4. assert the tuned schedule's modeled cost is <= the untuned one
     (the no-model-regression filter made this a guarantee; here we
     check the guarantee held through compile_model);
  5. assert tuned-vs-untuned forward outputs agree to <= 1e-5 —
     schedule decisions move bytes, never math.

Run: PYTHONPATH=src python scripts/autotune_smoke.py [--top-k 1 ...]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp


def _check_cnn(args) -> None:
    from repro.configs import get_config
    from repro.core import autotune
    from repro.core.hw import TPU_V5E
    from repro.core.schedule import compile_model
    from repro.models import cnn
    from repro.models.common import init_params

    cfg = get_config(args.cnn)
    hw = TPU_V5E
    cache = autotune.TunedCache.load(
        os.path.join(tempfile.mkdtemp(), "cnn.json"))
    rep = autotune.tune_cnn(
        cfg, batch=1, hw=hw, cache=cache, impl=args.impl,
        interpret=args.interpret, top_k=args.top_k, repeats=args.repeats)
    print(rep.summary())
    assert rep.error_rows, "no error table emitted"
    from repro.core.cost import format_error_table
    print(format_error_table(rep.error_rows))

    rep2 = autotune.tune_cnn(
        cfg, batch=1, hw=hw, cache=cache, impl=args.impl,
        interpret=args.interpret, top_k=args.top_k, repeats=args.repeats)
    assert rep2.n_measurements == 0, \
        f"second tune re-measured ({rep2.n_measurements}x)"
    print(f"[ok] {cfg.name}: second tune = pure cache hit")

    # Modeled cost must not regress (compare like-for-like: no cost
    # model on either side, so exec_time_s is the analytic model).
    fp = autotune.hw_fingerprint(hw)
    by = jnp.dtype(cfg.jdtype).itemsize
    plain = compile_model(cnn.to_graph(cfg, 1, by), hw)
    tuned = compile_model(cnn.to_graph(cfg, 1, by), hw,
                          tuned=cache.view(cfg.name, fp, 1))
    assert tuned.total_traffic_bytes <= plain.total_traffic_bytes, \
        (tuned.total_traffic_bytes, plain.total_traffic_bytes)
    assert tuned.total_exec_time_s <= plain.total_exec_time_s * (1 + 1e-9), \
        (tuned.total_exec_time_s, plain.total_exec_time_s)
    print(f"[ok] {cfg.name}: tuned modeled cost <= untuned "
          f"({tuned.total_traffic_bytes:.3e} <= "
          f"{plain.total_traffic_bytes:.3e} bytes)")

    params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, cfg.input_hw, cfg.input_hw, cfg.input_ch),
                          jnp.float32)
    y0 = cnn.forward(params, x, cfg, impl=args.impl, hw=hw,
                     interpret=args.interpret)
    autotune.activate(cache)
    try:
        y1 = cnn.forward(params, x, cfg, impl=args.impl, hw=hw,
                         interpret=args.interpret)
    finally:
        autotune.deactivate()
    err = float(jnp.max(jnp.abs(y1.astype(jnp.float32) -
                                y0.astype(jnp.float32))))
    assert err <= 1e-5, f"tuned-vs-untuned parity broke: {err}"
    print(f"[ok] {cfg.name}: tuned-vs-untuned forward max|d|={err:.2e}")


def _check_lm(args) -> None:
    from repro.configs import get_config
    from repro.core import autotune
    from repro.core.hw import TPU_V5E
    from repro.models import transformer
    from repro.models.common import init_params

    cfg = get_config(args.lm)
    hw = TPU_V5E
    cache = autotune.TunedCache.load(
        os.path.join(tempfile.mkdtemp(), "lm.json"))
    rep = autotune.tune_lm_decode(
        cfg, slots=args.slots, max_len=args.max_len, hw=hw, cache=cache,
        impl=args.impl, top_k=args.top_k, repeats=args.repeats)
    print(rep.summary())
    assert rep.error_rows, "no error table emitted"

    rep2 = autotune.tune_lm_decode(
        cfg, slots=args.slots, max_len=args.max_len, hw=hw, cache=cache,
        impl=args.impl, top_k=args.top_k, repeats=args.repeats)
    assert rep2.n_measurements == 0, \
        f"second tune re-measured ({rep2.n_measurements}x)"
    print(f"[ok] {cfg.name}: second tune = pure cache hit")

    params = init_params(transformer.param_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, args.max_len // 2),
                              0, cfg.vocab)
    y0 = transformer.program_forward(params, toks, cfg, hw=hw,
                                     impl=args.impl)
    autotune.activate(cache)
    try:
        y1 = transformer.program_forward(params, toks, cfg, hw=hw,
                                         impl=args.impl)
    finally:
        autotune.deactivate()
    err = float(jnp.max(jnp.abs(y1.astype(jnp.float32) -
                                y0.astype(jnp.float32))))
    assert err <= 1e-5, f"tuned-vs-untuned parity broke: {err}"
    print(f"[ok] {cfg.name}: tuned-vs-untuned forward max|d|={err:.2e}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cnn", default="alexnet-owt")
    ap.add_argument("--lm", default="smollm-360m-smoke")
    ap.add_argument("--impl", default="auto",
                    help='"pallas" + --interpret exercises candidate '
                         "tilings on CPU; the default resolves to the "
                         "reference kernels off-TPU")
    ap.add_argument("--interpret", action="store_true", default=None)
    ap.add_argument("--top-k", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=16)
    args = ap.parse_args(argv)
    _check_cnn(args)
    _check_lm(args)
    print("autotune smoke: all invariants hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""End-to-end training driver example (deliverable b).

Defaults to a CPU-feasible reduced model; the full ~100M-parameter
invocation used on real hardware is:

    PYTHONPATH=src python examples/train_lm.py --full

which trains a 12-layer/512-dim (~100M with embeddings) smollm-family
model for 300 steps on the synthetic stream, checkpointing + auto-
resuming via the fault-tolerant runtime (kill it mid-run and rerun to
see the resume).

After training, the trained parameters are evaluated through the
**compiled Program** (graph -> schedule -> regions -> instruction
stream, docs/ARCHITECTURE.md): the same path that serves traffic, not
the legacy scan forward.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_driver
from repro.configs import REGISTRY

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params, 300 steps (hours on CPU; minutes on "
                     "a real accelerator)")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
args = ap.parse_args()

if args.full:
    import repro.configs as C
    base = REGISTRY["smollm-360m"]
    cfg100m = dataclasses.replace(
        base, name="smollm-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, dtype="float32")
    C.REGISTRY["smollm-100m"] = cfg100m
    cfg, params = train_driver.main(
        ["--arch", "smollm-100m", "--steps", "300",
         "--batch", "8", "--seq", "256", "--ckpt-dir", args.ckpt_dir])
else:
    cfg, params = train_driver.main(
        ["--arch", "smollm-360m", "--smoke",
         "--steps", "120", "--batch", "8", "--seq", "64",
         "--ckpt-dir", args.ckpt_dir])

# --- eval through the compiled Program (the serving path) ---------------------
import jax
import jax.numpy as jnp

from repro.data import SyntheticLM
from repro.models import cross_entropy_loss
from repro.models.transformer import compile_program, program_forward

eval_seq, eval_batch = 64, 4
program = compile_program(cfg, batch=eval_batch, seq=eval_seq)
print(f"\neval via {program.listing().splitlines()[0]}")
batch = SyntheticLM(vocab=cfg.vocab, seq_len=eval_seq,
                    global_batch=eval_batch, seed=1).batch_at(10_000)
logits = program_forward(params, jnp.asarray(batch["tokens"]), cfg,
                         impl="reference")
loss = cross_entropy_loss(logits, jnp.asarray(batch["labels"]))
print(f"program-path eval loss on held-out synthetic batch: "
      f"{float(loss):.4f}")

"""Cross-pod int8 gradient sync demo (distributed-optimization trick).

    PYTHONPATH=src python examples/crosspod_sync.py

Runs on 8 forced host devices: a 2-"pod" mesh where each pod computes a
different gradient; the pods synchronize with the int8-compressed psum
(4x less cross-pod traffic) and error feedback keeps the long-run
average unbiased (printed drift ~0).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

sys.path.insert(0, "src")
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.crosspod import compressed_psum

mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2, 4),
                         ("pod", "data"))

def sync(grads, err):
    out, err = compressed_psum(grads, "pod", error=err)
    return out / 2.0, err

f = jax.jit(jax.shard_map(sync, mesh=mesh,
                          in_specs=(P("pod", None), P("pod", None)),
                          out_specs=(P("pod", None), P("pod", None)),
                          axis_names={"pod"}, check_vma=False))

key = jax.random.PRNGKey(0)
g = jax.random.normal(key, (2, 4096)) * 0.01      # per-pod gradients
err = jnp.zeros_like(g)
acc_true = jnp.zeros((4096,))
acc_comp = jnp.zeros_like(g)
for step in range(50):
    avg, err = f(g, err)
    acc_comp = acc_comp + avg
    acc_true = acc_true + g.mean(0)
drift = float(jnp.abs(acc_comp[0] - acc_true).max()
              / jnp.abs(acc_true).max())
print(f"50 compressed syncs: relative drift {drift:.4%} "
      f"(error feedback keeps it unbiased)")
print(f"bytes per sync: int8 {g[0].size}B vs f32 {g[0].size*4}B (4x less)")

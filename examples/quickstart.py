"""Quickstart: the whole stack in one page.

    PYTHONPATH=src python examples/quickstart.py

1. picks an assigned architecture config (--arch, default smollm-360m,
   reduced to its smoke size for CPU),
2. runs the schedule compiler on an AlexNet conv layer to show the
   paper's Mloop/Kloop decision, then compiles the whole AlexNet to an
   executable Program (schedule -> regions -> instruction stream) and
   classifies one image through runtime/executor.py,
3. trains the LM for 60 steps on the synthetic stream (loss printed),
4. serves two batched requests from the trained weights.
"""
import argparse
import sys

sys.path.insert(0, "src")
import jax
import numpy as np

from repro.configs import get_config
from repro.core import SNOWFLAKE, TPU_V5E, choose_matmul_dataflow
from repro.data import SyntheticLM
from repro.models import get_model, init_params
from repro.models.losses import chunked_cross_entropy
from repro.optim import AdamW
from repro.serving import Request, ServingEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm-360m")
ap.add_argument("--steps", type=int, default=60)
args = ap.parse_args()

# -- 1. the paper's decision, on its own hardware ------------------------------
from repro.core import ModelGraph, compile_model, conv_node
g = ModelGraph("alexnet_conv2")
g.add(conv_node("conv2", 27, 27, 64, 192, 5, 5, stride=1, pad=2))
layer = compile_model(g, SNOWFLAKE, paper_faithful=True).layers[0]
print(f"[compiler] AlexNet conv2 on Snowflake: {layer.dataflow.value} "
      f"({layer.traffic_bytes/1e6:.1f} MB moved, "
      f"{layer.exec_time_s*1e3:.2f} ms; alternatives "
      f"{ {k: round(v/1e6,1) for k, v in layer.notes.items() if k in ('kloop', 'mloop')} })")
dec_tpu = choose_matmul_dataflow(8192, 4096, 14336, 2, TPU_V5E)
print(f"[compiler] llama3 FFN tile on TPU v5e: {dec_tpu.dataflow.value} "
      f"blocks={dec_tpu.tiling.bm}x{dec_tpu.tiling.bk}x{dec_tpu.tiling.bn}")

# -- 1b. compile-to-Program: the schedule is what executes -----------------------
from repro.configs import CNN_REGISTRY
from repro.models import cnn
from repro.runtime import executor

cnn_cfg = CNN_REGISTRY["alexnet-owt"]
program = cnn.compile_program(cnn_cfg, batch=1)
plan = program.plan
print(f"[program] {cnn_cfg.name}: {len(program.ops)} ops, "
      f"{plan.n_pingpong} ping-pong + {plan.n_pinned} pinned regions "
      f"({plan.total_bytes/1e6:.2f} MB activations); first op: "
      f"{program.ops[0].trace()}")
cnn_params = init_params(cnn.param_defs(cnn_cfg), jax.random.PRNGKey(2))
img = jax.random.normal(jax.random.PRNGKey(3), (1, 224, 224, 3))
logits = executor.run(program, cnn_params, img, impl="reference")
print(f"[program] executed via runtime/executor.py -> "
      f"class {int(logits.argmax())}")

# -- 2. train ------------------------------------------------------------------
cfg = get_config(args.arch).smoke()
api = get_model(cfg)
params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
opt = AdamW(lr=3e-3)
opt_state = opt.init(params)
data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=1)

@jax.jit
def step(params, opt_state, batch):
    def loss_fn(p):
        out = api.forward(p, batch["tokens"], cfg, return_hidden=True)
        head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        return chunked_cross_entropy(out["hidden"], head, batch["labels"])
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state, _ = opt.update(grads, opt_state, params)
    return params, opt_state, loss

for i in range(args.steps):
    params, opt_state, loss = step(params, opt_state, data.batch_at(i))
    if i % 20 == 0 or i == args.steps - 1:
        print(f"[train] step {i:3d} loss {float(loss):.3f}")

# -- 3. serve ------------------------------------------------------------------
eng = ServingEngine(cfg, params, slots=2, max_len=64)
eng.submit(Request(uid=0, prompt=np.array([3, 1, 4], np.int32),
                   max_new_tokens=6))
eng.submit(Request(uid=1, prompt=np.array([2, 7], np.int32),
                   max_new_tokens=6))
for r in eng.run_until_drained():
    print(f"[serve] request {r.uid}: {list(r.prompt)} -> {r.out_tokens}")

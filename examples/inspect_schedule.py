"""Schedule inspection: what the compiler decided, per layer.

    PYTHONPATH=src python examples/inspect_schedule.py \
        [--model resnet18] [--arch smollm-360m] [--seq 16]

Prints the per-layer Mloop/Kloop choices, tile shapes, traffic and the
Fig-4-style bandwidth table for one of the paper's CNNs, the executable
Program the schedule lowers to (the paper-style instruction trace with
§5.1 memory-region ids), the LM arch's Program lowering (its smoke
config — dense families only), then the distributed-level decisions
for the assigned LM architecture.  The listings in docs/ARCHITECTURE.md
are this script's output.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import CNN_REGISTRY, get_config
from repro.configs.base import ShapeSpec
from repro.core import SINGLE_POD, SNOWFLAKE, TPU_V5E, compile_model
from repro.core.ir import LayerKind
from repro.models.cnn import compile_program, to_graph
from repro.parallel.rules import make_plan

ap = argparse.ArgumentParser()
ap.add_argument("--model", default="resnet18")
ap.add_argument("--arch", default="llama3-8b")
ap.add_argument("--seq", type=int, default=16,
                help="sequence length for the LM Program listing")
args = ap.parse_args()

g = to_graph(CNN_REGISTRY[args.model], batch=1)
sched = compile_model(g, SNOWFLAKE, paper_faithful=True)
print(f"== {args.model} on Snowflake "
      f"(exec {sched.total_exec_time_s*1e3:.1f} ms, "
      f"avg BW {sched.summary()['avg_bw_gbps']:.2f} GB/s) ==")
print(f"{'layer':14s} {'order':6s} {'strip':>5s} {'kpt':>4s} "
      f"{'MB moved':>9s} {'ms':>7s} {'stall':>5s}")
for l in sched.layers:
    if l.kind is not LayerKind.CONV2D:
        continue
    ct = l.conv_tiling
    print(f"{l.name:14s} {l.dataflow.value:6s} {ct.out_rows:5d} "
          f"{ct.kernels_per_tile:4d} {l.traffic_bytes/1e6:9.2f} "
          f"{l.exec_time_s*1e3:7.3f} {l.notes.get('stall', 1.0):5.2f}")

# The schedule is not a report: it lowers to the executable Program
# (regions + instruction stream) that runtime/executor.py runs.
print(f"\n== {args.model} Program (TPU v5e schedule) ==")
print(compile_program(CNN_REGISTRY[args.model], batch=1,
                      hw=TPU_V5E).listing())

# The LM families lower to Programs too (PR 3): the transformer graph
# (embed -> blocks -> lm head, residual adds fused into the projection
# writebacks) runs the same schedule -> regions -> instruction-stream
# pipeline.  Listed on the smoke config to keep the trace one page.
cfg = get_config(args.arch)
try:
    from repro.models import transformer
    lm_smoke = cfg.smoke()
    prog = transformer.compile_program(lm_smoke, batch=1, seq=args.seq)
    print(f"\n== {lm_smoke.name} Program (batch 1 x seq {args.seq}, "
          f"TPU v5e schedule) ==")
    print(prog.listing())
    # The stateful serving pair: prefill (cache writes at the admitted
    # slot) + decode (one token per slot against the persistent KV
    # regions), sharing one region table.
    pair = transformer.compile_program_pair(lm_smoke, slots=2,
                                            max_len=args.seq)
    print(f"\n== {lm_smoke.name} serving pair (2 slots x max_len "
          f"{args.seq}) ==")
    print(pair.listing())
except NotImplementedError as e:
    print(f"\n== no LM Program lowering: {e} ==")

print()
for shape in cfg.shapes():
    plan = make_plan(cfg, shape, SINGLE_POD, "auto")
    keys = {k: v for k, v in plan.decisions.items()
            if k in ("layout", "wq", "w_gate", "embed", "experts")}
    print(f"== {args.arch} x {shape.name}: {keys}")

"""Batched serving example: continuous batching over fixed cache slots.

    PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-7b]

Works with any assigned architecture (KV-cache archs get rolling
windows; SSM archs carry O(1) state).  Dense transformer archs route
through the compiled ``Program`` fast path — the engine executes the
compiler's instruction stream per tick; families without a Program
lowering fall back to the legacy scan decode automatically.
"""
import sys

sys.path.insert(0, "src")
from repro.launch import serve as serve_driver

serve_driver.main(sys.argv[1:] + ["--smoke", "--program"])

"""Figure 4 reproduction: required memory bandwidth under Mloop vs Kloop
for representative conv layers, against the 4.2 GB/s board limit.

The paper's qualitative claims checked here:
  * AlexNet layers (A, B) sit below the limit in either mode;
  * ResNet50 1x1 layers (G, H) exceed the limit under Mloop and need
    Kloop;
  * the better mode is layer-dependent (the crossover exists).
"""
from repro.core import SNOWFLAKE, Dataflow, choose_matmul_dataflow
from .common import emit

# (label, H, W, k, C_in, C_out, stride, pad) — A,B from AlexNet;
# C..F mid ResNet; G,H ResNet50-style 1x1 with many channels.
CONVS = [
    ("A_alexnet_conv2", 27, 27, 5, 64, 192, 1, 2),
    ("B_alexnet_conv4", 13, 13, 3, 384, 256, 1, 1),
    ("C_resnet_3x3_128", 28, 28, 3, 128, 128, 1, 1),
    ("D_resnet_3x3_256", 14, 14, 3, 256, 256, 1, 1),
    ("E_resnet_1x1_512", 7, 7, 1, 512, 2048, 1, 0),
    ("F_resnet_3x3_512", 7, 7, 3, 512, 512, 1, 1),
    ("G_resnet50_1x1_1024", 14, 14, 1, 1024, 2048, 2, 0),
    ("H_resnet50_1x1_2048", 7, 7, 1, 2048, 512, 1, 0),
]

LIMIT = 4.2  # GB/s


def run():
    below_both, kloop_needed = [], []
    for (label, H, W, k, cin, cout, s, p) in CONVS:
        oh = (H + 2 * p - k) // s + 1
        M, K, N = oh * oh, cin * k * k, cout
        flops = 2.0 * M * K * N
        t_compute = flops / SNOWFLAKE.peak_flops
        dec = choose_matmul_dataflow(M, K, N, 2, SNOWFLAKE,
                                     allow_output_stationary=False)
        bws = {}
        for mode, traffic in dec.alternatives.items():
            bws[mode] = traffic / t_compute / 1e9   # GB/s needed at peak
        chosen = dec.dataflow.value
        emit(f"fig4/{label}", bws[chosen],
             f"mloop_gbps={bws.get('mloop', 0):.2f};"
             f"kloop_gbps={bws.get('kloop', 0):.2f};chosen={chosen};"
             f"limit_gbps={LIMIT}")
        if max(bws.values()) < LIMIT:
            below_both.append(label)
        if (bws.get("mloop", 0) > LIMIT
                and bws.get("kloop", float("inf")) <= LIMIT):
            kloop_needed.append(label)
    emit("fig4/below_limit_both_modes", float(len(below_both)),
         ";".join(below_both))
    emit("fig4/kloop_required", float(len(kloop_needed)),
         ";".join(kloop_needed) + ";paper=G,H-style 1x1 layers")


if __name__ == "__main__":
    run()

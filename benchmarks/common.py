"""Benchmark utilities: wall-clock timing + CSV/JSON emission."""
import json
import sys
import time

import jax

# Rows collected by emit() for the --json sidecar (benchmarks/run.py).
ROWS: list[dict] = []

# Final obs.MetricsRegistry snapshot from the last serving-bench engine
# (set by set_metrics_snapshot); embedded in the sidecar so a bench run
# ships its own metrics plane next to the timing rows.
METRICS: dict | None = None


def set_metrics_snapshot(snapshot: dict) -> None:
    """Attach a metrics-registry snapshot (``obs.MetricsRegistry
    .snapshot()``) to the next ``write_json`` sidecar."""
    global METRICS
    METRICS = snapshot


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 2),
                 "derived": derived})


def _sidecar_meta() -> dict:
    """Provenance stamp for the JSON sidecar: which machine the numbers
    are valid on (``core/autotune.hw_fingerprint`` — model params +
    physical backend), which tuned-cache generation produced the
    schedules, and the exact source revision.  Without these a sidecar
    diffed across CI runs can silently compare a CPU-interpret run
    against a TPU run or a stale tuned cache against a fresh one."""
    import subprocess

    from repro.core.autotune import active_generation, hw_fingerprint
    from repro.core.hw import TPU_V5E
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "hw_fingerprint": hw_fingerprint(TPU_V5E),
        "tuned_generation": active_generation(),
        "git_sha": sha,
        "created": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def write_json(path: str) -> None:
    """Dump every row emitted so far, wrapped with a provenance ``meta``
    header — the machine-readable sidecar to the CSV stream (CI uploads
    it as an artifact so regressions are diffable across runs, and the
    meta says *which* runs are comparable)."""
    doc = {"meta": _sidecar_meta(), "rows": ROWS}
    if METRICS is not None:
        doc["metrics"] = METRICS
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)

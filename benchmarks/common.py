"""Benchmark utilities: wall-clock timing + CSV/JSON emission."""
import json
import sys
import time

import jax

# Rows collected by emit() for the --json sidecar (benchmarks/run.py).
ROWS: list[dict] = []


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 2),
                 "derived": derived})


def write_json(path: str) -> None:
    """Dump every row emitted so far as a JSON array — the
    machine-readable sidecar to the CSV stream (CI uploads it as an
    artifact so regressions are diffable across runs)."""
    with open(path, "w") as f:
        json.dump(ROWS, f, indent=2)

"""Benchmark utilities: wall-clock timing + CSV emission."""
import sys
import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()

"""Program-execution benchmark: the compiled Program path vs a legacy
layer-by-layer forward.

For each CNN this measures
  * wallclock of ``runtime/executor.py`` running the compiled Program
    (conv->pool fusion, fused bias/activation/bypass epilogues — the
    schedule's decisions executing) vs the pre-Program forward: every
    layer as its own reference op with its own HBM round trip;
  * the schedule's modeled traffic for the Program (fused pools free,
    zero-copy strips) vs the unfused per-layer minimum-bytes sum —
    the traffic the Program path deletes on paper;
and checks both paths agree with the oracle numerics.

Smoke mode runs a reduced-depth CNN so CI stays fast; the full run
covers AlexNetOWT and ResNet18.
"""
import functools

import jax
import jax.numpy as jnp

from repro.configs import CNN_REGISTRY
from repro.configs.base import CNNConfig, CNNLayer as C
from repro.models import cnn, init_params
from repro.models.cnn import reference_forward as legacy_forward
from repro.runtime import executor

from .common import emit, time_call

SMOKE = False          # set by benchmarks.run --smoke

# Reduced-depth stand-in with the same feature mix (fused pool,
# residual bypass, projection shortcut, fc head) for smoke runs.
TINY = CNNConfig(
    name="tiny-resnet", input_hw=32, input_ch=3, n_classes=10,
    layers=(
        C("conv", 16, 3, 1, 1),
        C("maxpool", k=2, stride=2),
        C("conv", 32, 3, 2, 1, activation=None, input_of=1),
        C("conv", 32, 3, 2, 1, input_of=1),
        C("conv", 32, 3, 1, 1, activation="relu", bypass_of=2),
        C("avgpool", k=8, stride=8),
        C("fc", 10, activation=None),
    ))


def _unfused_traffic(cfg, batch, dtype_bytes) -> float:
    g = cnn.to_graph(cfg, batch=batch, dtype_bytes=dtype_bytes)
    return g.total_min_bytes()


def run():
    cfgs = [TINY] if SMOKE else [TINY, CNN_REGISTRY["alexnet-owt"],
                                 CNN_REGISTRY["resnet18"]]
    for cfg in cfgs:
        params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(
            jax.random.PRNGKey(1),
            (1, cfg.input_hw, cfg.input_hw, cfg.input_ch), jnp.float32)

        program = cnn.compile_program(cfg, batch=1)
        prog_fn = executor.jitted_runner(program, impl="reference")
        legacy_fn = jax.jit(functools.partial(legacy_forward, cfg=cfg))

        err = float(jnp.abs(prog_fn(params, x)
                            - legacy_fn(params, x)).max())
        warmup, iters = (1, 3) if SMOKE else (2, 7)
        t_prog = time_call(prog_fn, params, x, warmup=warmup, iters=iters)
        t_leg = time_call(legacy_fn, params, x, warmup=warmup, iters=iters)

        by = jnp.dtype(cfg.jdtype).itemsize
        modeled = program.total_traffic_bytes
        unfused = _unfused_traffic(cfg, 1, by)
        emit(f"program/{cfg.name}/wallclock", t_prog,
             f"legacy_us={t_leg:.2f};"
             f"program_over_legacy={t_prog / max(t_leg, 1e-9):.3f};"
             f"err={err:.2e}")
        emit(f"program/{cfg.name}/traffic", 0.0,
             f"program_mb={modeled/1e6:.2f};unfused_min_mb={unfused/1e6:.2f};"
             f"ops={len(program.ops)};regions={len(program.plan.regions)};"
             f"region_mb={program.plan.total_bytes/1e6:.3f}")


if __name__ == "__main__":
    run()

"""Table 2 reproduction: whole-model execution time + bandwidth on the
Snowflake analytic model, via the full compiler pipeline
(CNNConfig -> IR -> schedule).

Paper: AlexNetOWT 10.68 ms / 1.22 GB/s; ResNet18 46.77 ms / 2.25 GB/s;
ResNet50 218.61 ms / 1.87 GB/s (conv layers; FC excluded, as the paper
excludes FC from its timings).
"""
from repro.configs import CNN_REGISTRY
from repro.core import SNOWFLAKE, compile_model
from repro.core.ir import LayerKind
from repro.models.cnn import to_graph
from .common import emit

PAPER = {
    "alexnet-owt": (10.68, 1.22),
    "resnet18": (46.77, 2.25),
    "resnet50": (218.61, 1.87),
}


def run():
    for name, (paper_ms, paper_bw) in PAPER.items():
        g = to_graph(CNN_REGISTRY[name], batch=1, dtype_bytes=2)
        # Paper accounting: Table 2 compares against the paper's own
        # numbers, which count only the conv streams — keep the
        # materialization round trip out of this reproduction.
        sched = compile_model(g, SNOWFLAKE, paper_faithful=True,
                              charge_materialization=False)
        conv_layers = [l for l in sched.layers
                       if l.kind in (LayerKind.CONV2D,)]
        t = sum(l.exec_time_s for l in conv_layers)
        traffic = sum(l.traffic_bytes for l in conv_layers)
        bw = traffic / t / 1e9 if t else 0.0
        emit(f"table2/{name}/exec", t * 1e9 / 1e3,
             f"model_ms={t*1e3:.2f};paper_ms={paper_ms};"
             f"ratio={t*1e3/paper_ms:.2f}")
        emit(f"table2/{name}/bw", bw,
             f"model_gbps={bw:.2f};paper_gbps={paper_bw};"
             f"imbalance_pct={sched.load_imbalance_pct:.1f}")


if __name__ == "__main__":
    run()

"""§5.3 reproduction: fixed-point accuracy profile.

The paper reports ImageNet top-5 with fp32 89%, Q8.8 84%, Q5.11 88% —
i.e. Q5.11 ≈ fp32 > Q8.8 for CNN activations.  Without ImageNet in the
container we reproduce the *ordering* on the information-preserving
proxy the accuracy difference stems from: per-layer quantization SNR of
a conv stack's activations (paper's layer-by-layer validation flow,
core/quant.validate_layerwise).
"""
import jax
import jax.numpy as jnp

from repro.configs import CNN_REGISTRY
from repro.core.quant import Q5_11, Q8_8, dequantize, quantize
from repro.models import cnn, init_params
from .common import emit


def run():
    cfg = CNN_REGISTRY["alexnet-owt"]
    params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 224, 224, 3),
                          jnp.float32)
    # capture per-layer activations via a hand-rolled partial forward
    acts = []
    h = x
    from repro.kernels.conv2d import conv2d_ref, maxpool2d_ref
    for i, layer in enumerate(cfg.layers):
        if layer.kind == "conv":
            p = params[f"layer_{i:02d}"]
            h = conv2d_ref(h, p["w"], stride=layer.stride, pad=layer.pad,
                           bias=p["b"], activation=layer.activation)
            acts.append(h)
        elif layer.kind == "maxpool":
            h = maxpool2d_ref(h, window=layer.k, stride=layer.stride,
                              pad=layer.pad)
        else:
            break
    snrs = {}
    for fmt, name in ((Q8_8, "q8.8"), (Q5_11, "q5.11")):
        errs = []
        for a in acts:
            deq = dequantize(quantize(a, fmt), fmt)
            num = jnp.mean(jnp.square(a))
            den = jnp.mean(jnp.square(a - deq)) + 1e-20
            errs.append(float(10 * jnp.log10(num / den)))
        snr = sum(errs) / len(errs)
        snrs[name] = snr
        emit(f"quant/{name}_snr_db", snr,
             f"per_layer={';'.join(f'{e:.1f}' for e in errs)}")
    ok = snrs["q5.11"] > snrs["q8.8"]
    emit("quant/ordering_q511_gt_q88", float(ok),
         "paper: top5 fp32 89% ~ Q5.11 88% > Q8.8 84%")


if __name__ == "__main__":
    run()

"""Table 3 reproduction: speedup vs communication load imbalance on the
paper's CONV 1x1 (1024 -> 2048 channels, stride 2) workload.

The paper drives imbalance from 132% down to 5% by splitting loads
across the 4 load units and measures 1.00 -> 1.66x, saturating once
transfers hide under compute.  We reproduce the saturation curve with
the same execution model (step = max(compute, slowest unit)) and verify
our balancer lands in the saturated regime.

Paper: 5%:1.658  17%:1.656  42%:1.652  102%:1.644  114%:1.297  132%:1.0
"""
from repro.core import SNOWFLAKE, balance_transfers
from .common import emit

PAPER = [(5, 1.658), (17, 1.656), (42, 1.652), (102, 1.644),
         (114, 1.297), (132, 1.000)]


def run():
    # CONV 1x1, 14x14x1024 -> 7x7x2048 (stride 2), one maps tile.
    M, K, N = 7 * 7, 1024, 2048
    flops = 2.0 * M * K * N
    t_compute = flops / SNOWFLAKE.peak_flops
    maps_bytes = 14 * 14 * K * 2
    ker_bytes = K * N * 2
    total = maps_bytes + ker_bytes
    # Each of the 4 load units owns 1/4 of the port bandwidth; a unit
    # carrying (1 + C_L) x the mean load finishes (1 + C_L) x later.
    unit_bw = SNOWFLAKE.hbm_bandwidth / SNOWFLAKE.load_units
    balanced = (total / SNOWFLAKE.load_units) / unit_bw

    def step_time(imb_pct):
        worst_unit = balanced * (1.0 + imb_pct / 100.0)
        return max(t_compute, worst_unit)

    t_worst = step_time(132.0)
    for imb, paper_speedup in PAPER:
        sp = t_worst / step_time(imb)
        emit(f"table3/imbalance_{imb}pct", step_time(imb) * 1e6,
             f"model_speedup={sp:.3f};paper_speedup={paper_speedup}")

    # our balancer on the same transfer set
    res = balance_transfers([maps_bytes, ker_bytes],
                            SNOWFLAKE.load_units)
    sp = t_worst / step_time(res.imbalance_after)
    emit("table3/balancer_result", res.imbalance_after,
         f"imbalance_before={res.imbalance_before:.0f}pct;"
         f"after={res.imbalance_after:.1f}pct;speedup={sp:.3f}")


if __name__ == "__main__":
    run()

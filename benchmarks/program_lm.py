"""LM Program-execution benchmark: the compiled transformer Program vs
the legacy scan forward, plus the decode regime.

For a dense-LM config this measures

  * wallclock of ``runtime/executor.py`` running the compiled Program
    (resolved matmul blocks, flash-attention tiles, residual adds fused
    into the projection writebacks) vs the legacy ``jax.lax.scan``
    forward — both jitted, both on the reference kernels so the
    comparison is schedule-vs-schedule, not Mosaic-vs-interpreter;
  * the schedule's modeled traffic for the Program vs the graph's
    unfused per-op minimum-bytes sum;
  * **decode**: serving tokens/s at slot occupancies 1 / half / full
    for the stateful decode Program (persistent KV regions +
    ProgramState, the serving engine's hot loop) vs the legacy
    ``decode_step`` scan vs the retired per-tick prefill-recompute
    path (the pre-stateful program engine: one full causal forward at
    (slots, max_len) per emitted token);

and checks the paths agree numerically (the PR-3 parity bound).

Smoke mode shrinks depth/shape so CI stays fast; the full run uses the
smollm-360m smoke config at serving-like shapes.
"""
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.models import init_params, transformer
from repro.runtime import executor

from .common import emit, time_call

SMOKE = False          # set by benchmarks.run --smoke


def _time_threaded(fn, params, toks, carry, *, warmup=1, iters=3):
    """Median wall time (us) of a (params, toks, carry) -> (out, carry)
    step whose carry is threaded (and possibly donated) through calls."""
    for _ in range(warmup):
        out, carry = fn(params, toks, carry)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out, carry = fn(params, toks, carry)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_decode_bench():
    """Decode-regime tokens/s: decode-Program vs legacy decode_step vs
    the retired prefill-recompute engine path."""
    cfg = REGISTRY["smollm-360m"].smoke()
    slots, max_len, warmup, iters = (2, 16, 1, 3) if SMOKE else (8, 64, 2, 7)
    if SMOKE:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-bench", n_layers=2)
    params = init_params(transformer.param_defs(cfg), jax.random.PRNGKey(0))
    prompt_len = max_len // 2
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(slots, prompt_len)).astype(np.int32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(slots,)), jnp.int32)

    # stateful decode Program: prefill every slot once, tick the pair
    pair = transformer.compile_program_pair(cfg, slots=slots,
                                            max_len=max_len)
    state = executor.init_program_state(pair)
    prefill = executor.jitted_prefill_runner(pair.prefill, impl="reference")

    def admit(s):
        padded = np.zeros((1, max_len), np.int32)
        padded[0, :prompt_len] = prompts[s]
        return prefill(params, jnp.asarray(padded), state, s, prompt_len)

    # warmup: slot 0's first call pays the jit trace+compile; its cache
    # write is overwritten by the timed admission below
    out, state = admit(0)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for s in range(slots):
        out, state = admit(s)
    jax.block_until_ready(out)
    t_prefill = (time.perf_counter() - t0) / slots * 1e6
    decode = executor.jitted_decode_runner(pair.decode, impl="reference")
    t_prog = _time_threaded(decode, params, toks, state,
                            warmup=warmup, iters=iters)

    # legacy decode_step (scan over stacked blocks, rolling cache)
    cache = transformer.init_cache(cfg, slots, max_len)
    leg = jax.jit(functools.partial(
        lambda p, t, c, cfg: transformer.decode_step(p, c, t, cfg,
                                                     impl="reference"),
        cfg=cfg))
    t_leg = _time_threaded(lambda p, t, c: leg(p, t, c), params, toks,
                           cache, warmup=warmup, iters=iters)

    # retired path: recompute the full causal prefill every tick
    flat = transformer.compile_program(cfg, batch=slots, seq=max_len)
    flat_fn = executor.jitted_runner(flat, impl="reference")
    full = jnp.asarray(np.tile(prompts, (1, max_len // prompt_len)))
    t_rec = time_call(flat_fn, params, full, warmup=warmup, iters=iters)

    for occ in sorted({1, slots // 2, slots}):
        tag = f"{cfg.name}/s{slots}l{max_len}/occ{occ}"
        tps = occ / (t_prog * 1e-6)
        emit(f"program_lm/decode/{tag}/toks_per_s", t_prog,
             f"decode_program_tps={tps:.1f};"
             f"legacy_tps={occ / (t_leg * 1e-6):.1f};"
             f"recompute_tps={occ / (t_rec * 1e-6):.1f};"
             f"program_over_legacy={t_prog / max(t_leg, 1e-9):.3f};"
             f"speedup_vs_recompute={t_rec / max(t_prog, 1e-9):.2f}x")
    emit(f"program_lm/decode/{cfg.name}/prefill_once", t_prefill,
         f"per_admission_us={t_prefill:.1f};"
         f"persistent_kv_mb={pair.persistent_bytes / 1e6:.3f}")

    # Rolling-window region plan: the same pair compiled for a sliding
    # window holds min(max_len, W) KV rows per slot — max_len/W fewer
    # persistent bytes resident, at full decode-Program parity.  Track
    # the resident-bytes trajectory alongside the decode tokens/s.
    window = max(max_len // 4, 2)
    win_cfg = dataclasses.replace(cfg, name=cfg.name + "-win",
                                  attn_window=window)
    win_pair = transformer.compile_program_pair(win_cfg, slots=slots,
                                                max_len=max_len)
    win_state = executor.init_program_state(win_pair)
    win_prefill = executor.jitted_prefill_runner(win_pair.prefill,
                                                 impl="reference")
    for s in range(slots):
        padded = np.zeros((1, max_len), np.int32)
        padded[0, :prompt_len] = prompts[s]
        out, win_state = win_prefill(params, jnp.asarray(padded),
                                     win_state, s, prompt_len)
    jax.block_until_ready(out)
    win_decode = executor.jitted_decode_runner(win_pair.decode,
                                               impl="reference")
    t_win = _time_threaded(win_decode, params, toks, win_state,
                           warmup=warmup, iters=iters)
    emit(f"program_lm/decode/{cfg.name}/windowed_kv", t_win,
         f"window={window};"
         f"windowed_tps={slots / (t_win * 1e-6):.1f};"
         f"kv_resident_full_mb={pair.persistent_bytes / 1e6:.3f};"
         f"kv_resident_win_mb={win_pair.persistent_bytes / 1e6:.3f};"
         f"kv_shrink={pair.persistent_bytes / win_pair.persistent_bytes:.1f}x")


def run():
    cfg = REGISTRY["smollm-360m"].smoke()
    shapes = [(1, 32)] if SMOKE else [(2, 64), (4, 128)]
    if SMOKE:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-bench",
                                  n_layers=2)
    params = init_params(transformer.param_defs(cfg), jax.random.PRNGKey(0))
    for batch, seq in shapes:
        toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq),
                                  0, cfg.vocab)

        program = transformer.compile_program(cfg, batch=batch, seq=seq)
        prog_fn = executor.jitted_runner(program, impl="reference")
        legacy_fn = jax.jit(functools.partial(
            lambda p, t, cfg: transformer.forward(
                p, t, cfg, impl="reference")["logits"], cfg=cfg))

        err = float(jnp.abs(prog_fn(params, toks)
                            - legacy_fn(params, toks)).max())
        warmup, iters = (1, 3) if SMOKE else (2, 7)
        t_prog = time_call(prog_fn, params, toks, warmup=warmup, iters=iters)
        t_leg = time_call(legacy_fn, params, toks, warmup=warmup,
                          iters=iters)

        graph = transformer.to_graph(cfg, batch=batch, seq=seq)
        unfused = graph.total_min_bytes()
        tag = f"{cfg.name}/b{batch}s{seq}"
        emit(f"program_lm/{tag}/wallclock", t_prog,
             f"legacy_us={t_leg:.2f};"
             f"program_over_legacy={t_prog / max(t_leg, 1e-9):.3f};"
             f"err={err:.2e}")
        emit(f"program_lm/{tag}/traffic", 0.0,
             f"program_mb={program.total_traffic_bytes / 1e6:.2f};"
             f"unfused_min_mb={unfused / 1e6:.2f};"
             f"ops={len(program.ops)};"
             f"regions={len(program.plan.regions)};"
             f"region_mb={program.plan.total_bytes / 1e6:.3f}")
    run_decode_bench()


if __name__ == "__main__":
    run()

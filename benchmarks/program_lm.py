"""LM Program-execution benchmark: the compiled transformer Program vs
the legacy scan forward, plus the decode regime.

For a dense-LM config this measures

  * wallclock of ``runtime/executor.py`` running the compiled Program
    (resolved matmul blocks, flash-attention tiles, residual adds fused
    into the projection writebacks) vs the legacy ``jax.lax.scan``
    forward — both jitted, both on the reference kernels so the
    comparison is schedule-vs-schedule, not Mosaic-vs-interpreter;
  * the schedule's modeled traffic for the Program vs the graph's
    unfused per-op minimum-bytes sum;
  * **decode**: serving tokens/s at slot occupancies 1 / half / full
    for the stateful decode Program (persistent KV regions +
    ProgramState, the serving engine's hot loop) vs the legacy
    ``decode_step`` scan vs the retired per-tick prefill-recompute
    path (the pre-stateful program engine: one full causal forward at
    (slots, max_len) per emitted token);

and checks the paths agree numerically (the PR-3 parity bound).

Smoke mode shrinks depth/shape so CI stays fast; the full run uses the
smollm-360m smoke config at serving-like shapes.
"""
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import REGISTRY
from repro.models import get_model, init_params, transformer
from repro.runtime import executor

from .common import emit, set_metrics_snapshot, time_call

SMOKE = False          # set by benchmarks.run --smoke


def _time_threaded(fn, params, toks, carry, *, warmup=1, iters=3):
    """Median wall time (us) of a (params, toks, carry) -> (out, carry)
    step whose carry is threaded (and possibly donated) through calls."""
    for _ in range(warmup):
        out, carry = fn(params, toks, carry)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out, carry = fn(params, toks, carry)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_decode_bench():
    """Decode-regime tokens/s: decode-Program vs legacy decode_step vs
    the retired prefill-recompute engine path."""
    cfg = REGISTRY["smollm-360m"].smoke()
    slots, max_len, warmup, iters = (2, 16, 1, 3) if SMOKE else (8, 64, 2, 7)
    if SMOKE:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-bench", n_layers=2)
    params = init_params(transformer.param_defs(cfg), jax.random.PRNGKey(0))
    prompt_len = max_len // 2
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(slots, prompt_len)).astype(np.int32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(slots,)), jnp.int32)

    # stateful decode Program: prefill every slot once, tick the pair
    pair = transformer.compile_program_pair(cfg, slots=slots,
                                            max_len=max_len)
    state = executor.init_program_state(pair)
    prefill = executor.jitted_prefill_runner(pair.prefill, impl="reference")

    def admit(s):
        padded = np.zeros((1, max_len), np.int32)
        padded[0, :prompt_len] = prompts[s]
        return prefill(params, jnp.asarray(padded), state, s, prompt_len)

    # warmup: slot 0's first call pays the jit trace+compile; its cache
    # write is overwritten by the timed admission below
    out, state = admit(0)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for s in range(slots):
        out, state = admit(s)
    jax.block_until_ready(out)
    t_prefill = (time.perf_counter() - t0) / slots * 1e6
    decode = executor.jitted_decode_runner(pair.decode, impl="reference")
    t_prog = _time_threaded(decode, params, toks, state,
                            warmup=warmup, iters=iters)

    # legacy decode_step (scan over stacked blocks, rolling cache)
    cache = transformer.init_cache(cfg, slots, max_len)
    leg = jax.jit(functools.partial(
        lambda p, t, c, cfg: transformer.decode_step(p, c, t, cfg,
                                                     impl="reference"),
        cfg=cfg))
    t_leg = _time_threaded(lambda p, t, c: leg(p, t, c), params, toks,
                           cache, warmup=warmup, iters=iters)

    # retired path: recompute the full causal prefill every tick
    flat = transformer.compile_program(cfg, batch=slots, seq=max_len)
    flat_fn = executor.jitted_runner(flat, impl="reference")
    full = jnp.asarray(np.tile(prompts, (1, max_len // prompt_len)))
    t_rec = time_call(flat_fn, params, full, warmup=warmup, iters=iters)

    for occ in sorted({1, slots // 2, slots}):
        tag = f"{cfg.name}/s{slots}l{max_len}/occ{occ}"
        tps = occ / (t_prog * 1e-6)
        emit(f"program_lm/decode/{tag}/toks_per_s", t_prog,
             f"decode_program_tps={tps:.1f};"
             f"legacy_tps={occ / (t_leg * 1e-6):.1f};"
             f"recompute_tps={occ / (t_rec * 1e-6):.1f};"
             f"program_over_legacy={t_prog / max(t_leg, 1e-9):.3f};"
             f"speedup_vs_recompute={t_rec / max(t_prog, 1e-9):.2f}x")
    emit(f"program_lm/decode/{cfg.name}/prefill_once", t_prefill,
         f"per_admission_us={t_prefill:.1f};"
         f"persistent_kv_mb={pair.persistent_bytes / 1e6:.3f}")

    # Rolling-window region plan: the same pair compiled for a sliding
    # window holds min(max_len, W) KV rows per slot — max_len/W fewer
    # persistent bytes resident, at full decode-Program parity.  Track
    # the resident-bytes trajectory alongside the decode tokens/s.
    window = max(max_len // 4, 2)
    win_cfg = dataclasses.replace(cfg, name=cfg.name + "-win",
                                  attn_window=window)
    win_pair = transformer.compile_program_pair(win_cfg, slots=slots,
                                                max_len=max_len)
    win_state = executor.init_program_state(win_pair)
    win_prefill = executor.jitted_prefill_runner(win_pair.prefill,
                                                 impl="reference")
    for s in range(slots):
        padded = np.zeros((1, max_len), np.int32)
        padded[0, :prompt_len] = prompts[s]
        out, win_state = win_prefill(params, jnp.asarray(padded),
                                     win_state, s, prompt_len)
    jax.block_until_ready(out)
    win_decode = executor.jitted_decode_runner(win_pair.decode,
                                               impl="reference")
    t_win = _time_threaded(win_decode, params, toks, win_state,
                           warmup=warmup, iters=iters)
    emit(f"program_lm/decode/{cfg.name}/windowed_kv", t_win,
         f"window={window};"
         f"windowed_tps={slots / (t_win * 1e-6):.1f};"
         f"kv_resident_full_mb={pair.persistent_bytes / 1e6:.3f};"
         f"kv_resident_win_mb={win_pair.persistent_bytes / 1e6:.3f};"
         f"kv_shrink={pair.persistent_bytes / win_pair.persistent_bytes:.1f}x")
    run_paged_bench(cfg, params, pair, win_pair, slots, max_len,
                    prompt_len, prompts, toks, t_prog, warmup, iters)


def run_family_decode_bench():
    """Non-dense family decode rows: the generic named-state Program
    (SSM scan / wkv recurrence state minted through the
    ``regions.state_specs`` hook) vs each family's legacy
    ``decode_step`` cache loop, at full slot occupancy."""
    slots, max_len, warmup, iters = (2, 16, 1, 3) if SMOKE else (8, 64, 2, 7)
    prompt_len = max_len // 2
    for name in ("mamba2", "rwkv6-7b"):
        cfg = REGISTRY[name].smoke()
        api = get_model(cfg)
        params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab,
                               size=(slots, prompt_len)).astype(np.int32)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(slots,)),
                           jnp.int32)

        pair = transformer.compile_program_pair(cfg, slots=slots,
                                                max_len=max_len)
        state = executor.init_program_state(pair)
        prefill = executor.jitted_prefill_runner(pair.prefill,
                                                 impl="reference")
        for s in range(slots):
            padded = np.zeros((1, max_len), np.int32)
            padded[0, :prompt_len] = prompts[s]
            out, state = prefill(params, jnp.asarray(padded), state, s,
                                 prompt_len)
        jax.block_until_ready(out)
        decode = executor.jitted_decode_runner(pair.decode,
                                               impl="reference")
        t_prog = _time_threaded(decode, params, toks, state,
                                warmup=warmup, iters=iters)

        # legacy: the family's rolling-cache decode_step, prompt
        # teacher-forced in so both sides tick from the same position
        cache = api.init_cache(cfg, slots, max_len)
        leg = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg,
                                                      impl="reference"))
        for t in range(prompt_len):
            _, cache = leg(params, cache, jnp.asarray(prompts[:, t]))
        t_leg = _time_threaded(lambda p, t, c: leg(p, c, t), params,
                               toks, cache, warmup=warmup, iters=iters)

        tps = slots / (t_prog * 1e-6)
        emit(f"program_lm/decode/{cfg.name}/family_decode", t_prog,
             f"family={cfg.family};"
             f"program_tps={tps:.1f};"
             f"legacy_tps={slots / (t_leg * 1e-6):.1f};"
             f"program_over_legacy={t_prog / max(t_leg, 1e-9):.3f};"
             f"persistent_state_mb={pair.persistent_bytes / 1e6:.3f}")


def run_paged_bench(cfg, params, pair, win_pair, slots, max_len,
                    prompt_len, prompts, toks, t_contig, warmup, iters):
    """Paged-KV region plan rows: concurrent sequences at a fixed HBM
    budget (prefix sharing), shared-prefix admission cost, int8
    resident-page bytes, and single-tick decode latency vs the
    contiguous plan."""
    from repro.core.regions import paged_kv_specs, pages_for_len

    page_size = max(max_len // 4, 2)

    # -- concurrent sequences at a fixed KV HBM budget ------------------------
    # Budget = what `slots` contiguous slots occupy.  The contiguous and
    # windowed plans admit a fixed sequence count regardless of content;
    # the paged plan shares the prompts' common full-page prefix, so the
    # same pool bytes admit every sequence whose *private* pages fit.
    pps = max_len // page_size
    _, plan = paged_kv_specs(
        n_layers=cfg.n_layers, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        slots=64, max_len=max_len, page_size=page_size,
        n_pages=1 + slots * pps)              # same rows as `slots` slots
    pool = executor.PagePool(plan, slots=64)
    donor = tuple(int(t) for t in prompts[0])
    seqs = [tuple(int(t) for t in prompts[0][:-1]) + (int(s),)
            for s in range(64)]               # shared prefix, unique tail
    admitted = 0
    shared = ()
    for s, seq in enumerate(seqs):
        if admitted:
            shared = pool.shared_prefix_pages(0, donor, seq)
        if not pool.can_admit(len(seq), len(shared)):
            break
        pool.admit(s, len(seq), shared)
        admitted += 1
    win_rows = min(max_len, max(max_len // 4, 2))
    emit(f"program_lm/decode/{cfg.name}/paged_kv/concurrency", 0.0,
         f"page_size={page_size};pool_pages={plan.n_pages - 1};"
         f"contig_seqs={slots};"
         f"windowed_seqs={slots};windowed_rows_per_seq={win_rows};"
         f"paged_seqs={admitted};"
         f"paged_over_contig={admitted / slots:.1f}x;"
         f"shared_pages={int((pool.refcount > 1).sum())}")

    # -- shared-prefix admission cost vs a full prefill -----------------------
    paged_pair = transformer.compile_program_pair(
        cfg, slots=slots, max_len=max_len, paged=True, page_size=page_size)
    pstate = executor.init_program_state(paged_pair)
    ppool = executor.PagePool(paged_pair.paged, slots)
    pprefill = executor.jitted_prefill_runner(paged_pair.prefill,
                                              impl="reference")
    padded = np.zeros((1, max_len), np.int32)
    padded[0, :prompt_len] = prompts[0]
    ptoks = jnp.asarray(padded)

    def admit(slot, shared):
        nonlocal pstate
        ppool.release(slot)
        wf = ppool.admit(slot, prompt_len, shared)
        executor.sync_page_table(pstate, paged_pair, ppool)
        out, pstate = pprefill(params, ptoks, pstate, slot, prompt_len, wf)
        return out

    jax.block_until_ready(admit(0, ()))       # donor + jit warmup
    donor_pages = ppool.slot_pages(0, (prompt_len // page_size) * page_size)
    t_full = t_shared = 0.0
    for kind, shared in (("full", ()), ("shared", donor_pages)):
        times = []
        for _ in range(warmup + iters):
            t0 = time.perf_counter()
            jax.block_until_ready(admit(1, shared))
            times.append(time.perf_counter() - t0)
        times = sorted(times[warmup:])
        t = times[len(times) // 2] * 1e6
        if kind == "full":
            t_full = t
        else:
            t_shared = t
    emit(f"program_lm/decode/{cfg.name}/paged_kv/admission", t_shared,
         f"full_prefill_us={t_full:.1f};shared_prefix_us={t_shared:.1f};"
         f"shared_pages={len(donor_pages)};"
         f"rows_not_written={len(donor_pages) * page_size}/{prompt_len}")

    # -- int8 pages: resident KV bytes ----------------------------------------
    int8_pair = transformer.compile_program_pair(
        cfg, slots=slots, max_len=max_len, paged=True,
        page_size=page_size, kv_quant="int8")
    emit(f"program_lm/decode/{cfg.name}/paged_kv/int8_resident", 0.0,
         f"paged_fp_mb={paged_pair.persistent_bytes / 1e6:.3f};"
         f"paged_int8_mb={int8_pair.persistent_bytes / 1e6:.3f};"
         f"bytes_cut={paged_pair.persistent_bytes / int8_pair.persistent_bytes:.1f}x")

    # -- decode tick vs the contiguous plan -----------------------------------
    # Host page decisions + table sync ride inside the timed step, as
    # they do in the serving engine's hot loop.
    for s in range(1, slots):
        jax.block_until_ready(admit(s, ()))
    pdecode = executor.jitted_decode_runner(paged_pair.decode,
                                            impl="reference")
    lens = [prompt_len] * slots

    def paged_tick(p, t, st):
        copies = []
        for s in range(slots):
            c = ppool.prepare_decode(s, lens[s])
            if c is not None:
                copies.append(c)
        executor.sync_page_table(st, paged_pair, ppool)
        executor.apply_page_copies(st, paged_pair, copies)
        out, st = pdecode(p, t, st)
        for s in range(slots):
            lens[s] += 1
        return out, st

    t_paged = _time_threaded(paged_tick, params, toks, pstate,
                             warmup=warmup, iters=iters)
    emit(f"program_lm/decode/{cfg.name}/paged_kv/tick", t_paged,
         f"paged_tps={slots / (t_paged * 1e-6):.1f};"
         f"contig_tps={slots / (t_contig * 1e-6):.1f};"
         f"paged_over_contig={t_paged / max(t_contig, 1e-9):.3f}")


def run_serving_bench():
    """Offered-load serving sweep: tokens/s and p50/p99 per-tick wall
    latency of the engine loop, whole-prefill vs chunked admission.

    The scenario is the one chunking exists for: a steady stream of
    short prompts decoding, then a burst of ``slots`` long prompts
    (4x max_len) landing mid-stream.  Whole-prefill admission runs one
    full prefill per burst arrival inside a single tick — that tick is
    the p99.  Chunked admission batches every in-flight prefill into
    one chunk call per tick, so the burst amortizes across ticks and
    the tail collapses while steady-state tokens/s holds."""
    from repro.serving import Request, ServingEngine

    cfg = REGISTRY["smollm-360m"].smoke()
    slots, max_len, loads = ((16, 16, (1,)) if SMOKE
                             else (16, 64, (1, 4)))
    cfg = dataclasses.replace(cfg, name=cfg.name + "-serve", n_layers=2)
    chunk = max_len // 2
    burst = slots - 4                           # lands on free slots
    params = init_params(transformer.param_defs(cfg),
                         jax.random.PRNGKey(0))

    # Per-tick latency lands on an obs.Histogram — the same fixed-
    # bucket type the serving engine's tick_ms metric uses — instead of
    # a private sample list + np.percentile.  Fine geometric buckets
    # (factor 1.05) keep the interpolated percentile within ~5% of the
    # exact sample percentile, tight enough for the p99_gain ratio.
    tick_buckets = obs.exp_buckets(1e-6, 30.0, factor=1.05)

    def drive(chunk_size, load):
        """Run the scenario; per-tick latency histogram + tokens."""
        eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                            use_program=True, impl="reference",
                            chunk_size=chunk_size)
        rng = np.random.default_rng(0)
        uid = 0
        h = obs.Histogram(tick_buckets)

        def submit(n_tokens):
            nonlocal uid
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(
                                   0, cfg.vocab,
                                   size=n_tokens).astype(np.int32),
                               max_new_tokens=6))
            uid += 1
        done, tick = [], 0
        while True:
            if tick % 3 == 0 and tick <= 12:
                for _ in range(load):
                    submit(int(rng.integers(2, 7)))
            if tick == 4:                       # mid-stream burst
                for _ in range(burst):
                    submit(4 * max_len)
            t0 = time.perf_counter()
            done += eng.step()
            h.observe(time.perf_counter() - t0)
            tick += 1
            if tick > 12 and not (eng.live or eng.admission
                                  or eng._prefilling):
                break
            assert tick < 600
        assert eng.n_starved_ticks == 0
        tokens = sum(len(r.out_tokens) for r in done)
        return h, tokens, eng

    eng = None
    for load in loads:
        drive(chunk, load)                      # jit warm (both paths
        drive(None, load)                       # + all chunk widths)
        hw, nw, _ = drive(None, load)
        hc, nc, eng = drive(chunk, load)
        tps_w, tps_c = nw / hw.sum, nc / hc.sum
        p50w, p99w = hw.percentile(50) * 1e6, hw.percentile(99) * 1e6
        p50c, p99c = hc.percentile(50) * 1e6, hc.percentile(99) * 1e6
        emit(f"program_lm/serving/{cfg.name}/load{load}/whole_prefill",
             p99w, f"tps={tps_w:.1f};p50_us={p50w:.0f};p99_us={p99w:.0f}")
        emit(f"program_lm/serving/{cfg.name}/load{load}/chunk{chunk}",
             p99c, f"tps={tps_c:.1f};p50_us={p50c:.0f};p99_us={p99c:.0f};"
             f"p99_gain={p99w / max(p99c, 1e-9):.2f}x;"
             f"tps_ratio={tps_c / max(tps_w, 1e-9):.2f}")
    if eng is not None:
        # The last driven engine's registry snapshot rides along in the
        # --json sidecar (TTFT/ITL/tick histograms + serving counters).
        set_metrics_snapshot(eng.obs.registry.snapshot())


def run():
    cfg = REGISTRY["smollm-360m"].smoke()
    shapes = [(1, 32)] if SMOKE else [(2, 64), (4, 128)]
    if SMOKE:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-bench",
                                  n_layers=2)
    params = init_params(transformer.param_defs(cfg), jax.random.PRNGKey(0))
    for batch, seq in shapes:
        toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq),
                                  0, cfg.vocab)

        program = transformer.compile_program(cfg, batch=batch, seq=seq)
        prog_fn = executor.jitted_runner(program, impl="reference")
        legacy_fn = jax.jit(functools.partial(
            lambda p, t, cfg: transformer.forward(
                p, t, cfg, impl="reference")["logits"], cfg=cfg))

        err = float(jnp.abs(prog_fn(params, toks)
                            - legacy_fn(params, toks)).max())
        warmup, iters = (1, 3) if SMOKE else (2, 7)
        t_prog = time_call(prog_fn, params, toks, warmup=warmup, iters=iters)
        t_leg = time_call(legacy_fn, params, toks, warmup=warmup,
                          iters=iters)

        graph = transformer.to_graph(cfg, batch=batch, seq=seq)
        unfused = graph.total_min_bytes()
        tag = f"{cfg.name}/b{batch}s{seq}"
        emit(f"program_lm/{tag}/wallclock", t_prog,
             f"legacy_us={t_leg:.2f};"
             f"program_over_legacy={t_prog / max(t_leg, 1e-9):.3f};"
             f"err={err:.2e}")
        emit(f"program_lm/{tag}/traffic", 0.0,
             f"program_mb={program.total_traffic_bytes / 1e6:.2f};"
             f"unfused_min_mb={unfused / 1e6:.2f};"
             f"ops={len(program.ops)};"
             f"regions={len(program.plan.regions)};"
             f"region_mb={program.plan.total_bytes / 1e6:.3f}")
    run_decode_bench()
    run_family_decode_bench()
    run_serving_bench()


if __name__ == "__main__":
    run()

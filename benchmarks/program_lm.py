"""LM Program-execution benchmark: the compiled transformer Program vs
the legacy scan forward.

For a dense-LM config this measures

  * wallclock of ``runtime/executor.py`` running the compiled Program
    (resolved matmul blocks, flash-attention tiles, residual adds fused
    into the projection writebacks) vs the legacy ``jax.lax.scan``
    forward — both jitted, both on the reference kernels so the
    comparison is schedule-vs-schedule, not Mosaic-vs-interpreter;
  * the schedule's modeled traffic for the Program vs the graph's
    unfused per-op minimum-bytes sum;

and checks the two paths agree numerically (the PR-3 parity bound).

Smoke mode shrinks depth/shape so CI stays fast; the full run uses the
smollm-360m smoke config at serving-like shapes.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.models import init_params, transformer
from repro.runtime import executor

from .common import emit, time_call

SMOKE = False          # set by benchmarks.run --smoke


def run():
    cfg = REGISTRY["smollm-360m"].smoke()
    shapes = [(1, 32)] if SMOKE else [(2, 64), (4, 128)]
    if SMOKE:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-bench",
                                  n_layers=2)
    params = init_params(transformer.param_defs(cfg), jax.random.PRNGKey(0))
    for batch, seq in shapes:
        toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq),
                                  0, cfg.vocab)

        program = transformer.compile_program(cfg, batch=batch, seq=seq)
        prog_fn = executor.jitted_runner(program, impl="reference")
        legacy_fn = jax.jit(functools.partial(
            lambda p, t, cfg: transformer.forward(
                p, t, cfg, impl="reference")["logits"], cfg=cfg))

        err = float(jnp.abs(prog_fn(params, toks)
                            - legacy_fn(params, toks)).max())
        warmup, iters = (1, 3) if SMOKE else (2, 7)
        t_prog = time_call(prog_fn, params, toks, warmup=warmup, iters=iters)
        t_leg = time_call(legacy_fn, params, toks, warmup=warmup,
                          iters=iters)

        graph = transformer.to_graph(cfg, batch=batch, seq=seq)
        unfused = graph.total_min_bytes()
        tag = f"{cfg.name}/b{batch}s{seq}"
        emit(f"program_lm/{tag}/wallclock", t_prog,
             f"legacy_us={t_leg:.2f};"
             f"program_over_legacy={t_prog / max(t_leg, 1e-9):.3f};"
             f"err={err:.2e}")
        emit(f"program_lm/{tag}/traffic", 0.0,
             f"program_mb={program.total_traffic_bytes / 1e6:.2f};"
             f"unfused_min_mb={unfused / 1e6:.2f};"
             f"ops={len(program.ops)};"
             f"regions={len(program.plan.regions)};"
             f"region_mb={program.plan.total_bytes / 1e6:.3f}")


if __name__ == "__main__":
    run()

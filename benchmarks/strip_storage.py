"""Strip-storage benchmark: materialized (HBM halo duplication, the
Snowflake scheme) vs virtual (zero-copy in-kernel gather) row strips.

For the Fig. 4 layer set this measures
  * modeled HBM traffic under both loop orders for both storage
    schemes, on the paper's strip geometry (Snowflake tiling, where
    layers genuinely split into several strips) — the virtual path
    must drop exactly the ``overlap_frac`` maps duplication from Kloop
    and ``n_kernel_tiles * overlap_frac`` from Mloop; 1x1 layers have
    no halo, so both schemes coincide there by construction;
  * interpret-mode wallclock of the real Pallas kernels at equal
    numerics (both paths allclose to ``conv2d_ref``), on
    channel-scaled shapes under the default TPU schedule — the
    difference measured is the materialization round trip the
    zero-copy path deletes.  (Interpret mode re-copies each resident
    block every grid step, so a constrained multi-strip schedule would
    mis-charge the virtual path for VMEM residency that is free on
    hardware; the default schedule avoids that artifact.)

Emits ``strips/<layer>/model`` rows (bytes; duplication eliminated)
and ``strips/<layer>/wallclock`` rows (us; virtual/materialized ratio
and max |err| vs the oracle).
"""
import functools

import jax
import jax.numpy as jnp

from repro.core import SNOWFLAKE
from repro.core.dataflow import conv_strip_traffic, materialization_roundtrip
from repro.core.tiling import select_conv_row_strips
from repro.kernels import conv2d, conv2d_ref

from .common import emit, time_call

# Fig. 4 layer set: (label, H, W, k, C_in, C_out, stride, pad).
LAYERS = [
    ("A_alexnet_conv2", 27, 27, 5, 64, 192, 1, 2),
    ("B_alexnet_conv4", 13, 13, 3, 384, 256, 1, 1),
    ("C_resnet_3x3_128", 28, 28, 3, 128, 128, 1, 1),
    ("D_resnet_3x3_256", 14, 14, 3, 256, 256, 1, 1),
    ("E_resnet_1x1_512", 7, 7, 1, 512, 2048, 1, 0),
    ("F_resnet_3x3_512", 7, 7, 3, 512, 512, 1, 1),
    ("G_resnet50_1x1_1024", 14, 14, 1, 1024, 2048, 2, 0),
    ("H_resnet50_1x1_2048", 7, 7, 1, 2048, 512, 1, 0),
]

SMOKE = False          # set by benchmarks.run --smoke
_CH_CAP = 48           # channel cap for interpret-mode wallclock runs


def _modeled(H, W, k, cin, cout, s, p, dtype_bytes=2):
    """Paper-geometry (Snowflake tiling) traffic for both storages."""
    ct = select_conv_row_strips(H, W, cin, cout, k, k, s, p,
                                dtype_bytes, SNOWFLAKE)
    oh = (H + 2 * p - k) // s + 1
    ow = (W + 2 * p - k) // s + 1
    maps = H * W * cin * dtype_bytes
    weights = cin * k * k * cout * dtype_bytes
    out = oh * ow * cout * dtype_bytes
    res = {
        storage: conv_strip_traffic(
            maps, weights, out, n_map_tiles=ct.n_map_tiles,
            n_kernel_tiles=ct.n_kernel_tiles,
            overlap_frac=ct.overlap_frac, strip_storage=storage)
        for storage in ("materialized", "virtual")
    }
    return ct, maps, res


def _wallclock(label, H, W, k, cin, cout, s, p):
    cin, cout = min(cin, _CH_CAP), min(cout, _CH_CAP)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (2, H, W, cin), jnp.float32)
    w = jax.random.normal(ks[1], (k, k, cin, cout), jnp.float32) * 0.1
    b = jax.random.normal(ks[2], (cout,), jnp.float32) * 0.1
    ref = conv2d_ref(x, w, stride=s, pad=p, bias=b, activation="relu")

    times, errs = {}, {}
    for storage in ("materialized", "virtual"):
        fn = jax.jit(functools.partial(
            conv2d, stride=s, pad=p, bias=b, activation="relu",
            impl="pallas", interpret=True, strip_storage=storage))
        out = fn(x, w)
        errs[storage] = float(jnp.abs(out - ref).max())
        warmup, iters = (1, 3) if SMOKE else (2, 7)
        times[storage] = time_call(fn, x, w, warmup=warmup, iters=iters)
    return times, errs


def run():
    eliminated_all = True
    for (label, H, W, k, cin, cout, s, p) in LAYERS:
        ct, maps, modeled = _modeled(H, W, k, cin, cout, s, p)
        k_mat, m_mat = modeled["materialized"]
        k_virt, m_virt = modeled["virtual"]
        # The virtual path deletes the duplicated-overlap bytes from each
        # loop order AND the materialization round trip (read maps +
        # write the augmented copy) the schedule model now charges;
        # zero-overlap (1x1) layers need no augmentation, so both terms
        # vanish there and the schemes coincide.
        roundtrip = materialization_roundtrip(maps, ct.overlap_frac)
        ok = (abs((k_mat - k_virt) - (ct.overlap_frac * maps + roundtrip))
              < 1.0
              and abs((m_mat - m_virt)
                      - (ct.n_kernel_tiles * ct.overlap_frac * maps
                         + roundtrip)) < 1.0)
        eliminated_all &= ok
        emit(f"strips/{label}/model", 0.0,
             f"kloop_mat_mb={k_mat/1e6:.3f};kloop_virt_mb={k_virt/1e6:.3f};"
             f"mloop_mat_mb={m_mat/1e6:.3f};mloop_virt_mb={m_virt/1e6:.3f};"
             f"overlap_frac={ct.overlap_frac:.3f};"
             f"roundtrip_mb={roundtrip/1e6:.3f};"
             f"n_strips={ct.n_map_tiles};ok={ok}")

    wl_layers = LAYERS[:2] if SMOKE else LAYERS
    tot = {"materialized": 0.0, "virtual": 0.0}
    for (label, H, W, k, cin, cout, s, p) in wl_layers:
        times, errs = _wallclock(label, H, W, k, cin, cout, s, p)
        ratio = times["virtual"] / max(times["materialized"], 1e-9)
        for kk in tot:
            tot[kk] += times[kk]
        emit(f"strips/{label}/wallclock", times["virtual"],
             f"materialized_us={times['materialized']:.2f};"
             f"virtual_over_materialized={ratio:.3f};"
             f"err_virtual={errs['virtual']:.2e};"
             f"err_materialized={errs['materialized']:.2e}")
    emit("strips/wallclock_total", tot["virtual"],
         f"materialized_us={tot['materialized']:.2f};"
         f"virtual_over_materialized="
         f"{tot['virtual'] / max(tot['materialized'], 1e-9):.3f}")
    emit("strips/duplication_eliminated_all_layers",
         float(eliminated_all),
         "virtual strips drop (1+overlap) term + materialization roundtrip")


if __name__ == "__main__":
    run()

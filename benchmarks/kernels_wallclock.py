"""Wall-clock sanity of the Pallas kernels (interpret mode, reduced
shapes) against their jnp references — structural overhead check, not a
TPU measurement (this container is CPU-only)."""
import jax
import jax.numpy as jnp

from repro.core.dataflow import Dataflow
from repro.kernels import (flash_attention, flash_ref, matmul, matmul_ref,
                           mamba2_scan, wkv6)
from .common import emit, time_call

K0 = jax.random.PRNGKey(0)


def run():
    ks = jax.random.split(K0, 6)
    a = jax.random.normal(ks[0], (512, 512), jnp.float32)
    b = jax.random.normal(ks[1], (512, 512), jnp.float32)
    for df in Dataflow:
        f = jax.jit(lambda a, b, df=df: matmul(
            a, b, impl="pallas", dataflow=df, block=(128, 128, 128),
            interpret=True))
        us = time_call(f, a, b)
        emit(f"kernel/matmul512/{df.value}", us, "interpret")
    f = jax.jit(lambda a, b: matmul_ref(a, b))
    emit("kernel/matmul512/xla_ref", time_call(f, a, b), "")

    q = jax.random.normal(ks[2], (1, 4, 512, 64), jnp.float32)
    f = jax.jit(lambda q: flash_attention(q, q, q, causal=True,
                                          impl="pallas", block_q=128,
                                          block_kv=128, interpret=True))
    emit("kernel/flash512/pallas", time_call(f, q), "interpret")
    f = jax.jit(lambda q: flash_ref(q, q, q, causal=True, chunk=128))
    emit("kernel/flash512/ref", time_call(f, q), "")

    x = jax.random.normal(ks[3], (1, 512, 4, 32)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, 512, 4))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[5], (4,)))
    B = jax.random.normal(ks[0], (1, 512, 16)) * 0.3
    f = jax.jit(lambda x, dt, B: mamba2_scan(
        x, dt, A, B, B, impl="pallas", chunk=128, interpret=True))
    emit("kernel/mamba512/pallas", time_call(f, x, dt, B), "interpret")
    f = jax.jit(lambda x, dt, B: mamba2_scan(x, dt, A, B, B,
                                             impl="reference"))
    emit("kernel/mamba512/ref_scan", time_call(f, x, dt, B), "")


if __name__ == "__main__":
    run()

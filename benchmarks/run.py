"""Benchmark suite: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Run as
``PYTHONPATH=src python -m benchmarks.run [--only table1]``.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: modeled suites + shortened "
                         "wallclock runs (CPU interpret mode)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the emitted rows as a JSON array "
                         "(machine-readable sidecar to the CSV stream)")
    args = ap.parse_args()

    from . import (fig4_loop_rearrangement, kernels_wallclock,
                   program_exec, program_lm, quant_profile, strip_storage,
                   table1_auto_vs_hand, table2_models, table3_load_balance)
    suites = [
        ("table1", table1_auto_vs_hand),
        ("table2", table2_models),
        ("fig4", fig4_loop_rearrangement),
        ("table3", table3_load_balance),
        ("strips", strip_storage),
        ("program", program_exec),
        ("program_lm", program_lm),
        ("quant", quant_profile),
        ("kernels", kernels_wallclock),
    ]
    if args.smoke:
        strip_storage.SMOKE = True
        program_exec.SMOKE = True
        program_lm.SMOKE = True
        # drop the wallclock-heavy suites; keep every modeled one
        suites = [s for s in suites if s[0] not in ("kernels", "quant")]
    print("name,us_per_call,derived")
    for name, mod in suites:
        if args.only and args.only not in name:
            continue
        try:
            mod.run()
        except Exception as e:   # keep the suite going; record the failure
            from .common import emit
            emit(f"{name}/ERROR", 0.0, f"{type(e).__name__}:{e}")
            import traceback
            traceback.print_exc(file=sys.stderr)
    if args.json:
        from .common import write_json
        write_json(args.json)


if __name__ == "__main__":
    main()

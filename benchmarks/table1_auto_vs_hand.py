"""Table 1 reproduction: compiler-generated vs hand-optimized schedules.

The paper compares auto-generated instruction streams against
hand-written assembly on four AlexNet conv layers and finds them within
~0.5%.  Our analogue, on the same four layers and the Snowflake analytic
timing model:

  * AUTO — the schedule compiler's conv scheduling (row strips +
    Mloop/Kloop + stall model), exactly what compile_model emits;
  * HAND — exhaustive search over every feasible (out_rows,
    kernels_per_tile, loop order) triple under the same per-CU buffer
    constraints — the "patient engineer" oracle.

Paper values (ms): 3.256/3.261, 1.627/1.624, 2.188/2.187, 1.462/1.458.
"""
from repro.core import SNOWFLAKE, conv_node
from repro.core.schedule import _schedule_conv
from .common import emit

LAYERS = [
    ("alexnet_conv2", 27, 27, 5, 64, 192, 1, 2, 3.256, 3.261),
    ("alexnet_conv3", 13, 13, 3, 192, 384, 1, 1, 1.627, 1.624),
    ("alexnet_conv4", 13, 13, 3, 384, 256, 1, 1, 2.188, 2.187),
    ("alexnet_conv5", 13, 13, 3, 256, 256, 1, 1, 1.462, 1.458),
]


def _hand_best(node) -> float:
    """Exhaustive schedule search under the same hardware constraints."""
    hw = SNOWFLAKE
    d = node.dims
    H, W, cin, cout = d["H"], d["W"], d["C_in"], d["C_out"]
    kh, kw, s, p = d["kh"], d["kw"], d["stride"], d["pad"]
    oh = (H + 2 * p - kh) // s + 1
    flops = node.flops()
    maps_b = H * W * cin * 2
    ker_b = cin * kh * kw * cout * 2
    out_b = oh * oh * cout * 2
    mcap = hw.maps_buffer_bytes
    wcap = hw.weights_buffer_bytes
    best = float("inf")
    import math
    for out_rows in range(1, oh + 1):
        in_rows = min(H, (out_rows - 1) * s + kh)
        if in_rows * W * cin * 2 * 2 > mcap:
            break
        max_kpt = min(cout, wcap // (cin * kh * kw * 2 * 2))
        if max_kpt < 1:
            break
        for kpt in range(1, max_kpt + 1):
            n_map = math.ceil(oh / out_rows)
            n_ker = math.ceil(cout / kpt)
            halo = max(0, in_rows - out_rows * s)
            ov = 1 + (halo * (n_map - 1)) / max(H, 1)
            for traffic in (maps_b * ov + n_map * ker_b + out_b,     # kloop
                            n_ker * maps_b * ov + ker_b + out_b):    # mloop
                best = min(best, hw.exec_time(flops, traffic))
    return best


def run():
    total_gap = 0.0
    for (name, H, W, k, cin, cout, s, p, hand_ms, auto_ms) in LAYERS:
        node = conv_node(name, H, W, cin, cout, k, k, stride=s, pad=p,
                         batch=1)
        sched = _schedule_conv(node, SNOWFLAKE, paper_faithful=True)
        t_auto = sched.exec_time_s * 1e3
        t_hand = _hand_best(node) * 1e3
        gap = (t_auto - t_hand) / t_hand * 100
        total_gap += abs(gap)
        emit(f"table1/{name}/auto", t_auto * 1e3,
             f"model_ms={t_auto:.3f};paper_ms={auto_ms}")
        emit(f"table1/{name}/hand", t_hand * 1e3,
             f"model_ms={t_hand:.3f};paper_ms={hand_ms};gap_pct={gap:.2f}")
    emit("table1/mean_abs_gap_pct", total_gap / len(LAYERS),
         "paper_gap_pct<=0.5")


def _layer_config(name, H, cin, cout, k, s, p):
    from repro.configs.base import CNNConfig, CNNLayer
    return CNNConfig(name=f"table1m-{name}", input_hw=H, input_ch=cin,
                     layers=(CNNLayer(kind="conv", c_out=cout, k=k,
                                      stride=s, pad=p, activation="relu"),),
                     n_classes=2, dtype="bfloat16")


def run_measured(*, impl: str = "auto", interpret: bool | None = None,
                 repeats: int = 3, top_k: int = 3):
    """Measured Table-1 analogue: AUTO is the autotuner's winner (top-k
    by calibrated cost, replay-measured); HAND is the "patient
    engineer" — *every* feasible candidate replay-measured, min taken.
    Both execute the same kernels on this host, so the auto/hand ratio
    is wallclock, not model.  The paper's claim is auto within ~0.5% of
    hand; here the check is auto_us/hand_us per layer.

    Off-TPU the default impl resolves to "reference", which ignores
    tilings — candidates then time identically up to dispatch noise and
    the ratio is a noise floor, not a schedule comparison.  Pass
    ``--interpret`` (pallas interpret mode) to actually execute each
    candidate's tiling on CPU; it is slow but schedule-sensitive."""
    from repro.core.autotune import TunedCache, tune_cnn
    from repro.core.hw import SNOWFLAKE as hw_snowflake
    ratios = []
    for (name, H, W, k, cin, cout, s, p, _hand_ms, _auto_ms) in LAYERS:
        cfg = _layer_config(name, H, cin, cout, k, s, p)
        # HAND: exhaustive — no top-k cut, no modeled-traffic filter.
        hand = tune_cnn(cfg, hw=hw_snowflake, cache=TunedCache(),
                        impl=impl, interpret=interpret, top_k=10**6,
                        repeats=repeats, require_no_model_regression=False)
        # AUTO: the production search path (defaults).
        auto = tune_cnn(cfg, hw=hw_snowflake, cache=TunedCache(),
                        impl=impl, interpret=interpret, top_k=top_k,
                        repeats=repeats)
        rh, ra = hand.results[0], auto.results[0]
        t_hand, t_auto = rh.winner_time_s, ra.winner_time_s
        t_untuned = ra.incumbent_time_s
        ratio = t_auto / t_hand
        ratios.append(ratio)
        emit(f"table1m/{name}/auto", t_auto * 1e6,
             f"untuned_us={t_untuned * 1e6:.1f};measured={ra.measurements}")
        emit(f"table1m/{name}/hand", t_hand * 1e6,
             f"auto_over_hand={ratio:.3f};measured={rh.measurements}")
    emit("table1m/mean_auto_over_hand",
         sum(ratios) / len(ratios) * 100, "pct_of_hand;paper<=100.5")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true",
                    help="execute AUTO (tuned) vs HAND (exhaustive "
                         "search) instead of the analytic model")
    ap.add_argument("--impl", default="auto")
    ap.add_argument("--interpret", action="store_true", default=None,
                    help="force pallas interpret mode (exercises the "
                         "tiled kernels on CPU)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--top-k", type=int, default=3)
    a = ap.parse_args()
    if a.measured:
        run_measured(impl=a.impl, interpret=a.interpret,
                     repeats=a.repeats, top_k=a.top_k)
    else:
        run()

"""Dependency-free metrics registry: Counter / Gauge / Histogram.

One registry instance is one metrics *plane*: every component of a
serving (or training) process registers its counters, gauges and
latency histograms here, and the whole plane serializes two ways —

* ``snapshot()`` — a JSON-able dict (what ``launch/serve.py
  --metrics-out`` writes and CI asserts against: no stdout scraping);
* ``prometheus_text()`` — the Prometheus text exposition format, so a
  scraper can ingest the same numbers without a client library.

Design constraints, in priority order:

1. **Hot-path cheapness.**  ``Counter.inc`` is one float add;
   ``Histogram.observe`` is one ``bisect`` + two adds.  No locks (the
   engine tick loop is single-threaded; ``AdmissionQueue`` serializes
   its own mutation), no allocation after registration.
2. **No dependencies.**  stdlib only — the metrics plane must import
   before (and without) jax.
3. **Fixed buckets.**  Histograms never store samples; percentiles are
   interpolated from fixed bucket counts, so memory is O(buckets) no
   matter how long the process serves.  The error bound is explicit:
   a reported percentile is within its bucket's width of the true
   sample percentile (tested against a numpy oracle in
   tests/test_observability.py).

Labels follow the Prometheus model: a *family* (name + kind + help +
bucket layout) owns one child metric per label-set, created on first
use — ``registry.counter("admission_blocked_total", reason="queue_full")``
returns the same child every call.
"""
from __future__ import annotations

import json
from bisect import bisect_left

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "exp_buckets", "LATENCY_MS_BUCKETS", "TIME_S_BUCKETS"]


def exp_buckets(lo: float, hi: float, factor: float = 2.0) -> list:
    """Geometric bucket upper bounds from ``lo`` up past ``hi`` —
    constant *relative* percentile error across the range."""
    if lo <= 0 or factor <= 1:
        raise ValueError(f"need lo > 0 and factor > 1, got {lo}, {factor}")
    edges, e = [], lo
    while True:
        edges.append(e)
        if e >= hi:
            return edges
        e *= factor


# Latencies in milliseconds: 1 µs .. ~2 min at 2x resolution — covers a
# sub-ms decode tick and a multi-second cold prefill in one layout.
LATENCY_MS_BUCKETS = exp_buckets(1e-3, 120e3)
# Wallclock in seconds (training steps): 10 µs .. ~20 min.
TIME_S_BUCKETS = exp_buckets(1e-5, 1200.0)


class Counter:
    """Monotone counter.  ``inc`` only; read via ``.value``."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        self.value += n


class Gauge:
    """Last-write-wins scalar (queue depth, free pages, flags)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``buckets`` are ascending upper bounds; observations above the last
    bound land in an implicit overflow bucket.  ``percentile(q)``
    linearly interpolates within the winning bucket (lower bound of
    bucket 0 is 0, of the overflow bucket the last edge) — the
    guarantee is ±(bucket width) vs the exact sample percentile, and
    the overflow bucket reports its lower edge (a *floor*, flagged by
    ``saturated``)."""
    __slots__ = ("buckets", "counts", "count", "sum")

    def __init__(self, buckets=None):
        b = list(LATENCY_MS_BUCKETS if buckets is None else buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"buckets must be ascending, got {b}")
        self.buckets = b
        self.counts = [0] * (len(b) + 1)          # + overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v

    @property
    def saturated(self) -> int:
        """Observations past the last bucket edge (their percentile
        contribution is floored at that edge)."""
        return self.counts[-1]

    def percentile(self, q: float) -> float:
        """Interpolated q-th percentile (0 <= q <= 100); 0.0 when
        empty."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile wants 0..100, got {q}")
        if self.count == 0:
            return 0.0
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                if i == len(self.buckets):        # overflow: floor
                    return self.buckets[-1]
                hi = self.buckets[i]
                return lo + (hi - lo) * max(rank - cum, 0.0) / c
            cum += c
        return self.buckets[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One metric name: kind, help text, bucket layout, and one child
    per label-set (children share the family's bucket layout)."""
    __slots__ = ("name", "kind", "help", "buckets", "children")

    def __init__(self, name, kind, help_, buckets=None):
        self.name = name
        self.kind = kind
        self.help = help_
        self.buckets = buckets
        self.children: dict[tuple, object] = {}

    def child(self, labels: tuple):
        m = self.children.get(labels)
        if m is None:
            m = (Histogram(self.buckets) if self.kind == "histogram"
                 else _KINDS[self.kind]())
            self.children[labels] = m
        return m


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _flat_name(name: str, labels: tuple) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """The process's metric families.  ``counter``/``gauge``/
    ``histogram`` register-or-fetch (same name + labels → same child
    object, so hot paths can hold the child directly and skip the
    lookup)."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _get(self, name, kind, help_, buckets=None):
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, kind, help_, buckets)
            self._families[name] = fam
        elif fam.kind != kind:
            raise ValueError(f"{name} already registered as {fam.kind}")
        return fam

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help).child(_label_key(labels))

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help).child(_label_key(labels))

    def histogram(self, name: str, help: str = "", buckets=None,
                  **labels) -> Histogram:
        return self._get(name, "histogram", help,
                         buckets).child(_label_key(labels))

    # -- serialization ----------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able view: ``counters`` / ``gauges`` map flat names
        (labels folded into the key) to values; ``histograms`` carry
        bucket layout + counts + the headline percentiles so consumers
        never re-implement the interpolation."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for fam in self._families.values():
            for labels, m in sorted(fam.children.items()):
                key = _flat_name(fam.name, labels)
                if fam.kind == "counter":
                    out["counters"][key] = m.value
                elif fam.kind == "gauge":
                    out["gauges"][key] = m.value
                else:
                    out["histograms"][key] = {
                        "count": m.count, "sum": m.sum,
                        "buckets": m.buckets, "counts": m.counts,
                        "p50": m.percentile(50), "p90": m.percentile(90),
                        "p99": m.percentile(99),
                    }
        return out

    def to_json(self, **meta) -> str:
        return json.dumps({**({"meta": meta} if meta else {}),
                           **self.snapshot()}, indent=2, sort_keys=True)

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (histograms as cumulative
        ``_bucket{le=...}`` series plus ``_sum``/``_count``)."""
        lines = []
        for fam in sorted(self._families.values(), key=lambda f: f.name):
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for labels, m in sorted(fam.children.items()):
                if fam.kind in ("counter", "gauge"):
                    lines.append(f"{_flat_name(fam.name, labels)} "
                                 f"{_fmt(m.value)}")
                    continue
                cum = 0
                for edge, c in zip(m.buckets + [float("inf")], m.counts):
                    cum += c
                    le = "+Inf" if edge == float("inf") else _fmt(edge)
                    lines.append(f"{_flat_name(fam.name + '_bucket', labels + (('le', le),))} {cum}")
                lines.append(f"{_flat_name(fam.name + '_sum', labels)} "
                             f"{_fmt(m.sum)}")
                lines.append(f"{_flat_name(fam.name + '_count', labels)} "
                             f"{m.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))

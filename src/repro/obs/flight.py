"""Flight recorder: typed per-request lifecycle events as JSONL.

The serving stack's black box.  Every externally meaningful state
transition of a request — enqueue, admission ticket (including the
typed backpressure rejections), each prefill chunk, first token,
every subsequent token, speculative propose/accept/rollback, COW page
forks, release — plus one per-tick engine snapshot, lands here as one
JSON object per line.  The stream is *replayable*: ``replay_summary``
reconstructs each request's token stream, TTFT and inter-token
latencies purely from the recorded events, so a serving run can be
audited (and CI-asserted) from the artifact alone, no stdout scraping
and no re-run.

Schema discipline: ``EVENT_FIELDS`` names the required fields per
event type and ``FlightRecorder.event`` enforces them at emit time —
a malformed event is a bug at the *producer*, caught where it is
cheap to debug, not downstream in a parser.  Extra fields are always
allowed (they version the schema forward).  Every event carries

* ``ev`` — the type tag;
* ``t``  — seconds on the recorder's clock (``time.perf_counter`` by
  default, injectable for deterministic tests).

Disabled mode is the module-level ``NULL`` recorder: ``event`` is a
no-op ``pass``, ``events`` is an empty tuple — the engine holds the
same code path either way and the overhead contract (docs Stage 8)
stays trivially true.
"""
from __future__ import annotations

import json
import time

__all__ = ["EVENT_FIELDS", "FlightRecorder", "NullFlightRecorder", "NULL",
           "read_events", "parse_events", "replay_summary"]

# Required fields per event type (beyond the implicit ev/t).  The
# taxonomy is documented in docs/ARCHITECTURE.md, Stage 8.
EVENT_FIELDS: dict[str, tuple] = {
    "enqueue":       ("uid", "prompt_len"),
    "admission":     ("accepted", "reason"),          # + uid when known
    "prefill_start": ("uid", "slot", "length", "write_from"),
    "prefill_chunk": ("uid", "slot", "start", "stop"),
    "first_token":   ("uid", "slot", "token", "ttft_ms"),
    "token":         ("uid", "slot", "token", "itl_ms"),
    "spec":          ("slot", "uid", "proposed", "accepted", "rollback"),
    "cow_fork":      ("slot", "src_page", "dst_page"),
    "release":       ("uid", "slot", "n_tokens", "reason"),
    "tick":          ("tick", "dt_ms", "live", "queue_depth",
                      "free_pages", "starved"),
    "fallback":      ("reason",),
    "op_sample":     ("kind", "name", "measured_time_s"),
}


class FlightRecorder:
    """Buffered JSONL event sink.  The hot path (``event``) does only
    the schema check and a list append — JSON serialization and file
    IO are deferred to ``flush``/``close``, which write every
    not-yet-written event.  That keeps the per-tick cost of a recorder
    inside the Stage-8 overhead contract (docs, Stage 8); a long-lived
    server should call ``flush`` periodically (tick boundary, every
    few seconds) so a crash loses at most one flush interval."""

    def __init__(self, path=None, clock=time.perf_counter):
        self.events: list[dict] = []
        self.clock = clock
        self.path = str(path) if path is not None else None
        self._fh = open(path, "w") if path is not None else None
        self._written = 0

    @property
    def enabled(self) -> bool:
        return True

    def event(self, ev: str, **fields) -> None:
        required = EVENT_FIELDS.get(ev)
        if required is None:
            raise ValueError(f"unknown flight event type {ev!r} "
                             f"(add it to EVENT_FIELDS)")
        missing = [k for k in required if k not in fields]
        if missing:
            raise ValueError(f"flight event {ev!r} missing required "
                             f"fields {missing}")
        self.events.append({"ev": ev, "t": self.clock(), **fields})

    def flush(self) -> None:
        if self._fh is None:
            return
        pending = self.events[self._written:]
        if pending:
            self._fh.write("".join(json.dumps(rec) + "\n"
                                   for rec in pending))
            self._written = len(self.events)
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self.flush()
            self._fh.close()
            self._fh = None


class NullFlightRecorder:
    """Disabled mode: same interface, zero work, zero events."""
    events: tuple = ()
    path = None
    enabled = False

    def event(self, ev: str, **fields) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL = NullFlightRecorder()


def parse_events(text: str) -> list[dict]:
    """JSONL text -> event dicts, with the schema check re-applied (a
    truncated or hand-edited record fails here, not in a consumer)."""
    events = []
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        rec = json.loads(line)
        ev = rec.get("ev")
        if ev not in EVENT_FIELDS:
            raise ValueError(f"line {i}: unknown event type {ev!r}")
        missing = [k for k in EVENT_FIELDS[ev]
                   if k not in rec] + [k for k in ("t",) if k not in rec]
        if missing:
            raise ValueError(f"line {i}: event {ev!r} missing {missing}")
        events.append(rec)
    return events


def read_events(path) -> list[dict]:
    with open(path) as f:
        return parse_events(f.read())


def replay_summary(events) -> dict:
    """Reconstruct the serving run from its flight record.

    Returns ``{"requests": {uid: {...}}, "totals": {...}}`` where each
    request carries its replayed token stream (``tokens`` — must match
    the engine's ``out_tokens`` exactly; CI asserts this), TTFT and
    per-token inter-token latencies in ms (recomputed from event
    timestamps, *not* read from the recorded ttft_ms/itl_ms fields —
    the replay is an independent check of the producer), and the
    release reason.  Totals aggregate tokens, rejections, ticks and
    the max starved-tick count seen in any tick snapshot."""
    reqs: dict = {}

    def r(uid):
        return reqs.setdefault(uid, {
            "prompt_len": None, "tokens": [], "token_t": [],
            "enqueue_t": None, "slot": None, "ttft_ms": None,
            "itl_ms": [], "release_reason": None, "chunks": 0,
        })

    totals = {"n_enqueued": 0, "n_rejected": 0, "n_blocked": 0,
              "n_released": 0, "n_tokens": 0, "n_ticks": 0,
              "max_starved": 0, "n_spec_proposed": 0,
              "n_spec_accepted": 0, "n_cow_forks": 0, "fallbacks": []}
    for e in events:
        ev = e["ev"]
        if ev == "enqueue":
            q = r(e["uid"])
            q["prompt_len"] = e["prompt_len"]
            q["enqueue_t"] = e["t"]
            totals["n_enqueued"] += 1
        elif ev == "admission" and not e["accepted"]:
            # queue_full is a terminal submit-time rejection of one
            # request; no_free_slot / pages_exhausted are stalls — the
            # request stays queued (head-requeued) and is retried.
            if e["reason"] == "queue_full":
                totals["n_rejected"] += 1
                if "uid" in e:
                    r(e["uid"])["release_reason"] = e["reason"]
            else:
                totals["n_blocked"] += 1
        elif ev == "prefill_start":
            r(e["uid"])["slot"] = e["slot"]
        elif ev == "prefill_chunk":
            r(e["uid"])["chunks"] += 1
        elif ev in ("first_token", "token"):
            q = r(e["uid"])
            q["slot"] = e["slot"]
            if ev == "first_token" and q["enqueue_t"] is not None:
                q["ttft_ms"] = (e["t"] - q["enqueue_t"]) * 1e3
            if q["token_t"]:
                q["itl_ms"].append((e["t"] - q["token_t"][-1]) * 1e3)
            q["tokens"].append(e["token"])
            q["token_t"].append(e["t"])
            totals["n_tokens"] += 1
        elif ev == "spec":
            totals["n_spec_proposed"] += e["proposed"]
            totals["n_spec_accepted"] += e["accepted"]
        elif ev == "cow_fork":
            totals["n_cow_forks"] += 1
        elif ev == "release":
            q = r(e["uid"])
            q["release_reason"] = e["reason"]
            if len(q["tokens"]) != e["n_tokens"]:
                raise ValueError(
                    f"uid {e['uid']}: release says {e['n_tokens']} tokens "
                    f"but the event stream replayed {len(q['tokens'])}")
            totals["n_released"] += 1
        elif ev == "tick":
            totals["n_ticks"] += 1
            totals["max_starved"] = max(totals["max_starved"],
                                        e["starved"])
        elif ev == "fallback":
            totals["fallbacks"].append(e["reason"])
    for q in reqs.values():
        q.pop("token_t")
    return {"requests": reqs, "totals": totals}

"""Observability plane: metrics registry + flight recorder (Stage 8).

The serving stack (and the trainer, and the benchmarks) report through
one substrate instead of three ad-hoc idioms:

* ``metrics``  — dependency-free Counter / Gauge / Histogram registry
  with JSON-snapshot and Prometheus-text serialization;
* ``flight``   — a JSONL flight recorder of typed per-request
  lifecycle events + per-tick engine snapshots, replayable offline;
* ``Observability`` — the bundle a component takes as one argument:
  registry + recorder + clock + op-sampling cadence.

Everything here is stdlib-only and import-safe before jax.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .flight import (EVENT_FIELDS, NULL, FlightRecorder,
                     NullFlightRecorder, parse_events, read_events,
                     replay_summary)
from .metrics import (LATENCY_MS_BUCKETS, TIME_S_BUCKETS, Counter, Gauge,
                      Histogram, MetricsRegistry, exp_buckets)

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "exp_buckets", "LATENCY_MS_BUCKETS", "TIME_S_BUCKETS",
           "FlightRecorder", "NullFlightRecorder", "NULL",
           "EVENT_FIELDS", "parse_events", "read_events",
           "replay_summary", "Observability"]


@dataclass
class Observability:
    """What a component needs to report: one registry, one recorder,
    one clock.  The default is the *cheap always-on* configuration —
    counters and latency histograms record (they are a handful of
    float ops per tick), the flight recorder is the no-op ``NULL``
    and op sampling is off, so a bare ``ServingEngine`` pays nothing
    measurable for its metrics plane.

    ``flight_path`` is the convenience constructor for the common
    case: ``Observability(flight_path="flight.jsonl")`` builds a real
    recorder on the bundle's clock.  ``sample_ops_every=N`` makes the
    engine time one decode tick per N through the Stage-7 trace
    recorder (``runtime/executor.py::OpTimingSampler``) — per-op-kind
    wallclock attribution at 1/N cost, without full trace mode."""
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    flight: object = NULL
    clock: object = time.perf_counter
    sample_ops_every: int = 0
    flight_path: object = None

    def __post_init__(self):
        if self.flight_path is not None and self.flight is NULL:
            self.flight = FlightRecorder(self.flight_path,
                                         clock=self.clock)

    @property
    def flight_enabled(self) -> bool:
        return self.flight.enabled

    def close(self) -> None:
        self.flight.close()

"""Step builders: train / prefill / decode, with full sharding trees.

Everything here is mesh- and allocation-agnostic: ``input_specs`` and
``abstract_*`` return ShapeDtypeStructs, and the jitted steps take
in/out shardings from the ShardingPlan — the same builders serve the
real launcher (concrete arrays) and the dry-run (.lower().compile()).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..core.hw import MeshDescriptor
from ..models import (abstract_params, cross_entropy_loss, get_model,
                      param_pspecs)
from ..models.losses import chunked_cross_entropy
from ..optim import AdamW
from ..parallel.act_sharding import activation_rules
from ..parallel.rules import ShardingPlan

__all__ = ["StepBundle", "input_specs", "batch_pspecs", "cache_pspecs",
           "abstract_train_state", "abstract_cache", "build_step",
           "opt_state_pspecs"]

AUX_LOSS_WEIGHT = 0.01


# --- input specs ------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    GB, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((GB, S), i32),
                 "labels": jax.ShapeDtypeStruct((GB, S), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((GB, S), i32)}
    else:  # decode: one new token each; the cache is a separate operand
        specs = {"tokens": jax.ShapeDtypeStruct((GB,), i32)}
    api = get_model(cfg)
    if api.extra_input == "vision_embeds" and shape.kind != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (GB, cfg.n_vision_tokens, cfg.d_model), cfg.jdtype)
    if api.extra_input == "encoder_frames" and shape.kind != "decode":
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (GB, cfg.encoder_seq, cfg.d_model), cfg.jdtype)
    return specs


def _axis_total(mesh_sizes: dict, entry) -> int:
    names = (entry,) if isinstance(entry, str) else tuple(entry or ())
    total = 1
    for n in names:
        total *= mesh_sizes.get(n, 1)
    return total


def _fit(shape: tuple, mesh_sizes: dict, *entries) -> P:
    """Divisibility-checked spec: non-dividing entries fall to None;
    each mesh axis used at most once."""
    used: set[str] = set()
    fixed = []
    for dim, e in zip(shape, entries):
        names = (e,) if isinstance(e, str) else tuple(e or ())
        total = _axis_total(mesh_sizes, e)
        if not names or dim % total != 0 or any(n in used for n in names):
            fixed.append(None)
        else:
            used.update(names)
            fixed.append(e)
    return P(*fixed)


def _batch_candidates(dp) -> list:
    """Fallback chain for the batch axis: the full dp spec, then every
    contiguous sub-tuple by decreasing coverage (e.g. 256-batch on a
    512-chip flat axis falls back to (data, model))."""
    if isinstance(dp, str) or dp is None:
        return [dp]
    cands = []
    n = len(dp)
    for size in range(n, 0, -1):
        for start in range(0, n - size + 1):
            cands.append(tuple(dp[start:start + size]))
    return cands


def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec, plan: ShardingPlan,
                 mesh_sizes: dict) -> dict:
    dp = plan.batch_spec[0]
    out = {}
    for k, v in input_specs(cfg, shape).items():
        spec = P(*([None] * len(v.shape)))
        for cand in _batch_candidates(dp):
            trial = _fit(v.shape, mesh_sizes, cand,
                         *([None] * (len(v.shape) - 1)))
            if trial[0] is not None:
                spec = trial
                break
        out[k] = spec
    return out


def cache_pspecs(cache_abstract: dict, plan: ShardingPlan,
                 mesh_sizes: dict) -> dict:
    """Per-key cache sharding: batch over dp, heads over model, with
    divisibility-aware fallback (kv_heads < model axis -> shard head_dim;
    batch=1 long-context -> shard heads over the data axes too)."""
    dp = plan.batch_spec[0]
    specs = {}
    for k, v in cache_abstract.items():
        sh = v.shape
        nd = len(sh)
        if k == "pos":
            specs[k] = _fit(sh, mesh_sizes, dp)
        elif k in ("k", "v", "cross_k", "cross_v", "attn_k", "attn_v"):
            # (L, B, KV, S, hd): prefer heads on model, else head_dim.
            s = _fit(sh, mesh_sizes, None, dp, "model", None, None)
            if s[2] is None:
                s = _fit(sh, mesh_sizes, None, dp, None, None, "model")
            if s[1] is None:   # batch not shardable: spread heads wider
                s2 = _fit(sh, mesh_sizes, None, None, (dp, "model")
                          if isinstance(dp, str) else tuple(dp) + ("model",),
                          None, None)
                if s2[2] is not None:
                    s = s2
            specs[k] = s
        elif k in ("ssm", "wkv"):            # (L, B, H, N, P)
            s = _fit(sh, mesh_sizes, None, dp, "model", None, None)
            if s[2] is None:
                s = _fit(sh, mesh_sizes, None, dp, None, None, "model")
            specs[k] = s
        elif k == "conv":                    # (L, B, K, C)
            specs[k] = _fit(sh, mesh_sizes, None, dp, None, "model")
        elif k in ("shift_t", "shift_c"):    # (L, B, D)
            specs[k] = _fit(sh, mesh_sizes, None, dp, "model")
        else:
            specs[k] = P(*([None] * nd))
    return specs


def opt_state_pspecs(param_specs: dict, state_bits: int) -> dict:
    """Optimizer-state specs mirror the (ZeRO-sharded) param specs.

    8-bit moments: Q8State(q like the param, scale with the last axis
    unsharded — it is reduced to length 1)."""
    if state_bits == 8:
        from ..optim.adamw import Q8State

        def expand(spec):
            entries = list(spec)
            scale_entries = entries[:-1] + [None] if entries else []
            return Q8State(q=spec, scale=P(*scale_entries))
        m = jax.tree.map(expand, param_specs,
                         is_leaf=lambda x: isinstance(x, P))
        return {"m": m, "v": m, "step": P()}
    return {"m": param_specs, "v": param_specs, "step": P()}


# --- abstract state ---------------------------------------------------------------
def abstract_train_state(cfg: ArchConfig, optimizer: AdamW):
    api = get_model(cfg)
    defs = api.param_defs(cfg)
    params = abstract_params(defs)
    opt_state = jax.eval_shape(optimizer.init, params)
    return params, opt_state, defs


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    api = get_model(cfg)
    return jax.eval_shape(
        functools.partial(api.init_cache, cfg, batch, max_len))


# --- step builders ----------------------------------------------------------------
@dataclass
class StepBundle:
    fn: Any                      # the jitted step
    args: tuple                  # abstract operands in call order
    donate: tuple = ()


def _named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def build_step(cfg: ArchConfig, shape: ShapeSpec, plan: ShardingPlan,
               mesh, *, optimizer: AdamW | None = None,
               impl: str = "auto", remat: bool | None = None
               ) -> StepBundle:
    """Build the jitted step for one (arch x shape) cell with shardings."""
    api = get_model(cfg)
    defs = api.param_defs(cfg)
    mesh_sizes = dict(mesh.shape)
    p_specs = param_pspecs(defs, plan.rules, plan.overrides,
                           axis_sizes=mesh_sizes)
    params_abs = abstract_params(defs)
    act_rules = plan.activation_rules(mesh)
    b_specs = batch_pspecs(cfg, shape, plan, mesh_sizes)
    batch_abs = input_specs(cfg, shape)
    if remat is None:
        remat = shape.kind == "train" and cfg.n_layers >= 16

    extra_key = api.extra_input if api.extra_input in batch_abs else None

    if shape.kind == "train":
        optimizer = optimizer or AdamW()
        opt_abs = jax.eval_shape(optimizer.init, params_abs)
        o_specs = opt_state_pspecs(p_specs, optimizer.state_bits)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                kw = {extra_key: batch[extra_key]} if extra_key else {}
                with activation_rules(act_rules):
                    out = api.forward(p, batch["tokens"], cfg, impl=impl,
                                      remat=remat, return_hidden=True, **kw)
                    head = (p["embed"].T if cfg.tie_embeddings
                            else p["lm_head"])
                    loss = chunked_cross_entropy(out["hidden"], head,
                                                 batch["labels"])
                aux = out.get("aux", {})
                if "lb_loss" in aux:
                    loss = loss + AUX_LOSS_WEIGHT * aux["lb_loss"]
                return loss, aux
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt_state, om = optimizer.update(grads, opt_state,
                                                     params)
            metrics = {"loss": loss, **om}
            if "imbalance_pct" in aux:
                metrics["moe_imbalance_pct"] = aux["imbalance_pct"]
            return params, opt_state, metrics

        fn = jax.jit(
            train_step,
            in_shardings=(_named(p_specs, mesh), _named(o_specs, mesh),
                          _named(b_specs, mesh)),
            out_shardings=(_named(p_specs, mesh), _named(o_specs, mesh),
                           None),
            donate_argnums=(0, 1))
        return StepBundle(fn, (params_abs, opt_abs, batch_abs))

    if shape.kind == "prefill":
        cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        c_specs = cache_pspecs(cache_abs, plan, mesh_sizes)

        def prefill_step(params, batch):
            kw = {extra_key: batch[extra_key]} if extra_key else {}
            with activation_rules(act_rules):
                out = api.forward(params, batch["tokens"], cfg, impl=impl,
                                  return_cache=True, return_hidden=True,
                                  cache_len=shape.seq_len, **kw)
                # head applied to the last position only — never
                # materializes (B, S, V) logits during prefill.
                head = (params["embed"].T if cfg.tie_embeddings
                        else params["lm_head"])
                logits = out["hidden"][:, -1] @ head
            return logits, out["cache"]

        logits_out = _fit((shape.global_batch, cfg.vocab), mesh_sizes,
                          plan.batch_spec[0], "model")
        fn = jax.jit(
            prefill_step,
            in_shardings=(_named(p_specs, mesh), _named(b_specs, mesh)),
            out_shardings=(NamedSharding(mesh, logits_out),
                           _named(c_specs, mesh)))
        return StepBundle(fn, (params_abs, batch_abs))

    # decode
    cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    c_specs = cache_pspecs(cache_abs, plan, mesh_sizes)

    def serve_step(params, cache, batch):
        with activation_rules(act_rules):
            logits, cache = api.decode_step(params, cache, batch["tokens"],
                                            cfg, impl=impl)
        return logits, cache

    logits_out = _fit((shape.global_batch, cfg.vocab), mesh_sizes,
                      plan.batch_spec[0], "model")
    fn = jax.jit(
        serve_step,
        in_shardings=(_named(p_specs, mesh), _named(c_specs, mesh),
                      _named(b_specs, mesh)),
        out_shardings=(NamedSharding(mesh, logits_out),
                       _named(c_specs, mesh)),
        donate_argnums=(1,))
    return StepBundle(fn, (params_abs, cache_abs, batch_abs),
                      donate=(1,))

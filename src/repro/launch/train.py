"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --smoke --steps 200 --batch 8 --seq 128 \
        --ckpt-dir /tmp/run0

On a real TPU slice this builds the production mesh and the sharded
train step (launch/steps.py); on CPU it runs single-device with the
same code path.  Fault tolerance (auto-resume, preemption checkpoint,
straggler log) comes from runtime/Trainer.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax

from ..configs import get_config
from ..configs.base import ShapeSpec
from ..data import SyntheticLM
from ..models import get_model, init_params
from ..optim import AdamW, cosine_schedule
from ..parallel.rules import make_plan
from ..runtime import Trainer, TrainerConfig
from .steps import build_step
from ..core.hw import MeshDescriptor
from .mesh import make_mesh_from_descriptor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt-bits", type=int, default=32, choices=[8, 32])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' or a packed-token file path")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = ShapeSpec("cli_train", args.seq, args.batch, "train")

    n_dev = len(jax.devices())
    if n_dev >= 4:
        desc = MeshDescriptor((n_dev // 2, 2), ("data", "model"))
    else:
        desc = MeshDescriptor((n_dev, 1), ("data", "model"))
    mesh = make_mesh_from_descriptor(desc)
    plan = make_plan(cfg, shape, desc, args.strategy)
    optimizer = AdamW(lr=cosine_schedule(args.lr, warmup=20,
                                         total=args.steps),
                      state_bits=args.opt_bits)

    with mesh:
        bundle = build_step(cfg, shape, plan, mesh, optimizer=optimizer,
                            impl="auto")
        api = get_model(cfg)
        params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
        opt_state = optimizer.init(params)

        if args.data == "synthetic":
            data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=0)
        else:
            from ..data import PackedFileDataset
            data = PackedFileDataset(args.data, cfg.vocab, args.seq,
                                     args.batch)

        trainer = Trainer(bundle.fn, data, TrainerConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir, log_every=10))
        params, opt_state, step = trainer.run(params, opt_state)
    print(f"finished at step {step}; "
          f"last loss {trainer.metrics_history[-1]['loss']:.4f}"
          if trainer.metrics_history else "no steps ran")
    return cfg, params


if __name__ == "__main__":
    main()

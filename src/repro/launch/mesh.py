"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax

from ..core.hw import MULTI_POD, SINGLE_POD, MeshDescriptor

__all__ = ["make_production_mesh", "make_mesh_from_descriptor",
           "descriptor_for"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def descriptor_for(*, multi_pod: bool = False) -> MeshDescriptor:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_mesh_from_descriptor(desc: MeshDescriptor):
    import numpy as np
    devices = jax.devices()
    if len(devices) < desc.n_chips:
        raise RuntimeError(f"need {desc.n_chips} devices, have "
                           f"{len(devices)}")
    dev = np.asarray(devices[:desc.n_chips]).reshape(desc.shape)
    return jax.sharding.Mesh(dev, desc.axes)


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CPU integration tests (8 host devices)."""
    return make_mesh_from_descriptor(MeshDescriptor(shape, axes))

"""Render the roofline table (EXPERIMENTS.md §Roofline) from a dry-run
results JSONL.  ``python -m repro.launch.report dryrun_results.jsonl``."""
from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return rows


def fmt_ms(x: float) -> str:
    if x >= 100_000:
        return f"{x/1000:.0f}s"
    if x >= 1000:
        return f"{x/1000:.2f}s"
    if x >= 1:
        return f"{x:.1f}ms"
    return f"{x*1000:.0f}us"


def roofline_table(rows: list[dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO flops | step bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped") or "error" in r or r.get("mesh") != mesh:
            continue
        step = max(r["compute_ms"], r["memory_ms"], r["collective_ms"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['compute_ms'])} "
            f"| {fmt_ms(r['memory_ms'])} | {fmt_ms(r['collective_ms'])} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {fmt_ms(step)} |")
    return "\n".join(out)


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compile | HLO GFLOPs/chip | "
           "coll bytes/chip | args/chip | temp/chip |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped") or "error" in r:
            continue
        m = r.get("memory_analysis", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']}s "
            f"| {r['hlo_flops']/r['chips']/1e9:.1f} "
            f"| {r['coll_link_bytes_per_chip']/1e6:.0f} MB "
            f"| {(m.get('argument_size_in_bytes') or 0)/1e9:.2f} GB "
            f"| {(m.get('temp_size_in_bytes') or 0)/1e9:.2f} GB |")
    skips = [r for r in rows if r.get("skipped")]
    if skips:
        out.append("")
        out.append("Skipped cells (per assignment rules):")
        for r in skips:
            out.append(f"* {r['arch']} x {r['shape']}: {r['reason']}")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl"
    rows = load(path)
    print("## Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16, 512 chips)\n")
    print(roofline_table(rows, "2x16x16"))
    print("\n## Dry-run records\n")
    print(dryrun_table(rows))


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as a script / module entry (``python -m
repro.launch.dryrun``) so the XLA_FLAGS above take effect before jax
initializes its backends.  For each cell this:

  1. builds the production mesh (16x16 single-pod, 2x16x16 multi-pod),
  2. builds the jitted step via launch/steps.py with full shardings,
  3. ``.lower()`` on ShapeDtypeStruct operands (zero allocation),
  4. ``.compile()`` — sharding mismatches / unsupported collectives fail
     here, which is the point,
  5. records memory_analysis / cost_analysis / per-collective bytes
     parsed from the optimized HLO -> roofline terms (core/roofline.py),
  6. appends one JSON record per cell to the output file.

Cells are compiled in-process; kernels run impl="reference" (Mosaic is
unavailable off-TPU; the collective/sharding structure under test is
kernel-choice independent).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs import REGISTRY, get_config
from ..core.hw import TPU_V5E
from ..core.roofline import roofline_report
from ..optim import AdamW
from ..parallel.rules import make_plan
from .mesh import descriptor_for, make_production_mesh
from .steps import build_step

# Serving-memory adaptations per cell (EXPERIMENTS.md §Dry-run notes):
# fp8 KV caches for the large dense/MoE decode cells.
F8_DECODE_ARCHS = {"llama3-8b", "deepseek-7b", "olmo-1b",
                   "llama4-maverick-400b-a17b", "llama-3.2-vision-11b"}


def analytic_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference steps."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # one token per sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             strategy: str = "auto", optimizer_bits: int = 32,
             hw=TPU_V5E) -> dict:
    cfg = get_config(arch)
    shape = {s.name: s for s in cfg.shapes()}[shape_name]
    if shape.kind == "decode" and arch in F8_DECODE_ARCHS:
        cfg = dataclasses.replace(cfg, kv_dtype="float8")
    desc = descriptor_for(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(cfg, shape, desc, strategy)
    optimizer = AdamW(state_bits=optimizer_bits)

    t0 = time.time()
    with mesh:
        bundle = build_step(cfg, shape, plan, mesh, optimizer=optimizer)
        lowered = bundle.fn.lower(*bundle.args)
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        try:
            cost = compiled.cost_analysis()
        except Exception:
            cost = None
        hlo = compiled.as_text()

    mesh_name = "2x16x16" if multi_pod else "16x16"
    rep = roofline_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_chips=desc.n_chips,
        cost_analysis=cost, hlo_text=hlo,
        model_flops=analytic_flops(cfg, shape), hw=hw,
        analytic_flops=analytic_flops(cfg, shape))
    mem_fields = {}
    if mem is not None:
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes"):
            mem_fields[f] = getattr(mem, f, None)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "strategy": plan.strategy, "kind": shape.kind,
        "chips": desc.n_chips,
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem_fields,
        "hlo_flops": rep.hlo_flops, "hlo_bytes": rep.hlo_bytes,
        "coll_link_bytes_per_chip": rep.coll_link_bytes,
        "coll_counts": rep.coll_counts,
        "compute_ms": rep.compute_s * 1e3,
        "memory_ms": rep.memory_s * 1e3,
        "collective_ms": rep.collective_s * 1e3,
        "dominant": rep.dominant,
        "model_flops": rep.model_flops,
        "useful_ratio": rep.useful_ratio,
        "notes": rep.notes,
        "decisions": plan.decisions,
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--optimizer-bits", type=int, default=32)
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(REGISTRY) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    done = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("strategy", "auto")))
                except Exception:
                    pass

    with open(args.out, "a") as out:
        for arch in archs:
            cfg = get_config(arch)
            shapes = ([s.name for s in cfg.shapes()]
                      if args.shape == "all" else [args.shape])
            for shape_name in shapes:
                for multi in meshes:
                    mesh_name = "2x16x16" if multi else "16x16"
                    key = (arch, shape_name, mesh_name, args.strategy)
                    if key in done:
                        continue
                    # big MoE training: 8-bit optimizer states to fit
                    bits = args.optimizer_bits
                    if (arch == "llama4-maverick-400b-a17b"
                            and shape_name == "train_4k"):
                        bits = 8
                    tag = f"{arch} x {shape_name} x {mesh_name}"
                    print(f"=== {tag}", flush=True)
                    try:
                        rec = run_cell(arch, shape_name, multi_pod=multi,
                                       strategy=args.strategy,
                                       optimizer_bits=bits)
                        print(f"    ok compile={rec['compile_s']}s "
                              f"dominant={rec['dominant']} "
                              f"compute={rec['compute_ms']:.2f}ms "
                              f"memory={rec['memory_ms']:.2f}ms "
                              f"coll={rec['collective_ms']:.2f}ms",
                              flush=True)
                        print(f"    memory_analysis={rec['memory_analysis']}",
                              flush=True)
                    except Exception as e:
                        rec = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "strategy": args.strategy,
                               "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()[-2000:]}
                        print(f"    FAILED: {type(e).__name__}: {e}",
                              flush=True)
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
        # record the spec-mandated skips
        for arch in archs:
            cfg = get_config(arch)
            for sk in cfg.skipped_shapes():
                out.write(json.dumps({
                    "arch": arch, "shape": sk, "skipped": True,
                    "reason": "pure full-attention arch; long_500k "
                              "requires sub-quadratic mixing "
                              "(DESIGN.md §4)"}) + "\n")


if __name__ == "__main__":
    main()

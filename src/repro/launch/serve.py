"""Production serving driver: continuous-batched decode.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch smollm-360m --smoke --requests 8 --max-new 16

CNN archs (alexnet-owt / resnet18 / resnet50) serve image-classify
requests through the compiled-Program fast path:

    PYTHONPATH=src python -m repro.launch.serve --arch alexnet-owt \
        --slots 2 --requests 4

Dense LM archs (smollm-360m / llama3-8b class) serve token requests
statefully through the compiled (prefill, decode) Program pair — each
request is prefilled exactly once into a persistent compiler-owned
KV-cache region, then every tick runs the decode Program (O(1) in
prompt length; the engine's ``n_prefill_recomputes`` counter stays 0):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --program --requests 4 --max-new 8

``--paged`` swaps in the paged §5.1 region plan (KV page pools + page
table, copy-on-write prefix sharing, optional ``--kv-quant int8``
pages); ``--shared-prefix N`` makes every prompt open with the same N
tokens so admission actually shares pages:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --program --paged --shared-prefix 32 --requests 4

``--chunk-size N`` splits each prefill into N-row chunks scheduled one
per decode tick (long prompts stop stalling in-flight streams — the
engine's ``n_starved_ticks`` stays 0); ``--spec-decode K`` turns on
greedy speculative decoding, with ``--draft ARCH`` naming a separate
draft model (default: self-draft):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --program --chunk-size 8 --spec-decode 3 --requests 4
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

from ..configs import CNN_REGISTRY, get_config
from ..models import get_model, init_params
from ..obs import Observability
from ..serving import Request, ServingEngine


def _build_obs(args) -> Observability:
    return Observability(flight_path=args.flight_out,
                         sample_ops_every=args.sample_ops)


def _write_artifacts(args, obs: Observability) -> None:
    """Serialize the metrics plane: a JSON snapshot at --metrics-out
    plus the Prometheus text exposition next to it (``.prom``), and
    flush/close the flight recorder.  Called on *every* exit path —
    including the --program exit-code-2 fallback, so a failed run is
    diagnosable from its artifacts."""
    obs.close()
    if not args.metrics_out:
        return
    with open(args.metrics_out, "w") as f:
        f.write(obs.registry.to_json(arch=args.arch, argv=sys.argv[1:]))
    prom = args.metrics_out + ".prom"
    with open(prom, "w") as f:
        f.write(obs.registry.prometheus_text())
    print(f"metrics snapshot -> {args.metrics_out} (+ {prom})")
    if args.flight_out:
        print(f"flight record -> {args.flight_out}")


def _drain(eng, args) -> list:
    """run_until_drained with the periodic console dashboard: every
    --dash-every ticks one line of engine vitals, read off the same
    registry the artifacts serialize."""
    if not args.dash_every:
        return eng.run_until_drained()
    done = []
    for _ in range(10_000):
        done += eng.step()
        if eng._tick_no % args.dash_every == 0:
            print(eng.dashboard_line())
        if (not eng.live and not eng.queue and not eng.admission
                and not eng._prefilling):
            break
    return done


def _serve_cnn(args) -> None:
    """Image-classification serving: the engine executes the compiled
    Program (schedule -> regions -> instruction stream) per tick."""
    from ..models import cnn
    cfg = CNN_REGISTRY[args.arch]
    params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0))
    if args.ckpt:
        from ..checkpoint import restore_checkpoint
        (params, _), step = restore_checkpoint(args.ckpt, (params, {}))
        print(f"restored params from step {step}")
    obs = _build_obs(args)
    eng = ServingEngine(cfg, params, slots=args.slots, obs=obs)
    print(eng.program.listing())
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        img = rng.standard_normal(
            (cfg.input_hw, cfg.input_hw, cfg.input_ch)).astype(np.float32)
        eng.submit(Request(uid=i, prompt=img))
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    print(f"served {len(done)} images in {dt:.2f}s "
          f"({len(done) / dt:.1f} img/s)")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: class {r.out_tokens[0]}")
    _write_artifacts(args, obs)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir to load params from")
    ap.add_argument("--program", action="store_true",
                    help="serve LM tokens through the compiled Program "
                         "(every registered state family: dense/MoE, "
                         "windowed, hybrid SSM, rwkv, whisper; exits "
                         "non-zero if the config cannot lower — "
                         "no silent legacy fallback when the program "
                         "path was explicitly requested)")
    ap.add_argument("--window", type=int, default=None,
                    help="override attn_window (sliding-window "
                         "attention); the program path then sizes the "
                         "persistent KV regions to min(max_len, window)")
    ap.add_argument("--paged", action="store_true",
                    help="compile the paged §5.1 region plan: KV page "
                         "pools + per-slot page table, host-side page "
                         "allocator with copy-on-write prefix sharing "
                         "(requires --program)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="rows per KV page (must divide --max-len)")
    ap.add_argument("--kv-quant", choices=["int8"], default=None,
                    help="quantize paged KV pages to int8 with "
                         "per-page scales (~2x resident cache bytes)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many identical tokens to every "
                         "prompt (exercises paged copy-on-write prefix "
                         "sharing; CI asserts shared pages > 0)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="chunked prefill: admit prompts one N-row "
                         "chunk per decode tick instead of a whole "
                         "prefill at admission (bounds per-tick "
                         "latency; requires --program)")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="speculative decode: a draft Program pair "
                         "proposes K tokens per tick, the target "
                         "verifies the burst in one batched step "
                         "(greedy only; requires --program)")
    ap.add_argument("--draft", default=None,
                    help="draft arch for --spec-decode (same vocab; "
                         "default: self-draft with the target weights)")
    ap.add_argument("--long-prompt", type=int, default=0,
                    help="inject one prompt of this length two ticks "
                         "into the run (the mid-stream long-prompt "
                         "scenario the chunked-prefill CI smoke pins)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the final metrics-registry snapshot as "
                         "JSON to PATH and the Prometheus text "
                         "exposition to PATH.prom (written on every "
                         "exit path, including --program fallback)")
    ap.add_argument("--flight-out", default=None, metavar="PATH",
                    help="record the JSONL flight record (typed "
                         "per-request lifecycle events + per-tick "
                         "snapshots) to PATH; replay offline with "
                         "repro.obs.replay_summary")
    ap.add_argument("--sample-ops", type=int, default=0, metavar="N",
                    help="time one decode tick per N through the "
                         "Stage-7 trace recorder (op_time_us{kind} "
                         "histograms + op_sample flight events); "
                         "0 = off")
    ap.add_argument("--dash-every", type=int, default=0, metavar="N",
                    help="print a one-line console dashboard every N "
                         "engine ticks; 0 = off")
    args = ap.parse_args(argv)
    if args.paged and not args.program:
        print("error: --paged requires --program (the paged plan only "
              "exists on the stateful Program path)", file=sys.stderr)
        raise SystemExit(2)

    if args.arch in CNN_REGISTRY:
        _serve_cnn(args)
        return

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.window:
        cfg = dataclasses.replace(cfg, attn_window=args.window)
    api = get_model(cfg)
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
    if args.ckpt:
        from ..checkpoint import restore_checkpoint
        (params, _), step = restore_checkpoint(args.ckpt, (params, {}))
        print(f"restored params from step {step}")

    draft_cfg = draft_params = None
    if args.draft:
        draft_cfg = get_config(args.draft)
        if args.smoke:
            draft_cfg = draft_cfg.smoke()
        draft_params = init_params(
            get_model(draft_cfg).param_defs(draft_cfg),
            jax.random.PRNGKey(1))

    # The engine compiles the (prefill, decode) Program pair itself and
    # warns (once, at construction) when a family has no lowering.
    obs = _build_obs(args)
    eng = ServingEngine(cfg, params, slots=args.slots,
                        max_len=args.max_len, use_program=args.program,
                        paged=args.paged, page_size=args.page_size,
                        kv_quant=args.kv_quant,
                        chunk_size=args.chunk_size,
                        spec_k=args.spec_decode, draft_cfg=draft_cfg,
                        draft_params=draft_params, obs=obs)
    if args.program and not eng.on_program_path:
        # The user *asked* for the program path; a silent legacy-loop
        # fallback would misreport what was measured.  The engine's
        # fallback_reason names the specific blocker — and the metrics
        # / flight artifacts carry the structured twin (the fallback
        # event + serving_fallback{fallback_reason} gauge).
        _write_artifacts(args, obs)
        print(f"error: --program requested but {cfg.name} has no "
              f"decode-Program lowering "
              f"({eng.fallback_reason or 'unknown reason'})",
              file=sys.stderr)
        raise SystemExit(2)
    if eng.program is not None:
        print(eng.program.listing().splitlines()[0])
    rng = np.random.default_rng(0)

    def _extra():
        # Families with a side-channel input (audio: stub encoder
        # frames) get one per request; admission encodes it into the
        # slot's read-only persistent memory regions.
        if api.extra_input != "encoder_frames":
            return None
        return rng.standard_normal(
            (cfg.encoder_seq, cfg.d_model)).astype(np.float32)

    t0 = time.perf_counter()
    prefix = rng.integers(0, cfg.vocab,
                          size=args.shared_prefix).astype(np.int32)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=rng.integers(1, 8)).astype(np.int32)
        if args.shared_prefix:
            prompt = np.concatenate([prefix, prompt])
        eng.submit(Request(uid=i, prompt=prompt,
                           max_new_tokens=args.max_new, extra=_extra()))
    done = []
    if args.long_prompt:
        # Two ticks of steady decode, then the long prompt lands
        # mid-stream — with --chunk-size its prefill interleaves with
        # the in-flight streams instead of stalling them.
        for _ in range(2):
            done += eng.step()
        eng.submit(Request(
            uid=args.requests,
            prompt=rng.integers(0, cfg.vocab,
                                size=args.long_prompt).astype(np.int32),
            max_new_tokens=args.max_new, extra=_extra()))
    done += _drain(eng, args)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    if eng.on_program_path:
        print(f"prefills={eng.n_prefills} "
              f"prefill_recomputes={eng.n_prefill_recomputes} "
              f"decode_ticks={eng.n_decode_ticks}")
        if eng.chunk_size is not None:
            print(f"prefill_chunks={eng.n_prefill_chunks} "
                  f"starved_ticks={eng.n_starved_ticks}")
        if eng.spec_k:
            print(f"spec_proposed={eng.n_spec_proposed} "
                  f"spec_accepted={eng.n_spec_accepted} "
                  f"spec_rollbacks={eng.n_spec_rollbacks}")
        if eng.admission.n_rejected or eng.admission.n_requeued:
            print(f"rejected={eng.admission.n_rejected} "
                  f"requeued={eng.admission.n_requeued} "
                  f"last_blocked={eng.admission.last_blocked}")
    if args.paged:
        print(f"shared_pages={eng.n_shared_pages} "
              f"cow_forks={eng.n_cow_forks} "
              f"pool_used={eng._pool.used_pages} "
              f"pool_free={eng._pool.free_pages}")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: {list(r.prompt)} -> {r.out_tokens}")
    _write_artifacts(args, obs)


if __name__ == "__main__":
    main()

"""Sharded, mesh-agnostic checkpointing with atomic commit + async save.

Layout on disk (one directory per step):

    <dir>/step_000120/
        manifest.json        # tree structure, shapes, dtypes, step
        arrays/<flat-key>.npy

Arrays are saved as full (unsharded) values — mesh-agnostic by
construction, so restores re-shard onto whatever mesh is live (elastic
scaling).  The manifest is written LAST and a ``COMMITTED`` marker makes
the commit atomic: a checkpoint without the marker is ignored by
``latest_step`` (crash-safe).  ``AsyncCheckpointer`` snapshots to host
memory synchronously and writes in a background thread so the training
loop keeps stepping.

(Per-host sharded-file saving is a straightforward extension — each
host writes its addressable shards — but the single-process container
exercises the full-value path.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_MARKER = "COMMITTED"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}.{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def save_checkpoint(directory: str, step: int, tree, *,
                    keep: int = 3) -> str:
    """Blocking save; returns the checkpoint path."""
    leaves, treedef = jax.tree.flatten(tree)
    flat = {f"leaf_{i:05d}": np.asarray(l) for i, l in enumerate(leaves)}
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
    for k, v in flat.items():
        np.save(os.path.join(tmp, "arrays", k + ".npy"), v)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(directory, keep)
    return path


def _gc(directory: str, keep: int):
    steps = sorted(_committed_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MARKER)):
                out.append(int(name[len("step_"):]))
    return out


def latest_step(directory: str) -> int | None:
    steps = _committed_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — arrays are placed (re-sharded) accordingly, which
    is what makes restores elastic across mesh changes."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    restored = []
    for i in range(len(leaves)):
        key = f"leaf_{i:05d}"
        arr = np.load(os.path.join(path, "arrays", key + ".npy"))
        want = manifest["dtypes"].get(key)
        if want and str(arr.dtype) != want:
            # ml_dtypes (bfloat16/float8) round-trip through .npy as raw
            # void bytes; re-view with the recorded dtype.
            import ml_dtypes  # noqa: F401  (registers the dtypes)
            target = np.dtype(want)
            arr = (arr.view(target) if arr.dtype.itemsize == target.itemsize
                   else arr.astype(target))
        restored.append(arr)
    tree = jax.tree.unflatten(treedef, restored)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else
            jax.device_put(x), tree, shardings,
            is_leaf=lambda x: isinstance(x, np.ndarray))
    return tree, step


class AsyncCheckpointer:
    """Snapshot-then-write-in-background checkpointer.

    ``save`` blocks only for the device->host copy; the serialization
    happens on a worker thread.  ``wait`` joins the in-flight write
    (called before exit and before starting a save for the same dir).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot

        def work():
            save_checkpoint(self.directory, step, host_tree,
                            keep=self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

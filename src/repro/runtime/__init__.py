from .trainer import Trainer, TrainerConfig
from .executor import jitted_runner, run
__all__ = ["Trainer", "TrainerConfig", "run", "jitted_runner"]

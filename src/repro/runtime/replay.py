"""Replay harness — re-execute one traced ProgramOp, optionally with a
*candidate* schedule substituted.

This is the autotuner's measurement primitive (byteprofile-style: the
trace records what ran; replay re-runs it in isolation).  A trace
record (``runtime/executor.TraceRecord``) fully determines an op's
dispatch — kind, resolved schedule, operand shapes/dtypes — so a
single op can be rebuilt and timed without its Program, its params, or
its upstream activations: operands are synthesized at the recorded
shapes, regions are remapped to a private id space, and the param path
is rewritten to a flat ``"p"``/``"p_b"`` dict.  Execution goes through
the *same* ``_run_op`` / ``_run_decode_attention`` dispatch the
executor uses, so a replayed op cannot drift from what ``run`` would
do (replay-vs-executor parity is a tier-1 test).

``candidate`` substitutes schedule decisions before dispatch — conv
(out_rows, kernels_per_tile, strip_storage), matmul (dataflow, block),
attention (block_q, block_kv) — which is exactly how
``core/autotune.py`` measures a candidate it is considering: schedule
decisions change *where bytes move*, never the math, so the replayed
output must match the incumbent's bit-for-bit (reference impl) or to
kernel tolerance (pallas).

The module is also a CLI: ``python -m repro.runtime.replay TRACE.jsonl``
prints the measured-vs-predicted error table per kernel kind, before
and after calibration (``core/cost.fit_cost_model``).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from ..core.dataflow import Dataflow
from ..core.program import AttentionSpec, ProgramOp
from ..core.tiling import ConvTiling
from .executor import (_FAMILY_KERNELS, TraceRecord,
                       _run_decode_attention, _run_op, _time_thunk)

__all__ = ["op_from_record", "synth_operands", "replay_record",
           "replay_outputs", "error_report"]

# Private region-id space for rebuilt ops (never collides with a real
# plan: replay builds its own regions dict).
_RID = {"in": 0, "k": 1, "v": 2, "in2": 3, "bypass": 4, "out": 9,
        "k_cache": 10, "v_cache": 11}


def op_from_record(record: TraceRecord | dict,
                   candidate: dict | None = None) -> ProgramOp:
    """Rebuild an executable ProgramOp from a trace record, with
    ``candidate`` schedule decisions substituted.

    Candidate keys (all optional): ``conv_tiling`` (ConvTiling or its
    asdict), ``strip_storage``, ``dataflow`` (Dataflow or its value),
    ``block`` ((bm, bk, bn)), ``block_q``, ``block_kv``.
    """
    r = record if isinstance(record, TraceRecord) else \
        TraceRecord.from_dict(record)
    s = dict(r.schedule)
    if candidate:
        s.update({k: v for k, v in candidate.items()
                  if k not in ("block_q", "block_kv")})
    ct = s.get("conv_tiling")
    if isinstance(ct, dict):
        ct = ConvTiling(**ct)
    df = s.get("dataflow")
    if isinstance(df, str):
        df = Dataflow(df)
    block = tuple(s["block"]) if s.get("block") else None
    attn = None
    if s.get("attn"):
        a = dict(s["attn"])
        if candidate:
            for k in ("block_q", "block_kv"):
                if k in candidate:
                    a[k] = candidate[k]
        attn = AttentionSpec(**a)
    # Keep the op's strip_storage consistent with a substituted tiling.
    strip = s.get("strip_storage")
    if ct is not None and candidate and "conv_tiling" in candidate:
        strip = ct.strip_storage
    has_bypass = s.get("fuse_bypass") and "bypass" in r.operands
    return ProgramOp(
        index=0, name=r.name, kernel=r.kind,
        in_region=_RID["in"], out_region=_RID["out"],
        param_key="p" if ("w" in r.operands or r.kind == "embed") else None,
        param_key_b="p_b" if "b" in r.operands and r.kind == "norm" else None,
        bypass_region=_RID["bypass"] if has_bypass else None,
        k_region=_RID["k"] if "k" in r.operands else None,
        v_region=_RID["v"] if "v" in r.operands else None,
        in2_region=_RID["in2"] if "in2" in r.operands else None,
        k_cache_region=_RID["k_cache"] if "k_cache" in r.operands else None,
        v_cache_region=_RID["v_cache"] if "v_cache" in r.operands else None,
        stride=s.get("stride", 1), pad=s.get("pad", 0),
        window=s.get("window", 0),
        fuse_bias=s.get("fuse_bias", False),
        fuse_activation=s.get("fuse_activation"),
        fuse_bypass=bool(has_bypass),
        bypass_first=s.get("bypass_first", True),
        fuse_pool=tuple(s["fuse_pool"]) if s.get("fuse_pool") else None,
        strip_storage=strip, dataflow=df, conv_tiling=ct, block=block,
        attn=attn, norm_kind=s.get("norm_kind"),
        flatten_input=s.get("flatten_input", False),
        transpose_w=s.get("transpose_w", False),
        flops=r.flops, traffic_bytes=r.traffic_bytes,
        exec_time_s=r.modeled_time_s)


def _synth(shape, dtype, key, *, vocab: int | None = None):
    shape = tuple(shape)
    jdt = jnp.dtype(dtype)
    if jdt.kind in "iu":
        return jax.random.randint(key, shape, 0, max(vocab or 2, 2),
                                  dtype=jdt)
    if jdt == jnp.bool_:
        return jnp.ones(shape, bool)
    return jax.random.normal(key, shape, jnp.float32).astype(jdt) * 0.1


def synth_operands(record: TraceRecord | dict, seed: int = 0
                   ) -> tuple[dict, dict]:
    """(regions, params) with random arrays at the recorded shapes,
    deterministic per seed.  Token inputs (int dtypes) draw from the
    recorded embed-table row count when present."""
    r = record if isinstance(record, TraceRecord) else \
        TraceRecord.from_dict(record)
    vocab = r.operands["w"][0][0] if r.kind == "embed" else None
    keys = iter(jax.random.split(jax.random.PRNGKey(seed), 16))
    regions: dict[int, jax.Array] = {}
    for role in ("in", "k", "v", "in2", "bypass", "k_cache", "v_cache"):
        if role in r.operands:
            shape, dt = r.operands[role]
            regions[_RID[role]] = _synth(shape, dt, next(keys), vocab=vocab)
    params: dict = {}
    if "w" in r.operands:
        flag = r.operands.get("param_dict")
        w = _synth(*r.operands["w"], next(keys))
        if flag and flag[1] == "dict":
            params["p"] = {"w": w}
            if "b" in r.operands:
                params["p"]["b"] = _synth(*r.operands["b"], next(keys))
        else:
            params["p"] = w
            if "b" in r.operands:          # norm bias rides separately
                params["p_b"] = _synth(*r.operands["b"], next(keys))
    return regions, params


def replay_outputs(record: TraceRecord | dict, *,
                   candidate: dict | None = None, impl: str = "auto",
                   interpret: bool | None = None, seed: int = 0):
    """Execute the rebuilt op once; returns its output array (decode
    ops: the attention output, cache updates discarded).  Same seed =>
    same synthetic operands, so two candidates' outputs are directly
    comparable."""
    out, _ = replay_record(record, candidate=candidate, impl=impl,
                           interpret=interpret, seed=seed, measure=False)
    return out


def replay_record(record: TraceRecord | dict, *,
                  candidate: dict | None = None, impl: str = "auto",
                  interpret: bool | None = None, repeats: int = 3,
                  measure: bool = True, seed: int = 0):
    """(output, measured_time_s | None) for one rebuilt op.

    The measurement is ``_time_thunk``'s min-of-repeats with
    block-until-ready, the same clock the trace recorder uses — so a
    replayed incumbent reproduces its traced wallclock up to noise, and
    candidates are ranked on an equal footing.
    """
    r = record if isinstance(record, TraceRecord) else \
        TraceRecord.from_dict(record)
    if r.kind in _FAMILY_KERNELS:
        # Family ops carry whole-block param subtrees and persistent
        # state rows the record does not serialize, so they cannot be
        # rebuilt in isolation.  The autotuner never proposes
        # candidates for them (autotune.TUNABLE), and the error-table
        # path (``error_report``) is record-dict based — calibration
        # still fits these kinds from their traced measurements.
        raise NotImplementedError(
            f"replay of family op kind {r.kind!r}: not rebuildable "
            f"from a trace record (block param subtree + persistent "
            f"state); these kinds are identity-only in the autotuner")
    op = op_from_record(r, candidate)
    regions, params = synth_operands(r, seed)
    if r.kind == "decode_attention":
        slots = r.operands["k_cache"][0][0]
        cache_len = r.operands["k_cache"][0][1]
        pos = jnp.asarray(r.extras.get("pos", [cache_len // 2] * slots),
                          jnp.int32)
        live = jnp.asarray(r.extras.get("live", [True] * slots), bool)

        def thunk():
            return _run_decode_attention(
                op, regions[op.in_region], regions[op.k_region],
                regions[op.v_region], regions[op.k_cache_region],
                regions[op.v_cache_region], pos, live, impl=impl,
                interpret=interpret)

        out = thunk()[0]
    else:
        def thunk():
            return _run_op(op, regions[op.in_region], regions, params,
                           impl=impl, interpret=interpret)

        out = thunk()
    t = _time_thunk(thunk, repeats) if measure else None
    return out, t


def error_report(trace, calibrate: bool = True) -> tuple[list[dict], str]:
    """(rows, rendered table) of measured-vs-predicted error per kernel
    kind for a trace — the harness's headline artifact.  With
    ``calibrate`` the table also shows the post-fit error of
    ``core/cost.fit_cost_model`` on the same records."""
    from ..core.cost import error_table, fit_cost_model, format_error_table
    recs = trace.record_dicts()
    model = fit_cost_model(recs) if calibrate else None
    rows = error_table(recs, model)
    return rows, format_error_table(rows)


def main(argv=None) -> int:
    from .executor import ExecutorTrace
    ap = argparse.ArgumentParser(
        description="measured-vs-predicted error table for a trace")
    ap.add_argument("trace", help="JSONL trace from trace_program(...).save")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the least-squares fit column")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the table rows as JSON")
    args = ap.parse_args(argv)
    trace = ExecutorTrace.load(args.trace)
    rows, table = error_report(trace, calibrate=not args.no_calibrate)
    print(f"trace {args.trace}: program {trace.program} on {trace.hw} "
          f"(impl={trace.impl}, repeats={trace.repeats})")
    print(table)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

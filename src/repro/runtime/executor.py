"""Program executor — runs the compiler's instruction stream (§5.2).

The Snowflake accelerator executes exactly what the compiler emitted;
here the executor walks a ``core/program.py::Program`` and dispatches
each op to the Pallas kernels with the schedule's *pre-resolved*
decisions — conv strip tiling, strip storage, loop order, matmul block,
attention (block_q, block_kv), and the fused epilogue flags.  The LM
families dispatch through the same loop as the CNNs: ``embed`` /
``norm`` / ``flash_attention`` / ``mul`` ops joined ``conv2d`` /
``matmul`` / the pools when the transformer lowering landed.

Stateful Programs (the serving pair) add a **ProgramState** carrier:
the persistent KV-cache buffers (keyed by the allocator's persistent
region ids) plus the per-slot sequence lengths.  ``run_prefill``
executes the prefill Program for one admitted request, writing each
block's K/V into the cache regions at the admitted slot;
``run_decode`` advances every slot by one token through the
``decode_attention`` ops.  Both thread the state functionally —
(params, x, state) -> (out, new_state) — and their jitted wrappers
donate the state so XLA updates the cache buffers in place.

Invariants:

* **Nothing is re-derived at run time.**  Every kernel call below
  passes the op's resolved schedule through verbatim (``tiling=``,
  ``block=``, ``block_q=``/``block_kv=``, ``strip_storage=``); the
  executor never calls a chooser.  If a kernel needs a decision the op
  does not carry, that is a lowering bug in core/program.py.
* **Region ids are allocator-owned.**  The region file below is keyed
  by the §5.1 ``RegionPlan`` ids embedded in the ops; the executor
  reads ``op.in_region``/``k_region``/``v_region``/``bypass_region``
  (and for stateful ops ``k_cache_region``/``v_cache_region``) and
  writes ``op.out_region``, and never maps a name to an id itself.
* **``run`` is functionally pure** (params, x -> output) and
  jit-compatible; models wrap it in ``jax.jit`` per (program, impl)
  via ``jitted_runner``.

``x`` is whatever the program's input region expects: an (B, H, W, C)
image batch for CNN programs, an (B, S) int32 token batch for LM
programs (the first op is then the ``embed`` gather).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

import numpy as np

from ..core.program import Program, ProgramOp, ProgramPair
from ..core.regions import PAGE_TABLE_REGION, PagedPlan, pages_for_len
from ..kernels.conv2d import avgpool2d_ref, conv2d, maxpool2d_ref
from ..kernels.decode_attention import (decode_attention,
                                        paged_decode_attention, ring_kv_len,
                                        ring_positions)
from ..kernels.flash_attention import flash_attention
from ..kernels.matmul import matmul

__all__ = ["run", "jitted_runner", "ProgramState", "init_program_state",
           "run_prefill", "run_prefill_chunk", "run_decode",
           "jitted_prefill_runner", "jitted_chunk_runner",
           "jitted_decode_runner", "PagePool", "paged_pool_regions",
           "sync_page_table", "apply_page_copies", "TraceRecord",
           "ExecutorTrace", "trace_program", "OpTimingSampler"]


def _param(params, key: str | None):
    """Resolve a ProgramOp param path.

    ``"layer_03"``       -> params["layer_03"]           (CNN groups)
    ``"blocks/wq:3"``    -> params["blocks"]["wq"][3]    (stacked LM blocks)
    ``"blocks:3"``       -> every leaf of params["blocks"] at index 3
                            (whole-block group path — coarse family ops)
    ``"final_norm"``     -> params["final_norm"]
    """
    if key is None:
        return None
    path, _, idx = key.partition(":")
    p = params
    for part in path.split("/"):
        p = p[part]
    if not idx:
        return p
    i = int(idx)
    if isinstance(p, dict):
        return jax.tree.map(lambda a: a[i], p)
    return p[i]


def _attention_heads(op: ProgramOp, regions: dict):
    """Reshape the flat q/k/v regions to per-head layout and apply RoPE
    when the spec says so — the shared front half of every prefill
    flash dispatch (whole and chunked), so the two can never drift."""
    # Lazy import: models.common is the one shared home of the rotary
    # helpers and models/cnn.py imports this module at load time.
    from ..models.common import Rotary, apply_rope
    a = op.attn
    q, k, v = regions[op.in_region], regions[op.k_region], regions[op.v_region]
    B, S = q.shape[0], q.shape[1]
    q = q.reshape(B, S, a.heads, a.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, a.kv_heads, a.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, a.kv_heads, a.head_dim).transpose(0, 2, 1, 3)
    if a.rope_theta:
        cos, sin = Rotary(a.head_dim, a.rope_theta).freqs(jnp.arange(S))
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    return q, k, v


def _run_attention(op: ProgramOp, regions: dict, *, impl: str,
                   interpret: bool | None, return_kv: bool = False):
    """Dispatch one flash_attention op: reshape the flat q/k/v regions
    to per-head layout, apply RoPE when the spec says so, and call the
    kernel with the schedule's exact (block_q, block_kv).

    ``return_kv=True`` additionally hands back the per-head (post-RoPE)
    K and V — exactly what a cache-writing prefill op stores in its
    persistent regions."""
    a = op.attn
    q, k, v = _attention_heads(op, regions)
    B, S = q.shape[0], q.shape[2]
    out = flash_attention(q, k, v, causal=a.causal, window=a.window,
                          block_q=a.block_q, block_kv=a.block_kv,
                          impl=impl, interpret=interpret)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, a.heads * a.head_dim)
    if return_kv:
        return out, k, v
    return out


def _run_norm(op: ProgramOp, src: jax.Array, params) -> jax.Array:
    from ..models.common import layer_norm, rms_norm
    w = _param(params, op.param_key)
    if op.norm_kind == "layernorm":
        return layer_norm(src, w, _param(params, op.param_key_b))
    if op.norm_kind == "nonparametric":
        return layer_norm(src)
    return rms_norm(src, w)


_FAMILY_KERNELS = ("wkv", "ssm_scan", "moe_dispatch", "cross_attention")


def _write_state_row(caches: dict, rid: int, val: jax.Array, slot) -> None:
    """Scatter a prefill op's (1, ...) final state into the
    (slots, ...) persistent region at the admitted slot."""
    buf = caches[rid]
    row = val[0].astype(buf.dtype)
    caches[rid] = jax.lax.dynamic_update_slice(
        buf, row[None], (slot,) + (0,) * row.ndim)


def _run_family_op(op: ProgramOp, src: jax.Array, regions: dict, params,
                   caches: dict | None, *, slot=None, length=None,
                   live=None, impl: str, interpret: bool | None):
    """Dispatch one family op (coarse recurrent block, MoE dispatch, or
    cross-attention over read-only encoder memory).

    Prefill and decode share one arm per kernel, split on the operand
    rank — (B, S, D) is a prefill pass, (slots, D) a decode tick —
    because the instruction stream is the only difference the lowering
    leaves between the two.  State-carrying ops resolve their buffers
    through ``op.state_regions`` (the allocator's generic persistent
    rids, in the family's documented order) and never assume a KV
    shape; prefill scatters the block's final state at the admitted
    slot, decode reads/writes all slots with dead ones masked to their
    old rows via ``live``.  ``caches=None`` (stateless ``run``) skips
    the writes — the recurrent blocks still compute from their zero
    init, matching the legacy scan forward."""
    if op.kernel == "moe_dispatch":
        from ..models.moe import moe_mlp
        c = dict(op.op_cfg)
        p = _param(params, op.param_key)
        shp = src.shape
        vc = None
        if length is not None and src.ndim == 3:
            # Right-padded prefill rows: pad tokens must not claim
            # expert capacity (models/moe sentinel-expert path).
            vc = jnp.asarray(length, jnp.int32)
        out, _ = moe_mlp(src.reshape(-1, shp[-1]), p["router"],
                         p["w_gate"], p.get("w_up", p["w_gate"]),
                         p["w_down"], top_k=c["top_k"],
                         capacity_factor=c["capacity_factor"],
                         activation=c["activation"], gated=c["gated"],
                         valid_count=vc)
        out = out.reshape(shp).astype(src.dtype)
        if op.fuse_bypass and op.bypass_region is not None:
            out = out + regions[op.bypass_region]
        return out
    if op.kernel == "cross_attention":
        if caches is None:
            raise ValueError(
                f"op {op.name} reads persistent encoder memory; use "
                f"run_prefill/run_decode with a ProgramState")
        a = op.attn
        ck, cv = caches[op.k_cache_region], caches[op.v_cache_region]
        if src.ndim == 3:                         # prefill: one slot
            B, S = src.shape[:2]
            q = src.reshape(B, S, a.heads, a.head_dim).transpose(0, 2, 1, 3)
            km = jax.lax.dynamic_slice_in_dim(ck, slot, 1, axis=0)
            vm = jax.lax.dynamic_slice_in_dim(cv, slot, 1, axis=0)
            out = flash_attention(
                q, km.transpose(0, 2, 1, 3).astype(q.dtype),
                vm.transpose(0, 2, 1, 3).astype(q.dtype),
                causal=False, block_q=a.block_q, block_kv=a.block_kv,
                impl=impl, interpret=interpret)
            return (out.transpose(0, 2, 1, 3)
                    .reshape(B, S, a.heads * a.head_dim))
        B = src.shape[0]                          # decode: all slots
        q = src.reshape(B, a.heads, a.head_dim)
        out = decode_attention(
            q, ck.transpose(0, 2, 1, 3).astype(q.dtype),
            cv.transpose(0, 2, 1, 3).astype(q.dtype),
            block_kv=a.block_kv, impl=impl, interpret=interpret)
        return out.reshape(B, a.heads * a.head_dim)
    # coarse recurrent block ops ("wkv" | "ssm_scan")
    p = _param(params, op.param_key)
    if src.ndim == 3:                             # prefill pass
        if op.kernel == "wkv":
            from ..models.rwkv import block_prefill
        else:
            from ..models.zamba2 import block_prefill
        out, states = block_prefill(src, p, impl=impl, length=length)
        if caches is not None and op.state_regions:
            for rid, val in zip(op.state_regions, states):
                _write_state_row(caches, rid, val, slot)
        return out
    if caches is None:
        raise ValueError(
            f"op {op.name} needs a ProgramState (persistent state "
            f"regions); use run_decode for decode Programs")
    states = [caches[r] for r in op.state_regions]
    if op.kernel == "wkv":
        from ..models.rwkv import block_decode
        out, new = block_decode(src, p, *states)
    else:
        from ..models.zamba2 import block_decode
        out, new = block_decode(src, p, states[0], states[1], impl=impl)
    for rid, old, fresh in zip(op.state_regions, states, new):
        fresh = fresh.astype(old.dtype)
        if live is not None:
            keep = live.reshape((-1,) + (1,) * (old.ndim - 1))
            fresh = jnp.where(keep, fresh, old)
        caches[rid] = fresh
    return out


def _run_op(op: ProgramOp, src: jax.Array, regions: dict, params, *,
            impl: str, interpret: bool | None, pos=None) -> jax.Array:
    """Dispatch one (stateless) op with its pre-resolved schedule."""
    if op.kernel == "conv2d":
        p = _param(params, op.param_key)
        bypass = (regions[op.bypass_region]
                  if op.fuse_bypass and op.bypass_region is not None
                  else None)
        return conv2d(
            src, p["w"], stride=op.stride, pad=op.pad,
            bias=p["b"] if op.fuse_bias else None,
            activation=op.fuse_activation, bypass=bypass,
            bypass_first=op.bypass_first, fuse_pool=op.fuse_pool,
            strip_storage=op.strip_storage or "auto",
            tiling=op.conv_tiling, dataflow=op.dataflow,
            impl=impl, interpret=interpret)
    if op.kernel == "matmul":
        p = _param(params, op.param_key)
        w = p["w"] if isinstance(p, dict) else p
        if op.transpose_w:
            w = w.T
        if op.flatten_input:
            src = src.reshape(src.shape[0], -1)
        bypass = (regions[op.bypass_region]
                  if op.fuse_bypass and op.bypass_region is not None
                  else None)
        if bypass is not None and op.flatten_input:
            bypass = bypass.reshape(bypass.shape[0], -1)
        return matmul(
            src, w,
            bias=(p["b"] if isinstance(p, dict) and op.fuse_bias
                  else None),
            activation=op.fuse_activation, bypass=bypass,
            dataflow=op.dataflow, block=op.block,
            impl=impl, interpret=interpret)
    if op.kernel == "flash_attention":
        return _run_attention(op, regions, impl=impl, interpret=interpret)
    if op.kernel == "embed":
        table = _param(params, op.param_key)
        out = table[src]
        if op.param_key_b is not None:
            pe = _param(params, op.param_key_b)
            if src.ndim >= 2:      # prefill/stateless: rows [0, S)
                out = out + pe[: src.shape[1]][None].astype(out.dtype)
            else:                  # decode: each slot's absolute position
                out = out + pe[pos].astype(out.dtype)
        return out
    if op.kernel == "norm":
        return _run_norm(op, src, params)
    if op.kernel == "mul":
        return src * regions[op.in2_region]
    if op.kernel == "add":
        return src + regions[op.in2_region]
    if op.kernel == "maxpool":
        return maxpool2d_ref(src, window=op.window, stride=op.stride,
                             pad=op.pad)
    if op.kernel == "avgpool":
        return avgpool2d_ref(src, window=op.window, stride=op.stride,
                             pad=op.pad)
    raise NotImplementedError(f"unknown program kernel {op.kernel}")


def run(program: Program, params, x: jax.Array, *, impl: str = "auto",
        interpret: bool | None = None) -> jax.Array:
    """Execute ``program`` against ``params`` on input ``x``.

    x: (B, H, W, C) for CNN programs, (B, S) int32 tokens for LM
    programs.  Returns the final op's output (the array living in
    ``program.output_region``).  Cache-writing prefill ops run as plain
    flash attention here (stateless execution ignores the persistent
    regions); ``decode_attention`` ops need state and are rejected —
    use ``run_decode``.
    """
    regions: dict[int, jax.Array] = {program.input_region: x}
    for op in program.ops:
        if op.kernel == "decode_attention":
            raise ValueError(
                f"op {op.name} needs a ProgramState (persistent KV "
                f"regions); use run_decode for decode Programs")
        if op.kernel in _FAMILY_KERNELS:
            regions[op.out_region] = _run_family_op(
                op, regions[op.in_region], regions, params, None,
                impl=impl, interpret=interpret)
            continue
        regions[op.out_region] = _run_op(op, regions[op.in_region], regions,
                                         params, impl=impl,
                                         interpret=interpret)
    return regions[program.output_region]


# --- stateful Programs (serving prefill/decode pair) -------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass
class ProgramState:
    """Runtime carrier for a Program pair's persistent regions.

    ``caches`` maps the allocator's persistent region ids to their
    buffers — for the LM pair, (slots, cache_len, kv_heads, head_dim)
    per block and cache side, where cache_len is max_len or the
    attention window (whichever the §5.1 plan sized the region at);
    ``lengths`` is the per-slot sequence length (the decode ops'
    position operand, counting absolute tokens even once the ring has
    wrapped).  Registered as a pytree
    so the jitted prefill/decode runners can donate it and XLA aliases
    the cache updates in place.
    """

    caches: dict[int, jax.Array]
    lengths: jax.Array               # (slots,) int32

    def tree_flatten(self):
        rids = tuple(sorted(self.caches))
        return (tuple(self.caches[r] for r in rids) + (self.lengths,), rids)

    @classmethod
    def tree_unflatten(cls, rids, leaves):
        *bufs, lengths = leaves
        return cls(dict(zip(rids, bufs)), lengths)


def init_program_state(pair: ProgramPair | Program) -> ProgramState:
    """Allocate zeroed persistent buffers from the plan's persistent
    regions (their shape/dtype is allocator-recorded identity)."""
    plan = (pair.decode.plan if isinstance(pair, ProgramPair) else pair.plan)
    name = (pair.decode.name if isinstance(pair, ProgramPair) else pair.name)
    persistent = plan.persistent_regions()
    if not persistent:
        raise ValueError(
            f"program {name} reserves no persistent regions "
            f"({len(plan.regions)} transient only) — stateful execution "
            f"needs a plan extended via regions.extend_with_persistent "
            f"(e.g. transformer.compile_program_pair)")
    caches = {r.rid: jnp.zeros(r.shape, jnp.dtype(r.dtype))
              for r in persistent}
    # Paged plans key slot count off the page table (pools are
    # slot-agnostic); contiguous plans off any cache region's axis 0.
    pt = next((r for r in persistent if r.name == PAGE_TABLE_REGION), None)
    slots = (pt if pt is not None else persistent[0]).shape[0]
    return ProgramState(caches, jnp.zeros((slots,), jnp.int32))


def _write_prefill_cache(caches: dict, op: ProgramOp, k, v, slot,
                         length) -> None:
    """Store a prefill op's per-head K/V — (1, KVh, S, hd) — into the
    (slots, cache_len, KV, hd) cache regions at the admitted slot.

    A window-sized region (cache_len < S, the §5.1 rolling-window plan)
    receives the **ring layout** the decode ops expect, via the shared
    ``ring_positions`` rule: ring slot j holds the latest prompt
    position ``p < length`` with ``p % cache_len == j`` — the same
    keep-last-W conversion ``to_graph``'s cache export performs,
    generalized to a runtime ``length``.  Every ring slot is written
    (slots with no valid position duplicate an early row, overwritten
    by decode before ``ring_kv_len`` ever admits them), so re-admission
    into a previously used slot can never leak a dead request's stale
    rows."""
    for rid, val in ((op.k_cache_region, k), (op.v_cache_region, v)):
        buf = caches[rid]
        row = val[0].transpose(1, 0, 2).astype(buf.dtype)     # (S, KV, hd)
        S, cache_len = row.shape[0], buf.shape[1]
        if cache_len < S:
            row = row[ring_positions(length, cache_len, S)]
        caches[rid] = jax.lax.dynamic_update_slice(
            buf, row[None], (slot, 0, 0, 0))


def _write_prefill_cache_paged(caches: dict, op: ProgramOp, k, v, slot,
                               length, write_from) -> None:
    """Paged flavor of the prefill cache write: scatter the prompt's
    K/V into the slot's table-mapped pool pages, one whole page per
    scatter row.

    ``write_from`` is the shared-prefix redirect (a page multiple): the
    pages covering rows ``< write_from`` are COW-mapped from a donor
    slot and must not be touched, so their scatter destination is the
    null page 0 — the write stays dense and branch-free.  Unallocated
    tail entries are already 0 in the table and land there too.  Rows
    at ``>= length`` (prompt right-padding) are zeroed before the write
    so an int8 tail page's scale is set by real rows only."""
    a = op.attn
    pg = a.page_size
    pt = caches[op.page_table_region]
    pt_row = jax.lax.dynamic_slice_in_dim(pt, slot, 1, axis=0)[0]
    quant = op.k_scale_region is not None
    scales = ((op.k_scale_region, op.v_scale_region) if quant
              else (None, None))
    for rid, srid, val in ((op.k_cache_region, scales[0], k),
                          (op.v_cache_region, scales[1], v)):
        buf = caches[rid]
        row = val[0].transpose(1, 0, 2)                       # (S, KV, hd)
        S = row.shape[0]
        row = jnp.where(jnp.arange(S)[:, None, None] < length, row, 0)
        pages = row.reshape(S // pg, pg, row.shape[1], row.shape[2])
        dest = jnp.where(jnp.arange(S // pg) * pg
                         >= jnp.asarray(write_from, jnp.int32),
                         pt_row, 0)
        if quant:
            from ..core.quant import int8_quantize_pages
            q, sc = int8_quantize_pages(pages)
            caches[rid] = buf.at[dest].set(q)
            caches[srid] = caches[srid].at[dest].set(sc)
        else:
            caches[rid] = buf.at[dest].set(pages.astype(buf.dtype))


def run_prefill(program: Program, params, tokens: jax.Array,
                state: ProgramState, slot, length, write_from=0, *,
                impl: str = "auto", interpret: bool | None = None):
    """Execute the prefill Program for one admitted request.

    tokens: (1, max_len) int32, the prompt right-padded (rows past
    ``length`` are masked downstream by the per-slot length, so their
    K/V content is inert).  Writes each block's K/V into the persistent
    cache regions at ``slot`` — window-sized regions get the rolling
    (ring) layout, see ``_write_prefill_cache`` — sets
    ``lengths[slot] = length`` and returns
    (logits (1, max_len, vocab), new_state).
    """
    regions: dict[int, jax.Array] = {program.input_region: tokens}
    caches = dict(state.caches)
    for op in program.ops:
        src = regions[op.in_region]
        if op.kernel == "flash_attention" and op.k_cache_region is not None:
            out, k, v = _run_attention(op, regions, impl=impl,
                                       interpret=interpret, return_kv=True)
            if op.page_table_region is not None:
                _write_prefill_cache_paged(caches, op, k, v, slot, length,
                                           write_from)
            else:
                _write_prefill_cache(caches, op, k, v, slot, length)
            regions[op.out_region] = out
            continue
        if op.kernel in _FAMILY_KERNELS:
            regions[op.out_region] = _run_family_op(
                op, src, regions, params, caches, slot=slot,
                length=length, impl=impl, interpret=interpret)
            continue
        regions[op.out_region] = _run_op(op, src, regions, params,
                                         impl=impl, interpret=interpret)
    lengths = state.lengths.at[slot].set(jnp.asarray(length, jnp.int32))
    return regions[program.output_region], ProgramState(caches, lengths)


# --- chunked prefill (throughput-grade serving) ------------------------------------
def _run_attention_chunk(op: ProgramOp, regions: dict, caches: dict,
                         slot, start, *, impl: str,
                         interpret: bool | None):
    """One flash op of a *chunk* prefill pass: identical q/k/v + RoPE
    front half as the whole-prefill dispatch, but the K/V columns at
    positions ``< start`` are substituted from the slot's persistent
    cache rows before the kernel call.

    The chunk pass always runs over the full (B, max_len) padded token
    buffer — embed/norm/matmul are position-local, so the fresh rows at
    ``>= start`` are bitwise what a whole prefill computes there, and
    the substituted history rows were themselves written by earlier
    chunks (induction).  Feeding the *same* (block_q, block_kv) flash
    kernel the same shapes keeps the reduction order identical, so a
    chunked prefill reproduces the whole-prefill outputs bit for bit at
    its chunk rows.

    History substitution per region plan:

    * contiguous — ``cache[slot]`` is already position-indexed;
    * rolling ring — position ``p`` lives at ring row ``p %
      cache_len``, valid only for the window ``start - cache_len <= p <
      start`` (older positions are window-masked inside the kernel, so
      their column content is inert);
    * paged — gather through the slot's page-table row (rows whose page
      is still null can only be positions ``>= start``, never
      selected).
    """
    a = op.attn
    q, k, v = _attention_heads(op, regions)
    B, S = q.shape[0], q.shape[2]
    pos = jnp.arange(S)
    if op.page_table_region is not None:
        pg = a.page_size
        pt_rows = caches[op.page_table_region][slot]   # (B, pages_per_slot)
        page = jnp.take_along_axis(pt_rows, pos[None] // pg, axis=1)
        hk = caches[op.k_cache_region][page, pos[None] % pg]
        hv = caches[op.v_cache_region][page, pos[None] % pg]
        valid = pos[None] < start[:, None]
    else:
        buf_k, buf_v = caches[op.k_cache_region], caches[op.v_cache_region]
        cache_len = buf_k.shape[1]
        ring = pos % cache_len
        hk = buf_k[slot][:, ring]                      # (B, S, KV, hd)
        hv = buf_v[slot][:, ring]
        valid = ((pos[None] < start[:, None])
                 & (pos[None] >= start[:, None] - cache_len))
    m = valid[:, None, :, None]                        # (B, 1, S, 1)
    k = jnp.where(m, hk.transpose(0, 2, 1, 3).astype(k.dtype), k)
    v = jnp.where(m, hv.transpose(0, 2, 1, 3).astype(v.dtype), v)
    out = flash_attention(q, k, v, causal=a.causal, window=a.window,
                          block_q=a.block_q, block_kv=a.block_kv,
                          impl=impl, interpret=interpret)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, a.heads * a.head_dim)
    return out, k, v


def _write_chunk_cache(caches: dict, op: ProgramOp, k, v, slot, start,
                       stop, length) -> None:
    """Store a chunk's fresh K/V rows — (B, KVh, S, hd), rows ``[start,
    stop)`` per batch entry — into the (slots, cache_len, KV, hd) cache
    regions.

    Contiguous regions take the chunk rows in place; the final chunk
    (``stop == length``) extends the write through the padded tail so
    the slot's region ends bitwise-equal to a whole prefill's
    unconditional full-row write.  Window-sized regions (cache_len < S)
    take the ring layout: ring row ``j`` receives the latest chunk
    position ``p < min(stop, length)`` with ``p % cache_len == j``; the
    first chunk seeds every ring row with fresh row 0 — the same
    duplicate-early-row rule ``ring_positions`` applies for ring rows
    no prompt position covers — so re-admission hygiene and whole-
    prefill bit-parity both hold."""
    for rid, val in ((op.k_cache_region, k), (op.v_cache_region, v)):
        buf = caches[rid]
        row = val.transpose(0, 2, 1, 3).astype(buf.dtype)   # (B, S, KV, hd)
        S, cache_len = row.shape[1], buf.shape[1]
        old = buf[slot]                                     # (B, cl, KV, hd)
        if cache_len == S:
            wstop = jnp.where(stop >= length, S, stop)
            pos = jnp.arange(S)
            m = (pos[None] >= start[:, None]) & (pos[None] < wstop[:, None])
            new = jnp.where(m[..., None, None], row, old)
        else:
            wstop = jnp.minimum(stop, length)
            j = jnp.arange(cache_len)
            last = (wstop - 1)[:, None]
            p = j[None] + ((last - j[None]) // cache_len) * cache_len
            written = (p >= start[:, None]) & (p < wstop[:, None])
            gathered = jax.vmap(lambda r, idx: r[idx])(
                row, jnp.clip(p, 0, S - 1))
            seed = jnp.broadcast_to(row[:, :1], old.shape)
            base = jnp.where((start == 0)[:, None, None, None], seed, old)
            new = jnp.where(written[..., None, None], gathered, base)
        caches[rid] = buf.at[slot].set(new)


def _write_chunk_cache_paged(caches: dict, op: ProgramOp, k, v, slot,
                             start, stop, length, write_from) -> None:
    """Paged flavor of the chunk cache write: scatter the chunk rows
    through the slot's page-table row, one row per scatter entry.

    Rows outside ``[max(start, write_from), stop)`` — and every row on
    the final chunk past ``length`` (prompt right-padding, zeroed as in
    the whole-prefill write) — redirect to the null page 0, so the
    scatter stays dense and COW-shared prefix pages are never touched.
    int8 pools are rejected upstream (``ProgramPair.chunk_blocker``):
    their page scale is set by whole-page quantization, which a
    row-granular chunk write would silently re-base."""
    a = op.attn
    pg = a.page_size
    pt_rows = caches[op.page_table_region][slot]       # (B, pages_per_slot)
    if op.k_scale_region is not None:
        raise NotImplementedError(
            "chunked prefill over int8 paged KV: page scales are "
            "whole-page decisions (see ProgramPair.chunk_blocker)")
    for rid, val in ((op.k_cache_region, k), (op.v_cache_region, v)):
        buf = caches[rid]                              # (n_pages, pg, KV, hd)
        row = val.transpose(0, 2, 1, 3)                # (B, S, KV, hd)
        S = row.shape[1]
        pos = jnp.arange(S)
        wstop = jnp.where(stop >= length, S, stop)
        write = ((pos[None] >= jnp.maximum(start, write_from)[:, None])
                 & (pos[None] < wstop[:, None]))
        rowv = jnp.where(pos[None, :, None, None]
                         < length[:, None, None, None], row, 0)
        page = jnp.where(
            write, jnp.take_along_axis(pt_rows, pos[None] // pg, axis=1), 0)
        caches[rid] = buf.at[page, pos[None] % pg].set(rowv.astype(buf.dtype))


def run_prefill_chunk(program: Program, params, tokens: jax.Array,
                      state: ProgramState, slot, start, stop, length,
                      write_from=None, *, impl: str = "auto",
                      interpret: bool | None = None):
    """Execute the prefill Program for one *chunk* of each of B
    in-flight admissions — rows ``[start[i], stop[i])`` of slot
    ``slot[i]`` — against the full (B, max_len) padded token buffers.

    All operands past ``tokens`` are (B,) int32 vectors: ``length`` is
    each prompt's total row count (``stop == length`` marks the final
    chunk) and ``write_from`` the paged shared-prefix redirect.  Each
    flash op substitutes the slot's already-written cache rows for the
    K/V columns below ``start`` (see ``_run_attention_chunk``), then
    writes the chunk rows back; ``lengths[slot]`` advances to ``stop``
    so the next chunk (or the first decode tick after the final chunk)
    continues exactly where this one stopped.  Returns (logits (B,
    max_len, vocab), new_state) — only rows ``[start, stop)`` of the
    logits are chunk-fresh; the final chunk's ``length - 1`` row is the
    one the engine samples the first token from.

    A full-prompt "chunk" (start 0, stop == length) degenerates to
    ``run_prefill`` semantics, bit for bit."""
    regions: dict[int, jax.Array] = {program.input_region: tokens}
    caches = dict(state.caches)
    slot = jnp.asarray(slot, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    write_from = (jnp.zeros_like(start) if write_from is None
                  else jnp.asarray(write_from, jnp.int32))
    for op in program.ops:
        src = regions[op.in_region]
        if op.kernel == "flash_attention" and op.k_cache_region is not None:
            out, k, v = _run_attention_chunk(op, regions, caches, slot,
                                             start, impl=impl,
                                             interpret=interpret)
            if op.page_table_region is not None:
                _write_chunk_cache_paged(caches, op, k, v, slot, start,
                                         stop, length, write_from)
            else:
                _write_chunk_cache(caches, op, k, v, slot, start, stop,
                                   length)
            regions[op.out_region] = out
            continue
        regions[op.out_region] = _run_op(op, src, regions, params,
                                         impl=impl, interpret=interpret)
    lengths = state.lengths.at[slot].set(stop)
    return regions[program.output_region], ProgramState(caches, lengths)


def jitted_chunk_runner(program: Program, impl: str = "auto",
                        interpret: bool | None = None):
    """Compiled chunk prefill: (params, tokens, state, slot, start,
    stop, length, write_from) -> (logits, state), state donated.  One
    executable per in-flight batch width B (XLA re-specializes on the
    leading shape; the engine's chunk batches are small and repeat)."""
    def make():
        def _run(params, tokens, state, slot, start, stop, length,
                 write_from, _program=program):
            return run_prefill_chunk(_program, params, tokens, state,
                                     slot, start, stop, length,
                                     write_from, impl=impl,
                                     interpret=interpret)
        return jax.jit(_run, donate_argnums=(2,))
    return _cached_runner((id(program), impl, interpret, "chunk"), make)


def _run_decode_attention(op: ProgramOp, src: jax.Array, k_src: jax.Array,
                          v_src: jax.Array, ck: jax.Array, cv: jax.Array,
                          pos: jax.Array, live: jax.Array, *, impl: str,
                          interpret: bool | None):
    """One decode_attention step against explicit cache buffers: RoPE
    the new q/k at each slot's absolute position, write the new K/V row
    at ``position % cache_len`` (masked per-slot by ``live``), attend
    over the ring-valid rows.  Returns (out (B, heads*head_dim),
    new_k_cache, new_v_cache).  Shared verbatim by ``run_decode`` and
    the replay harness, so a replayed op cannot drift from the
    executor."""
    from ..models.common import Rotary, apply_rope
    a = op.attn
    B = src.shape[0]
    q = src.reshape(B, a.heads, a.head_dim)
    k_new = k_src.reshape(B, a.kv_heads, a.head_dim)
    v_new = v_src.reshape(B, a.kv_heads, a.head_dim)
    if a.rope_theta:
        cos, sin = Rotary(a.head_dim, a.rope_theta).freqs(pos)
        q = apply_rope(q, cos[:, None], sin[:, None])
        k_new = apply_rope(k_new, cos[:, None], sin[:, None])
    cache_len = ck.shape[1]
    row = pos % cache_len                 # rolling overwrite

    def cur(c, r):
        return jax.lax.dynamic_slice_in_dim(c, r, 1, axis=0)[0]

    def upd(c, x, r):
        return jax.lax.dynamic_update_slice_in_dim(c, x[None], r, axis=0)

    # Mask the *row*, not the buffer: a dead slot rewrites its
    # current row with itself (a no-op), so the select stays
    # row-sized and the bandwidth-bound cache update remains a
    # single in-place scatter per side.
    keep = live[:, None, None]
    k_row = jnp.where(keep, k_new.astype(ck.dtype), jax.vmap(cur)(ck, row))
    v_row = jnp.where(keep, v_new.astype(cv.dtype), jax.vmap(cur)(cv, row))
    ck = jax.vmap(upd)(ck, k_row, row)
    cv = jax.vmap(upd)(cv, v_row, row)
    out = decode_attention(
        q, ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
        kv_len=ring_kv_len(pos, cache_len), block_kv=a.block_kv,
        impl=impl, interpret=interpret)
    return out.reshape(B, a.heads * a.head_dim), ck, cv


def _run_decode_attention_paged(op: ProgramOp, src: jax.Array,
                                k_src: jax.Array, v_src: jax.Array,
                                ck, cv, ks, vs, pt, pos, live, *,
                                impl: str, interpret: bool | None):
    """Paged flavor of the decode step: the new K/V row scatters into
    the pool page named by the slot's table entry for virtual row ``pos
    % cache_len`` — the same rolling-ring rule as the contiguous path,
    applied through the table — and attention gathers every valid page
    via ``paged_decode_attention``.

    The engine's host-side ``PagePool`` guarantees the write page is
    allocated and private (COW-forked if shared) *before* this runs, so
    the scatter never needs a branch; dead slots redirect to the null
    page 0, which keeps the write dense (their garbage row lands where
    nothing valid ever reads — see ``regions.PagedPlan``).

    int8 pools rewrite the whole target page: the page scale grows to
    admit the new row when needed (``max(old, |row|/127)``) and the
    page is requantized under it — exact when the scale is unchanged,
    the common case.  Returns (out, ck, cv, ks, vs)."""
    from ..models.common import Rotary, apply_rope
    a = op.attn
    B = src.shape[0]
    pg = a.page_size
    pages_per_slot = pt.shape[1]
    cache_len = pages_per_slot * pg
    q = src.reshape(B, a.heads, a.head_dim)
    k_new = k_src.reshape(B, a.kv_heads, a.head_dim)
    v_new = v_src.reshape(B, a.kv_heads, a.head_dim)
    if a.rope_theta:
        cos, sin = Rotary(a.head_dim, a.rope_theta).freqs(pos)
        q = apply_rope(q, cos[:, None], sin[:, None])
        k_new = apply_rope(k_new, cos[:, None], sin[:, None])
    row = pos % cache_len                           # rolling overwrite
    offs = row % pg
    page = jnp.take_along_axis(pt, (row // pg)[:, None], axis=1)[:, 0]
    page = jnp.where(live, page, 0)                 # dead slots -> null page

    if ks is None:
        ck = ck.at[page, offs].set(k_new.astype(ck.dtype))
        cv = cv.at[page, offs].set(v_new.astype(cv.dtype))
    else:
        from ..core.quant import int8_requantize_page

        def write_row(pool, scales, new_row):
            old_page = pool[page]                   # (B, pg, KV, hd)
            old_scale = scales[page]
            amax = jnp.max(jnp.abs(new_row.astype(jnp.float32)),
                           axis=(1, 2))
            new_scale = jnp.maximum(old_scale, amax / 127.0)
            new_scale = jnp.where(new_scale > 0, new_scale, 1.0)
            qp = int8_requantize_page(old_page, old_scale[:, None, None,
                                                          None],
                                      new_scale[:, None, None, None])
            qrow = jnp.clip(jnp.round(new_row.astype(jnp.float32)
                                      / new_scale[:, None, None]),
                            -127, 127).astype(jnp.int8)
            qp = jax.vmap(lambda p, r, o:
                          jax.lax.dynamic_update_slice_in_dim(
                              p, r[None], o, axis=0))(qp, qrow, offs)
            return pool.at[page].set(qp), scales.at[page].set(new_scale)

        ck, ks = write_row(ck, ks, k_new)
        cv, vs = write_row(cv, vs, v_new)

    out = paged_decode_attention(
        q, ck, cv, pt, kv_len=ring_kv_len(pos, cache_len),
        k_scale=ks, v_scale=vs, impl=impl, interpret=interpret)
    return out.reshape(B, a.heads * a.head_dim), ck, cv, ks, vs


def run_decode(program: Program, params, tokens: jax.Array,
               state: ProgramState, mask: jax.Array | None = None, *,
               impl: str = "auto", interpret: bool | None = None):
    """Advance the occupied slots by one token through the decode
    Program.

    tokens: (slots,) int32; mask: (slots,) bool occupancy (None = all
    occupied).  Each ``decode_attention`` op RoPEs the new q/k at the
    slot's absolute position, writes the new K/V row into the
    persistent cache regions at ``position % cache_len`` (the rolling
    ring rule — cache_len is the region's allocator-recorded row count,
    ``min(max_len, attn_window)`` for a windowed plan), and attends
    over ``ring_kv_len(position, cache_len)`` valid rows with the
    schedule's block_kv.  Returns (logits (slots, vocab), new_state)
    with every *occupied* slot's length advanced by one.

    Unoccupied slots are fully inert: their length does not advance and
    their cache rows are not written — a dead slot can never smear
    garbage rows into a region a later request's attention window will
    read (slot-cache hygiene; full-length prefills used to mask this by
    rewriting the whole row region, rolling-window prefills do not).
    Their logits are still garbage the (absent) request never reads.
    """
    regions: dict[int, jax.Array] = {program.input_region: tokens}
    caches = dict(state.caches)
    pos = state.lengths
    live = (jnp.ones(pos.shape, bool) if mask is None
            else jnp.asarray(mask, bool))
    for op in program.ops:
        src = regions[op.in_region]
        if op.kernel == "decode_attention":
            if op.page_table_region is not None:
                quant = op.k_scale_region is not None
                out, ck, cv, ks, vs = _run_decode_attention_paged(
                    op, src, regions[op.k_region], regions[op.v_region],
                    caches[op.k_cache_region], caches[op.v_cache_region],
                    caches[op.k_scale_region] if quant else None,
                    caches[op.v_scale_region] if quant else None,
                    caches[op.page_table_region], pos, live,
                    impl=impl, interpret=interpret)
                if quant:
                    caches[op.k_scale_region] = ks
                    caches[op.v_scale_region] = vs
            else:
                out, ck, cv = _run_decode_attention(
                    op, src, regions[op.k_region], regions[op.v_region],
                    caches[op.k_cache_region], caches[op.v_cache_region],
                    pos, live, impl=impl, interpret=interpret)
            caches[op.k_cache_region] = ck
            caches[op.v_cache_region] = cv
            regions[op.out_region] = out
            continue
        if op.kernel in _FAMILY_KERNELS:
            regions[op.out_region] = _run_family_op(
                op, src, regions, params, caches, live=live,
                impl=impl, interpret=interpret)
            continue
        regions[op.out_region] = _run_op(op, src, regions, params,
                                         impl=impl, interpret=interpret,
                                         pos=pos)
    return (regions[program.output_region],
            ProgramState(caches, jnp.where(live, pos + 1, pos)))


_RUNNERS: "collections.OrderedDict" = collections.OrderedDict()
_RUNNERS_CAP = 64


def jitted_runner(program: Program, impl: str = "auto",
                  interpret: bool | None = None):
    """One compiled (jit) executor per Program — the models' fast path.

    Keyed by program identity (a Program holds dicts, so it is not
    hashable); the cached closure keeps the program alive, so the id
    cannot be recycled while the entry exists.  LRU-bounded so a
    long-running server cycling through many (config, hw, batch)
    variants cannot pin programs + compiled executables forever.
    """
    def make():
        def _run(params, x, _program=program):
            return run(_program, params, x, impl=impl, interpret=interpret)
        return jax.jit(_run)
    return _cached_runner((id(program), impl, interpret, "run"), make)


def _cached_runner(key, make):
    fn = _RUNNERS.get(key)
    if fn is None:
        fn = _RUNNERS[key] = make()
        while len(_RUNNERS) > _RUNNERS_CAP:
            _RUNNERS.popitem(last=False)
    else:
        _RUNNERS.move_to_end(key)
    return fn


def jitted_prefill_runner(program: Program, impl: str = "auto",
                          interpret: bool | None = None):
    """Compiled prefill: (params, tokens, state, slot, length[,
    write_from]) -> (logits, state).  The state argument is donated so
    the cache buffers update in place; ``write_from`` (paged plans
    only) is the shared-prefix row the cache writes start at."""
    def make():
        def _run(params, tokens, state, slot, length, write_from=0,
                 _program=program):
            return run_prefill(_program, params, tokens, state, slot,
                               length, write_from, impl=impl,
                               interpret=interpret)
        return jax.jit(_run, donate_argnums=(2,))
    return _cached_runner((id(program), impl, interpret, "prefill"), make)


def jitted_decode_runner(program: Program, impl: str = "auto",
                         interpret: bool | None = None):
    """Compiled decode tick: (params, tokens, state[, mask]) ->
    (logits, state) with the state donated — the bandwidth-bound
    serving hot loop.  ``mask`` is the (slots,) bool occupancy; omitted
    means every slot is live."""
    def make():
        def _run(params, tokens, state, mask=None, _program=program):
            return run_decode(_program, params, tokens, state, mask,
                              impl=impl, interpret=interpret)
        return jax.jit(_run, donate_argnums=(2,))
    return _cached_runner((id(program), impl, interpret, "decode"), make)


# --- paged KV runtime (host-side page allocator, §5.1 paged plan) ------------------
class PagePool:
    """Host-side allocator for a pair's §5.1 paged-KV plan.

    The compiler minted the *capacity* (``regions.paged_kv_specs``:
    pool shape, table shape, null page 0); this object owns the
    *assignment* — a free list, per-page refcounts, and a host mirror
    of the device page table.  All decisions (admission, on-demand
    decode pages, COW forks, retirement) happen here between jitted
    calls; the device only ever sees the decided table
    (``sync_page_table``) and whole-page copies (``apply_page_copies``),
    so the jitted prefill/decode runners stay branch-free.

    Refcounts are table-granular, shared by every block's pools: slot
    tables are identical across blocks (the same virtual rows), so one
    count per page id covers all of them.

    Invariants:

    * page 0 is never allocated — it is the dense-scatter target for
      masked writes (dead slots, shared-prefix prefill rows);
    * a page a slot is about to *write* (``prepare_decode``) always has
      refcount 1 — shared pages are forked first (copy-on-write);
    * a freed page returns to the free list only at refcount 0, so a
      donor's retirement never invalidates a sharer's prefix.
    """

    def __init__(self, plan: PagedPlan, slots: int):
        self.plan = plan
        self.slots = slots
        self.free: list[int] = list(range(plan.n_pages - 1, 0, -1))
        self.refcount = np.zeros(plan.n_pages, np.int32)
        self.table = np.zeros((slots, plan.pages_per_slot), np.int32)
        # True whenever the host table has edits the device copy hasn't
        # seen; ``sync_page_table`` clears it.  Steady-state decode
        # (write row inside an already-owned page) leaves the table
        # untouched, so the per-tick sync becomes a no-op.
        self.dirty = True

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def used_pages(self) -> int:
        return int((self.refcount > 0).sum())

    def _alloc(self) -> int:
        if not self.free:
            raise RuntimeError(
                f"page pool exhausted ({self.plan.n_pages} pages, "
                f"page_size={self.plan.page_size}) — retire a slot or "
                f"compile with a larger page_pool")
        p = self.free.pop()
        self.refcount[p] = 1
        return p

    def _unref(self, p: int) -> None:
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            self.free.append(p)

    def can_admit(self, length: int, shared_pages: int = 0) -> bool:
        need = pages_for_len(length, self.plan.page_size) - shared_pages
        return need <= len(self.free)

    def admit(self, slot: int, length: int,
              shared: tuple[int, ...] = ()) -> int:
        """Map ``shared`` donor pages (full-page common prefix, in
        order) into ``slot``'s table, allocate fresh pages for the
        rest of the ``length``-row prompt, and return ``write_from`` —
        the first row the prefill must actually write (= the shared
        row count)."""
        pg = self.plan.page_size
        need = pages_for_len(length, pg)
        shared = tuple(shared)[:need]
        row = np.zeros(self.plan.pages_per_slot, np.int32)
        for i, p in enumerate(shared):
            self.refcount[p] += 1
            row[i] = p
        for i in range(len(shared), need):
            row[i] = self._alloc()
        self.table[slot] = row
        self.dirty = True
        return len(shared) * pg

    def release(self, slot: int) -> None:
        """Retire a slot: unref every mapped page (freed at refcount 0)
        and null the table row so re-admission starts clean."""
        for p in self.table[slot]:
            if p:
                self._unref(int(p))
        self.table[slot] = 0
        self.dirty = True

    def slot_pages(self, slot: int, length: int) -> tuple[int, ...]:
        """The slot's first ``pages_for_len(length)`` page ids — what a
        donor exposes for prefix sharing."""
        n = pages_for_len(length, self.plan.page_size)
        return tuple(int(p) for p in self.table[slot, :n])

    def shared_prefix_pages(self, slot: int, donor_prompt: tuple,
                            prompt: tuple) -> tuple[int, ...]:
        """Donor pages coverable by the common *full-page* prompt
        prefix of ``donor_prompt`` and ``prompt`` (partial pages can't
        be shared — the donor's rows past the common prefix live in
        the same page)."""
        pg = self.plan.page_size
        common = 0
        for a, b in zip(donor_prompt, prompt):
            if a != b:
                break
            common += 1
        return self.slot_pages(slot, (common // pg) * pg)

    def prepare_decode(self, slot: int, pos: int):
        """Make the page receiving the write at ``pos % cache_len``
        writable: allocate it if the table entry is still null, fork it
        (new page, caller copies rows) if shared.  Returns the (src,
        dst) copy a COW fork requires, else None."""
        pg = self.plan.page_size
        idx = (pos % self.plan.cache_len) // pg
        p = int(self.table[slot, idx])
        if p == 0:
            self.table[slot, idx] = self._alloc()
            self.dirty = True
            return None
        if self.refcount[p] > 1:
            fresh = self._alloc()
            self._unref(p)
            self.table[slot, idx] = fresh
            self.dirty = True
            return (p, fresh)
        return None


def paged_pool_regions(pair: ProgramPair) -> list[tuple]:
    """(k_pages, v_pages, k_scale, v_scale) region-id tuples of every
    paged decode op — the buffers a COW fork must copy (scale rids are
    None for float pools)."""
    out = []
    for op in pair.decode.ops:
        if (op.kernel == "decode_attention"
                and op.page_table_region is not None):
            out.append((op.k_cache_region, op.v_cache_region,
                        op.k_scale_region, op.v_scale_region))
    return out


def sync_page_table(state: ProgramState, pair: ProgramPair,
                    pool: PagePool) -> None:
    """Push the host mirror of the page table to the device state (the
    jitted runners read the device copy; all mutation is host-side).
    No-op when the table is unchanged since the last sync — the
    steady-state decode tick transfers nothing."""
    if not pool.dirty:
        return
    state.caches[pair.page_table_region] = jnp.asarray(pool.table)
    pool.dirty = False


def apply_page_copies(state: ProgramState, pair: ProgramPair,
                      copies) -> None:
    """Apply COW forks: device-copy pool page ``src -> dst`` (rows and,
    for int8 pools, the per-page scale) across every block's K and V
    pools.  Runs between jitted calls; each copy is one small
    dynamic-slice update per buffer."""
    if not copies:
        return
    rids = [r for quad in paged_pool_regions(pair) for r in quad
            if r is not None]
    for src, dst in copies:
        for rid in rids:
            buf = state.caches[rid]
            state.caches[rid] = buf.at[dst].set(buf[src])


# --- trace recorder (measured-cost loop, stage 7) ----------------------------------
def _shape_dtype(x) -> list:
    return [list(x.shape), str(jnp.asarray(x).dtype)]


def _op_operands(op: ProgramOp, regions: dict, params,
                 caches: dict | None = None) -> dict:
    """role -> [shape, dtype] for everything the op touches."""
    out: dict[str, list] = {"in": _shape_dtype(regions[op.in_region])}
    for role, rid in (("k", op.k_region), ("v", op.v_region),
                      ("in2", op.in2_region)):
        if rid is not None:
            out[role] = _shape_dtype(regions[rid])
    if op.fuse_bypass and op.bypass_region is not None:
        out["bypass"] = _shape_dtype(regions[op.bypass_region])
    if op.param_key is not None:
        p = _param(params, op.param_key)
        if isinstance(p, dict) and "w" not in p:
            # Family ops (wkv / ssm_scan / moe_dispatch) carry a whole
            # block subtree, not a w/b pair; record the leaf count —
            # these kinds are not rebuildable in isolation (replay
            # raises, the autotuner keeps them identity-only).
            out["param_dict"] = [[len(jax.tree.leaves(p))], "tree"]
        elif isinstance(p, dict):
            out["w"] = _shape_dtype(p["w"])
            if "b" in p:
                out["b"] = _shape_dtype(p["b"])
            out["param_dict"] = [[], "dict"]
        else:
            out["w"] = _shape_dtype(p)
            out["param_dict"] = [[], "array"]
    if op.param_key_b is not None:
        out["b"] = _shape_dtype(_param(params, op.param_key_b))
    if caches is not None and op.k_cache_region is not None:
        out["k_cache"] = _shape_dtype(caches[op.k_cache_region])
        out["v_cache"] = _shape_dtype(caches[op.v_cache_region])
    if caches is not None and op.state_regions:
        for j, rid in enumerate(op.state_regions):
            out[f"state{j}"] = _shape_dtype(caches[rid])
    return out


def _op_schedule(op: ProgramOp) -> dict:
    """The op's resolved schedule decisions, JSON-shaped — every field
    the kernels receive verbatim, so a trace record fully determines
    the dispatch (replay invariant)."""
    d: dict = {
        "strip_storage": op.strip_storage,
        "dataflow": op.dataflow.value if op.dataflow else None,
        "block": list(op.block) if op.block else None,
        "stride": op.stride, "pad": op.pad, "window": op.window,
        "fuse_bias": op.fuse_bias, "fuse_activation": op.fuse_activation,
        "fuse_bypass": op.fuse_bypass, "bypass_first": op.bypass_first,
        "fuse_pool": list(op.fuse_pool) if op.fuse_pool else None,
        "norm_kind": op.norm_kind, "flatten_input": op.flatten_input,
        "transpose_w": op.transpose_w,
    }
    if op.conv_tiling is not None:
        d["conv_tiling"] = dataclasses.asdict(op.conv_tiling)
    if op.attn is not None:
        a = op.attn
        d["attn"] = {"heads": a.heads, "kv_heads": a.kv_heads,
                     "head_dim": a.head_dim, "causal": a.causal,
                     "window": a.window, "rope_theta": a.rope_theta,
                     "block_q": a.block_q, "block_kv": a.block_kv,
                     "page_size": a.page_size}
    return d


@dataclass
class TraceRecord:
    """One executed ProgramOp: identity, resolved schedule, operand
    shapes, modeled cost, and measured wallclock.  ``measured_time_s``
    is the only run-to-run varying field (``static_dict`` drops it);
    everything else is a pure function of the Program + inputs."""
    index: int
    name: str
    kind: str                        # ProgramOp.kernel
    operands: dict
    schedule: dict
    flops: float
    traffic_bytes: float
    modeled_time_s: float
    measured_time_s: float | None = None
    repeats: int = 0
    # runtime operand *values* a replay needs beyond shapes — e.g. the
    # decode slots' positions (kv_len drives the attention work).
    extras: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TraceRecord":
        return cls(**d)

    def static_dict(self) -> dict:
        d = self.to_dict()
        d.pop("measured_time_s")
        d.pop("repeats")
        return d


@dataclass
class ExecutorTrace:
    """A traced Program execution: one TraceRecord per op + the context
    needed to interpret the timings.  Serializes to JSONL (meta header
    line, then one record per line) — the interchange format between
    the executor, ``core/cost.fit_cost_model`` and ``core/autotune``."""
    program: str
    hw: str
    impl: str
    interpret: bool | None
    repeats: int
    records: list = field(default_factory=list)

    def record_dicts(self) -> list[dict]:
        return [r.to_dict() for r in self.records]

    def to_jsonl(self) -> str:
        meta = {"trace_meta": {"program": self.program, "hw": self.hw,
                               "impl": self.impl, "interpret": self.interpret,
                               "repeats": self.repeats}}
        lines = [json.dumps(meta)]
        lines += [json.dumps(d, sort_keys=True) for d in self.record_dicts()]
        return "\n".join(lines) + "\n"

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    @classmethod
    def from_jsonl(cls, text: str) -> "ExecutorTrace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        meta = json.loads(lines[0])["trace_meta"]
        recs = [TraceRecord.from_dict(json.loads(ln)) for ln in lines[1:]]
        return cls(records=recs, **meta)

    @classmethod
    def load(cls, path) -> "ExecutorTrace":
        with open(path) as f:
            return cls.from_jsonl(f.read())


def _time_thunk(thunk, repeats: int) -> float:
    """Min-of-``repeats`` wallclock of ``thunk`` with block-until-ready
    (one untimed warmup absorbs tracing/compilation)."""
    jax.block_until_ready(thunk())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        best = min(best, time.perf_counter() - t0)
    return best


def trace_program(program: Program, params, x: jax.Array, *,
                  impl: str = "auto", interpret: bool | None = None,
                  repeats: int = 3, measure: bool = True,
                  state: ProgramState | None = None,
                  mask: jax.Array | None = None) -> ExecutorTrace:
    """Execute ``program`` op by op, recording each op's resolved
    schedule, operand shapes, modeled cost and measured wallclock.

    Opt-in (the fast path is ``jitted_runner``): ops dispatch eagerly
    so each can be individually blocked on and timed; the per-call
    dispatch overhead is uniform and lands in the calibration's
    ``gamma`` term.  Stateless Programs take (params, x); decode
    Programs additionally need ``state`` (and optional ``mask``), and
    the cache write is timed as part of its ``decode_attention`` op —
    that *is* the op's memory traffic.  ``measure=False`` skips the
    timing loops (schema-only traces, e.g. on CI).
    """
    is_decode = (any(op.kernel == "decode_attention" for op in program.ops)
                 or program.name.endswith(".decode"))
    if is_decode and state is None:
        raise ValueError("decode Programs need state=; see run_decode")
    regions: dict[int, jax.Array] = {program.input_region: x}
    caches = dict(state.caches) if state is not None else None
    pos = state.lengths if state is not None else None
    live = None
    if state is not None:
        live = (jnp.ones(pos.shape, bool) if mask is None
                else jnp.asarray(mask, bool))
    trace = ExecutorTrace(program=program.name, hw=program.hw_name,
                          impl=impl, interpret=interpret,
                          repeats=repeats if measure else 0)
    for op in program.ops:
        src = regions[op.in_region]
        if op.kernel == "decode_attention":
            ck0, cv0 = caches[op.k_cache_region], caches[op.v_cache_region]
            if op.page_table_region is not None:
                quant = op.k_scale_region is not None
                ks0 = caches[op.k_scale_region] if quant else None
                vs0 = caches[op.v_scale_region] if quant else None
                pt0 = caches[op.page_table_region]

                def thunk(op=op, src=src, ck0=ck0, cv0=cv0, ks0=ks0,
                          vs0=vs0, pt0=pt0):
                    return _run_decode_attention_paged(
                        op, src, regions[op.k_region], regions[op.v_region],
                        ck0, cv0, ks0, vs0, pt0, pos, live,
                        impl=impl, interpret=interpret)

                out, ck, cv, ks, vs = thunk()
                if quant:
                    caches[op.k_scale_region] = ks
                    caches[op.v_scale_region] = vs
            else:
                def thunk(op=op, src=src, ck0=ck0, cv0=cv0):
                    return _run_decode_attention(
                        op, src, regions[op.k_region], regions[op.v_region],
                        ck0, cv0, pos, live, impl=impl, interpret=interpret)

                out, ck, cv = thunk()
            caches[op.k_cache_region] = ck
            caches[op.v_cache_region] = cv
        elif op.kernel in _FAMILY_KERNELS:
            # Each call works on a fresh copy of the pre-op cache dict
            # so repeated timing runs are idempotent; the real state
            # advance is applied once from the first call's copy.
            snap = dict(caches) if caches is not None else None

            def thunk(op=op, src=src, snap=snap):
                cc = dict(snap) if snap is not None else None
                res = _run_family_op(op, src, regions, params, cc,
                                     slot=0, live=live, impl=impl,
                                     interpret=interpret)
                return res, cc

            out, cc = thunk()
            if caches is not None:
                caches.update(cc)
        else:
            def thunk(op=op, src=src):
                return _run_op(op, src, regions, params, impl=impl,
                               interpret=interpret,
                               pos=pos if is_decode else None)

            out = thunk()
        regions[op.out_region] = out
        operands = _op_operands(op, regions, params, caches)
        operands["out"] = _shape_dtype(out)
        extras = {}
        if op.kernel == "decode_attention":
            extras = {"pos": [int(p) for p in pos],
                      "live": [bool(b) for b in live]}
        trace.records.append(TraceRecord(
            index=op.index, name=op.name, kind=op.kernel,
            operands=operands, schedule=_op_schedule(op),
            flops=op.flops, traffic_bytes=op.traffic_bytes,
            modeled_time_s=op.exec_time_s,
            measured_time_s=_time_thunk(thunk, repeats) if measure else None,
            repeats=repeats if measure else 0, extras=extras))
    return trace


class OpTimingSampler:
    """Cheap sampled op-timing for serving ticks (Stage 8).

    Full trace mode (``trace_program`` with repeats) is far too heavy
    for a serving loop, but *sampling* it is not: every ``every``-th
    ``tick()`` call runs one eager traced execution of the decode
    Program against the live ``ProgramState`` — same ``TraceRecord``
    schema as Stage 7, single repeat — and attributes the measured
    wallclock to op kinds on the metrics plane
    (``op_time_us{kind=...}`` histograms) plus one ``op_sample``
    flight event per op.  The other ``every - 1`` ticks cost exactly
    one integer increment.

    The eager walk is *read-only* with respect to the engine:
    ``trace_program`` copies the cache dict and produces new arrays,
    so the donated state buffers the jitted tick consumes afterwards
    are untouched — which is also why the engine samples *before* its
    jitted decode call (after it, donation may have invalidated the
    buffers the tracer would read).
    """

    def __init__(self, every: int, registry=None, flight=None, *,
                 impl: str = "auto", interpret: bool | None = None,
                 repeats: int = 1):
        if every < 0:
            raise ValueError(f"sample cadence must be >= 0, got {every}")
        self.every = every
        self.registry = registry
        self.flight = flight
        self.impl = impl
        self.interpret = interpret
        self.repeats = repeats
        self.n_calls = 0
        self.n_samples = 0

    def tick(self, program: Program, params, tokens, *,
             state: ProgramState | None = None,
             mask=None) -> ExecutorTrace | None:
        """Count one tick; on the sampled ones, trace-and-time the
        Program and feed the records to the metrics/flight planes.
        Returns the trace on sampled ticks, None otherwise."""
        if not self.every:
            return None
        self.n_calls += 1
        if self.n_calls % self.every:
            return None
        trace = trace_program(program, params, tokens, impl=self.impl,
                              interpret=self.interpret,
                              repeats=self.repeats, measure=True,
                              state=state, mask=mask)
        self.n_samples += 1
        for rec in trace.records:
            if self.registry is not None:
                self.registry.histogram(
                    "op_time_us",
                    help="sampled per-op executor wallclock",
                    kind=rec.kind).observe(rec.measured_time_s * 1e6)
            if self.flight is not None:
                self.flight.event(
                    "op_sample", kind=rec.kind, name=rec.name,
                    index=rec.index, flops=rec.flops,
                    traffic_bytes=rec.traffic_bytes,
                    modeled_time_s=rec.modeled_time_s,
                    measured_time_s=rec.measured_time_s)
        return trace

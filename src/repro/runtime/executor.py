"""Program executor — runs the compiler's instruction stream (§5.2).

The Snowflake accelerator executes exactly what the compiler emitted;
here the executor walks a ``core/program.py::Program`` and dispatches
each op to the Pallas kernels with the schedule's *pre-resolved*
decisions — conv strip tiling, strip storage, loop order, matmul block,
attention (block_q, block_kv), and the fused epilogue flags.  The LM
families dispatch through the same loop as the CNNs: ``embed`` /
``norm`` / ``flash_attention`` / ``mul`` ops joined ``conv2d`` /
``matmul`` / the pools when the transformer lowering landed.

Invariants:

* **Nothing is re-derived at run time.**  Every kernel call below
  passes the op's resolved schedule through verbatim (``tiling=``,
  ``block=``, ``block_q=``/``block_kv=``, ``strip_storage=``); the
  executor never calls a chooser.  If a kernel needs a decision the op
  does not carry, that is a lowering bug in core/program.py.
* **Region ids are allocator-owned.**  The region file below is keyed
  by the §5.1 ``RegionPlan`` ids embedded in the ops; the executor
  reads ``op.in_region``/``k_region``/``v_region``/``bypass_region``
  and writes ``op.out_region``, and never maps a name to an id itself.
* **``run`` is functionally pure** (params, x -> output) and
  jit-compatible; models wrap it in ``jax.jit`` per (program, impl)
  via ``jitted_runner``.

``x`` is whatever the program's input region expects: an (B, H, W, C)
image batch for CNN programs, an (B, S) int32 token batch for LM
programs (the first op is then the ``embed`` gather).
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp

from ..core.program import Program, ProgramOp
from ..kernels.conv2d import avgpool2d_ref, conv2d, maxpool2d_ref
from ..kernels.flash_attention import flash_attention
from ..kernels.matmul import matmul

__all__ = ["run", "jitted_runner"]


def _param(params, key: str | None):
    """Resolve a ProgramOp param path.

    ``"layer_03"``       -> params["layer_03"]           (CNN groups)
    ``"blocks/wq:3"``    -> params["blocks"]["wq"][3]    (stacked LM blocks)
    ``"final_norm"``     -> params["final_norm"]
    """
    if key is None:
        return None
    path, _, idx = key.partition(":")
    p = params
    for part in path.split("/"):
        p = p[part]
    return p[int(idx)] if idx else p


def _run_attention(op: ProgramOp, regions: dict, *, impl: str,
                   interpret: bool | None) -> jax.Array:
    """Dispatch one flash_attention op: reshape the flat q/k/v regions
    to per-head layout, apply RoPE when the spec says so, and call the
    kernel with the schedule's exact (block_q, block_kv)."""
    # Lazy import: models.common is the one shared home of the rotary
    # helpers and models/cnn.py imports this module at load time.
    from ..models.common import Rotary, apply_rope
    a = op.attn
    q, k, v = regions[op.in_region], regions[op.k_region], regions[op.v_region]
    B, S = q.shape[0], q.shape[1]
    q = q.reshape(B, S, a.heads, a.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, a.kv_heads, a.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, a.kv_heads, a.head_dim).transpose(0, 2, 1, 3)
    if a.rope_theta:
        cos, sin = Rotary(a.head_dim, a.rope_theta).freqs(jnp.arange(S))
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    out = flash_attention(q, k, v, causal=a.causal, window=a.window,
                          block_q=a.block_q, block_kv=a.block_kv,
                          impl=impl, interpret=interpret)
    return out.transpose(0, 2, 1, 3).reshape(B, S, a.heads * a.head_dim)


def _run_norm(op: ProgramOp, src: jax.Array, params) -> jax.Array:
    from ..models.common import layer_norm, rms_norm
    w = _param(params, op.param_key)
    if op.norm_kind == "layernorm":
        return layer_norm(src, w, _param(params, op.param_key_b))
    if op.norm_kind == "nonparametric":
        return layer_norm(src)
    return rms_norm(src, w)


def run(program: Program, params, x: jax.Array, *, impl: str = "auto",
        interpret: bool | None = None) -> jax.Array:
    """Execute ``program`` against ``params`` on input ``x``.

    x: (B, H, W, C) for CNN programs, (B, S) int32 tokens for LM
    programs.  Returns the final op's output (the array living in
    ``program.output_region``).
    """
    regions: dict[int, jax.Array] = {program.input_region: x}
    for op in program.ops:
        src = regions[op.in_region]
        if op.kernel == "conv2d":
            p = _param(params, op.param_key)
            bypass = (regions[op.bypass_region]
                      if op.fuse_bypass and op.bypass_region is not None
                      else None)
            out = conv2d(
                src, p["w"], stride=op.stride, pad=op.pad,
                bias=p["b"] if op.fuse_bias else None,
                activation=op.fuse_activation, bypass=bypass,
                bypass_first=op.bypass_first, fuse_pool=op.fuse_pool,
                strip_storage=op.strip_storage or "auto",
                tiling=op.conv_tiling, dataflow=op.dataflow,
                impl=impl, interpret=interpret)
        elif op.kernel == "matmul":
            p = _param(params, op.param_key)
            w = p["w"] if isinstance(p, dict) else p
            if op.transpose_w:
                w = w.T
            if op.flatten_input:
                src = src.reshape(src.shape[0], -1)
            bypass = (regions[op.bypass_region]
                      if op.fuse_bypass and op.bypass_region is not None
                      else None)
            if bypass is not None and op.flatten_input:
                bypass = bypass.reshape(bypass.shape[0], -1)
            out = matmul(
                src, w,
                bias=(p["b"] if isinstance(p, dict) and op.fuse_bias
                      else None),
                activation=op.fuse_activation, bypass=bypass,
                dataflow=op.dataflow, block=op.block,
                impl=impl, interpret=interpret)
        elif op.kernel == "flash_attention":
            out = _run_attention(op, regions, impl=impl, interpret=interpret)
        elif op.kernel == "embed":
            table = _param(params, op.param_key)
            out = table[src]
        elif op.kernel == "norm":
            out = _run_norm(op, src, params)
        elif op.kernel == "mul":
            out = src * regions[op.in2_region]
        elif op.kernel == "add":
            out = src + regions[op.in2_region]
        elif op.kernel == "maxpool":
            out = maxpool2d_ref(src, window=op.window, stride=op.stride,
                                pad=op.pad)
        elif op.kernel == "avgpool":
            out = avgpool2d_ref(src, window=op.window, stride=op.stride,
                                pad=op.pad)
        else:
            raise NotImplementedError(f"unknown program kernel {op.kernel}")
        regions[op.out_region] = out
    return regions[program.output_region]


_RUNNERS: "collections.OrderedDict" = collections.OrderedDict()
_RUNNERS_CAP = 64


def jitted_runner(program: Program, impl: str = "auto",
                  interpret: bool | None = None):
    """One compiled (jit) executor per Program — the models' fast path.

    Keyed by program identity (a Program holds dicts, so it is not
    hashable); the cached closure keeps the program alive, so the id
    cannot be recycled while the entry exists.  LRU-bounded so a
    long-running server cycling through many (config, hw, batch)
    variants cannot pin programs + compiled executables forever.
    """
    key = (id(program), impl, interpret)
    fn = _RUNNERS.get(key)
    if fn is None:
        def _run(params, x, _program=program):
            return run(_program, params, x, impl=impl, interpret=interpret)
        fn = _RUNNERS[key] = jax.jit(_run)
        while len(_RUNNERS) > _RUNNERS_CAP:
            _RUNNERS.popitem(last=False)
    else:
        _RUNNERS.move_to_end(key)
    return fn

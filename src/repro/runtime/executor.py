"""Program executor — runs the compiler's instruction stream (§5.2).

The Snowflake accelerator executes exactly what the compiler emitted;
here the executor walks a ``core/program.py::Program`` and dispatches
each op to the Pallas kernels with the schedule's *pre-resolved*
decisions — conv strip tiling, strip storage, loop order, matmul block,
and the fused epilogue flags.  Nothing is re-derived at run time: the
executor maintains a region file (region id -> live activation array,
mirroring the paper's main-memory regions) and feeds each kernel from
the op's input/bypass regions.

``run`` is functionally pure (params, x -> output) and jit-compatible;
models wrap it in ``jax.jit`` per (program, impl) via ``jitted_runner``.
"""
from __future__ import annotations

import collections

import jax

from ..core.program import Program
from ..kernels.conv2d import avgpool2d_ref, conv2d, maxpool2d_ref
from ..kernels.matmul import matmul

__all__ = ["run", "jitted_runner"]


def run(program: Program, params, x: jax.Array, *, impl: str = "auto",
        interpret: bool | None = None) -> jax.Array:
    """Execute ``program`` against ``params`` on input ``x``.

    x: (B, H, W, C) for the CNN programs.  Returns the final op's
    output (the array living in ``program.output_region``).
    """
    regions: dict[int, jax.Array] = {program.input_region: x}
    for op in program.ops:
        src = regions[op.in_region]
        if op.kernel == "conv2d":
            p = params[op.param_key]
            bypass = (regions[op.bypass_region]
                      if op.fuse_bypass and op.bypass_region is not None
                      else None)
            out = conv2d(
                src, p["w"], stride=op.stride, pad=op.pad,
                bias=p["b"] if op.fuse_bias else None,
                activation=op.fuse_activation, bypass=bypass,
                bypass_first=op.bypass_first, fuse_pool=op.fuse_pool,
                strip_storage=op.strip_storage or "auto",
                tiling=op.conv_tiling, dataflow=op.dataflow,
                impl=impl, interpret=interpret)
        elif op.kernel == "matmul":
            p = params[op.param_key]
            B = src.shape[0]
            bypass = (regions[op.bypass_region].reshape(B, -1)
                      if op.fuse_bypass and op.bypass_region is not None
                      else None)
            out = matmul(
                src.reshape(B, -1), p["w"],
                bias=p["b"] if op.fuse_bias else None,
                activation=op.fuse_activation, bypass=bypass,
                dataflow=op.dataflow, block=op.block,
                impl=impl, interpret=interpret)
        elif op.kernel == "maxpool":
            out = maxpool2d_ref(src, window=op.window, stride=op.stride,
                                pad=op.pad)
        elif op.kernel == "avgpool":
            out = avgpool2d_ref(src, window=op.window, stride=op.stride,
                                pad=op.pad)
        else:
            raise NotImplementedError(f"unknown program kernel {op.kernel}")
        regions[op.out_region] = out
    return regions[program.output_region]


_RUNNERS: "collections.OrderedDict" = collections.OrderedDict()
_RUNNERS_CAP = 64


def jitted_runner(program: Program, impl: str = "auto",
                  interpret: bool | None = None):
    """One compiled (jit) executor per Program — the models' fast path.

    Keyed by program identity (a Program holds dicts, so it is not
    hashable); the cached closure keeps the program alive, so the id
    cannot be recycled while the entry exists.  LRU-bounded so a
    long-running server cycling through many (config, hw, batch)
    variants cannot pin programs + compiled executables forever.
    """
    key = (id(program), impl, interpret)
    fn = _RUNNERS.get(key)
    if fn is None:
        def _run(params, x, _program=program):
            return run(_program, params, x, impl=impl, interpret=interpret)
        fn = _RUNNERS[key] = jax.jit(_run)
        while len(_RUNNERS) > _RUNNERS_CAP:
            _RUNNERS.popitem(last=False)
    else:
        _RUNNERS.move_to_end(key)
    return fn

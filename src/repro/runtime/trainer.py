"""Fault-tolerant training loop.

Production behaviours exercised end-to-end (examples/train_lm.py,
tests/test_runtime.py):

* **checkpoint/restart** — async sharded checkpoints every
  ``ckpt_every`` steps; on start the trainer auto-resumes from the
  latest committed step (data iterator state = the step counter, so the
  stream continues exactly where it left off);
* **preemption** — SIGTERM/SIGINT installs a flag; the loop finishes
  the in-flight step, forces a checkpoint, and exits cleanly;
* **straggler / hang detection** — a ring buffer of host-side step
  times; a step slower than ``straggler_factor`` x the trailing median
  raises a logged anomaly (on multi-host deployments this is the signal
  to evict the slow host and re-shard — here it feeds the log + metrics
  so tests can assert on it).  The median comes off an
  ``obs.Histogram`` over the window — the same fixed-bucket type the
  serving metrics plane uses — and a cumulative ``step_time_s``
  histogram rides in ``metrics_history`` (p50/p99 per log record);
* **NaN containment** — non-finite loss skips the update (params/opt
  state keep their donated buffers via a no-op update) and counts
  toward an abort threshold.
"""
from __future__ import annotations

import logging
import signal
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint.store import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from ..obs import Histogram, exp_buckets

__all__ = ["TrainerConfig", "Trainer"]

log = logging.getLogger("repro.trainer")

# Fine geometric buckets (factor 1.1 => percentile error <= 10%) for
# host-side step times: sub-100us jitted steps up to 20-minute stalls.
_STEP_TIME_BUCKETS = exp_buckets(1e-5, 1200.0, factor=1.1)


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 32
    max_nan_steps: int = 10


@dataclass
class Trainer:
    step_fn: object                  # jitted (params, opt, batch) -> ...
    data: object                     # .batch_at(step) -> dict of np arrays
    cfg: TrainerConfig = field(default_factory=TrainerConfig)
    batch_shardings: object = None

    def __post_init__(self):
        self._preempted = False
        self._times: list[float] = []
        self.anomalies: list[dict] = []
        self.metrics_history: list[dict] = []
        # Cumulative step-time distribution (whole run, never evicted)
        # — the metrics-plane view next to the trailing window above.
        self.step_time_hist = Histogram(_STEP_TIME_BUCKETS)

    # -- signals ---------------------------------------------------------------
    def _install_signals(self):
        def handler(signum, frame):
            log.warning("preemption signal %s: checkpoint + exit", signum)
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass   # not on the main thread (tests)

    # -- straggler detection -----------------------------------------------------
    def _record_time(self, step: int, dt: float):
        self._times.append(dt)
        if len(self._times) > self.cfg.straggler_window:
            self._times.pop(0)
        self.step_time_hist.observe(dt)
        if len(self._times) >= 8:
            # Trailing-window median through the shared Histogram type
            # (<= straggler_window observes per step — negligible next
            # to the jitted step).  Bucket factor 1.1 bounds the
            # percentile error at ~10%, far inside straggler_factor.
            h = Histogram(_STEP_TIME_BUCKETS)
            for t in self._times[:-1]:
                h.observe(t)
            med = h.percentile(50)
            if dt > self.cfg.straggler_factor * med:
                anomaly = {"step": step, "dt": dt, "median": med,
                           "kind": "straggler"}
                self.anomalies.append(anomaly)
                log.warning("straggler step %d: %.3fs vs median %.3fs",
                            step, dt, med)

    # -- main loop ----------------------------------------------------------------
    def run(self, params, opt_state):
        self._install_signals()
        ckpt = AsyncCheckpointer(self.cfg.ckpt_dir, keep=self.cfg.keep)
        start = 0
        if latest_step(self.cfg.ckpt_dir) is not None:
            (params, opt_state), start = restore_checkpoint(
                self.cfg.ckpt_dir, (params, opt_state))
            log.info("resumed from step %d", start)

        nan_steps = 0
        step = start
        while step < self.cfg.total_steps and not self._preempted:
            batch = self.data.batch_at(step)
            if self.batch_shardings is not None:
                batch = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), batch,
                    self.batch_shardings)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self._record_time(step, dt)
            if not np.isfinite(loss):
                nan_steps += 1
                self.anomalies.append({"step": step, "kind": "nan"})
                log.warning("non-finite loss at step %d (%d/%d)", step,
                            nan_steps, self.cfg.max_nan_steps)
                if nan_steps >= self.cfg.max_nan_steps:
                    raise FloatingPointError(
                        f"{nan_steps} non-finite steps; aborting")
            if step % self.cfg.log_every == 0:
                rec = {"step": step, "loss": loss, "dt_s": dt,
                       "dt_p50_s": self.step_time_hist.percentile(50),
                       "dt_p99_s": self.step_time_hist.percentile(99)}
                rec.update({k: float(v) for k, v in metrics.items()
                            if k != "loss"})
                self.metrics_history.append(rec)
                log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
            step += 1
            if step % self.cfg.ckpt_every == 0:
                ckpt.save(step, (params, opt_state))

        ckpt.wait()
        if self._preempted or step % self.cfg.ckpt_every != 0:
            ckpt.save(step, (params, opt_state))
            ckpt.wait()
        return params, opt_state, step

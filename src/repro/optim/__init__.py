from .adamw import (AdamW, Q8State, cosine_schedule, dequantize_state,
                    global_norm, quantize_state)
__all__ = ["AdamW", "Q8State", "cosine_schedule", "dequantize_state",
           "global_norm", "quantize_state"]

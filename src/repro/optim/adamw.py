"""AdamW with optional 8-bit moment states and global-norm clipping.

States are pytrees matching the params, so they inherit the params'
PartitionSpecs (ZeRO-style sharding falls out of the FSDP rules: states
shard wherever the weights shard).  The 8-bit mode stores both moments
as int8 with per-row f32 scales — the distributed-optimization trick
that makes the llama4-400b training cell fit 256 chips (EXPERIMENTS.md
§Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "Q8State", "quantize_state", "dequantize_state",
           "global_norm", "cosine_schedule"]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


# --- 8-bit moment storage ---------------------------------------------------------
@dataclass(frozen=True)
class Q8State:
    q: jax.Array          # int8
    scale: jax.Array      # f32, per-row (last axis reduced)


jax.tree_util.register_pytree_node(
    Q8State, lambda s: ((s.q, s.scale), None),
    lambda _, c: Q8State(*c))


def quantize_state(x: jax.Array) -> Q8State:
    if x.ndim == 0:
        x = x[None]
        amax = jnp.max(jnp.abs(x))[None]
    else:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return Q8State(q, scale)


def dequantize_state(s: Q8State) -> jax.Array:
    return s.q.astype(jnp.float32) * s.scale


# --- AdamW ------------------------------------------------------------------------
@dataclass(frozen=True)
class AdamW:
    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float | None = 1.0
    state_bits: int = 32          # 32 (f32 moments) or 8 (int8 + scales)

    def init(self, params) -> dict:
        def zero(p):
            z = jnp.zeros(p.shape, jnp.float32)
            return quantize_state(z) if self.state_bits == 8 else z
        return {
            "m": jax.tree.map(zero, params),
            # v is stored in sqrt domain when quantized: int8's 1/127
            # relative floor is far too coarse for v directly (tiny v
            # -> 0 -> unbounded update); sqrt halves the dynamic range.
            "v": jax.tree.map(zero, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state, params):
        """Returns (new_params, new_state, metrics)."""
        step = state["step"] + 1
        gnorm = global_norm(grads)
        if self.grad_clip is not None:
            clip = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * clip, grads)
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            if self.state_bits == 8:
                mf = dequantize_state(m)
                vf = jnp.square(dequantize_state(v))   # sqrt-domain store
                if g.ndim == 0:
                    mf, vf = mf[0], vf[0]
            else:
                mf, vf = m, v
            m_new = b1 * mf + (1 - b1) * g
            v_new = b2 * vf + (1 - b2) * g * g
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.state_bits == 8:
                # Adafactor-style update clipping: int8 v can underflow
                # to 0 for small-|g| rows, exploding m/sqrt(v); capping
                # the update RMS at 1 bounds the damage.
                rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
                delta = delta / jnp.maximum(1.0, rms)
            if p.ndim >= 2:   # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            if self.state_bits == 8:
                return (p_new, quantize_state(m_new),
                        quantize_state(jnp.sqrt(v_new)))
            return p_new, m_new, v_new

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_state = {"m": tdef.unflatten([o[1] for o in out]),
                     "v": tdef.unflatten([o[2] for o in out]),
                     "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""Batched serving engine: continuous batching over a fixed-slot cache.

One prefill step admits a request into a free slot (its KV/state cache
written at that slot); every decode step advances all live slots by one
token.  Slots whose sequence emits EOS (or hits max_len) are freed and
refilled from the queue — the standard continuous-batching loop, sized
so the decode step is always full-batch (the bandwidth-bound regime the
decode_32k / long_500k cells measure).

Per-slot positions come from the models' per-sequence ``pos`` vector,
so mixed-progress batches are exact (verified in tests against
single-request decoding).

CNN workloads take the **program fast path**: a ``CNNConfig`` (or an
explicit ``program=``) makes the engine stateless — each tick batches
up to ``slots`` queued image requests and executes the compiled
``core/program.py::Program`` once through ``runtime/executor.py``, so
the compiler's schedule is what serves the traffic.

Dense-LM workloads are served **statefully** (``use_program=True``):
the engine compiles the (prefill, decode) Program pair
(``models/transformer.py::compile_program_pair``) whose persistent
KV-cache regions are owned by the §5.1 allocator, and keeps one
``runtime/executor.py::ProgramState`` across ticks.  Admission runs
the prefill Program once per request — full causal forward, cache
written at the admitted slot, first token emitted from the prompt's
last position — and every subsequent tick runs the decode Program: one
token per live slot against the cache, O(1) in prompt length.  Nothing
is ever prefilled twice (``n_prefill_recomputes`` stays 0 by
construction).  Windowed-attention configs serve on the same path with
persistent KV regions sized to the window (``min(max_len,
attn_window)`` rows per slot, rolling eviction-by-overwrite — the
§5.1 plan shrinks resident state by max_len/window).  ``paged=True``
compiles the third region scheme — the paged plan: fixed-size page
pools plus a per-slot page table, with admission, copy-on-write prefix
sharing, and on-demand page allocation decided host-side by a
``runtime/executor.py::PagePool`` between jitted calls
(``n_shared_pages`` / ``n_cow_forks`` count the wins; ``kv_quant=
"int8"`` additionally halves resident page bytes).

Persistent state is *generic named state*, not KV rows: each family's
``regions.state_specs`` hook mints its own per-slot specs — recurrent
SSM/conv state (hybrid, O(1) in sequence length), wkv matrices +
token-shift rows (rwkv), read-only encoder memory written at admission
by ``ModelApi.encode_memory`` (whisper) — and a ``StateCaps`` record
that gates the serving features per family: ``paged``/COW needs
KV-row-granular state, ``chunkable`` prefill needs resumable state
(``pair.chunk_blocker``), ``speculatable`` needs rollback-by-length-
truncation.  The engine consults the caps instead of assuming every
family is KV-shaped.  Families without a lowering (vlm) fall back to
the legacy ``decode_step`` loop with a single warning at engine
construction naming the *full* blocker list (``fallback_reason``).

The tick loop itself is throughput-grade (see docs/ARCHITECTURE.md,
"Serving loop"):

* **Chunked prefill** (``chunk_size``): admission only assigns the
  slot; the prompt prefills ``chunk_size`` rows per tick through one
  batched ``run_prefill_chunk`` call shared by every in-flight
  admission, bitwise-equal to a whole prefill.  Decode-first fairness
  bounds per-tick latency: live slots always advance
  (``n_starved_ticks`` stays 0), and a prefill completes within
  ``ceil(length/chunk_size)`` ticks of slot assignment.
* **Async admission with typed backpressure**: ``submit`` goes through
  a bounded ``serving/admission.py::AdmissionQueue`` — ``queue_full``
  rejects at the door with a ticket; ``no_free_slot`` /
  ``pages_exhausted`` stalls are recorded, and a pool-starved request
  is requeued at the *head* so later arrivals can never overtake it.
* **Speculative decode** (``spec_k``): a draft (prefill, decode) pair
  — ``compile_program_pair`` verbatim via ``compile_draft_pair`` —
  proposes up to k greedy tokens per tick; the target verifies the
  burst in one batched chunk call, accepts the longest agreeing
  prefix, emits the correcting token, and rolls back by truncating
  both states' lengths.  Greedy output is token-identical to
  speculation off (``n_spec_proposed`` / ``n_spec_accepted`` /
  ``n_spec_rollbacks`` count the wins next to the prefill metrics).

**Observability** (``obs=``, docs Stage 8): every engine reports
through one ``obs.Observability`` bundle — the ``n_*`` counters above
live on its ``MetricsRegistry`` (the attributes are read-through
properties), per-tick wallclock and TTFT / inter-token latency land in
fixed-bucket histograms (``tick_ms`` / ``ttft_ms`` / ``itl_ms``), and
when a flight recorder is attached every request's lifecycle — enqueue
→ admission ticket → prefill chunks → first token → per-token → spec
accept/rollback → COW fork → release — plus a per-tick engine snapshot
streams to JSONL (``obs.flight.replay_summary`` reconstructs the token
streams exactly).  The default bundle is counters-only: no recorder,
no op sampling, no extra device syncs — a bare engine pays a few float
adds per tick for its metrics plane.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, CNNConfig
from ..models import get_model
from ..obs import Observability
from . import admission as adm
from .admission import AdmissionQueue, AdmissionTicket

__all__ = ["Request", "ServingEngine"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (len,) int32 tokens, or (H, W, C) image
    max_new_tokens: int = 16
    # Family side-channel input (ModelApi.extra_input): encoder frames
    # for audio configs — admission runs ``encode_memory`` over it and
    # writes the result into the slot's read-only persistent regions.
    extra: np.ndarray | None = None
    out_tokens: list = field(default_factory=list)
    done: bool = False


@dataclass
class _InFlightPrefill:
    """A chunked admission mid-prefill: the slot is reserved (neither
    free nor live) while ``done`` walks the prompt in ``chunk_size``
    steps; ``admitted_tick`` dates the slot assignment so the
    completes-within-``ceil(length/chunk)``-ticks bound is checkable."""
    req: Request
    tokens: np.ndarray               # (max_len,) right-padded prompt window
    length: int                      # prompt rows to prefill
    done: int                        # rows already in the cache
    write_from: int                  # paged shared-prefix redirect
    admitted_tick: int


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 impl: str = "auto", greedy: bool = True, program=None,
                 use_program: bool = False, paged: bool = False,
                 page_size: int = 16, page_pool: int | None = None,
                 kv_quant: str | None = None,
                 chunk_size: int | None = None,
                 queue_capacity: int | None = None,
                 spec_k: int = 0, draft_cfg=None, draft_params=None,
                 obs: Observability | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_id
        self.impl = impl
        self.greedy = greedy
        self.live: dict[int, Request] = {}       # slot -> request
        self.queue: list[Request] = []           # legacy/CNN paths only
        # One metrics plane + flight recorder per engine (Stage 8).
        # The default bundle is counters-only (no recorder, no op
        # sampling); callers that want the flight record / Prometheus
        # snapshot / sampled op timings pass their own bundle.
        self.obs = obs if obs is not None else Observability()
        self._init_metrics()
        # LM-program requests enter through the bounded admission queue
        # (typed backpressure, head requeue); ``submit`` routes there.
        # It shares the engine's registry: admission_* and serving_*
        # metrics land in one snapshot.
        self.admission = AdmissionQueue(queue_capacity,
                                        registry=self.obs.registry)
        self.chunk_size = chunk_size
        self.spec_k = spec_k
        self._spec = False
        self._prefilling: dict[int, _InFlightPrefill] = {}
        self._lm_program = False
        self._tick_no = 0
        self._op_sampler = None
        # Why an LM config requested on the program path fell back to
        # the legacy decode loop (None = no fallback happened); callers
        # that *require* the program path (launch/serve.py --program)
        # check this instead of re-parsing the warning.
        self.fallback_reason: str | None = None
        self._pool = None                 # runtime/executor.py::PagePool
        self._slot_prompts: dict[int, tuple] = {}   # donor registry
        self._slot_len: dict[int, int] = {}         # host length mirror
        self._memory_writer = None        # ModelApi.encode_memory
        self._memory_input = None         # ModelApi.extra_input
        lm = isinstance(cfg, ArchConfig)
        if (program is not None or use_program) and lm:
            # Stateful LM program path: (prefill, decode) Program pair
            # sharing persistent KV regions + one ProgramState.
            from ..core.program import ProgramPair
            from ..models.transformer import compile_program_pair
            from ..runtime import executor
            if program is not None and not isinstance(program, ProgramPair):
                raise TypeError(
                    f"LM serving is stateful: pass the (prefill, decode) "
                    f"ProgramPair from models/transformer.py::"
                    f"compile_program_pair, got {type(program).__name__} "
                    f"(the per-tick prefill-recompute path was removed)")
            if program is not None:
                # Catch a geometry mismatch at construction, not as a
                # shape error mid-serve.  The pair records its compiled
                # (slots, max_len); the persistent-region shapes alone
                # cannot recover max_len for a windowed config (the row
                # count collapses to the window), so prefer the
                # recorded geometry and fall back to the region shape
                # for externally assembled pairs that left it unset.
                if program.paged is not None:
                    # Paged plans: pools are slot-agnostic, so geometry
                    # lives in the page table (slots rows) and the
                    # plan's virtual extent (pages_per_slot * page_size
                    # == max_len).
                    pt = next(s for s in program.decode.plan
                              .persistent_regions()
                              if s.name == "page_table")
                    checks = [(pt.shape,
                               (slots, program.paged.pages_per_slot)),
                              ((program.paged.cache_len,), (max_len,))]
                else:
                    # Generic named state: re-mint the engine config's
                    # own state specs through the family hook and
                    # demand the pair's persistent regions match name
                    # for name, shape for shape.  This catches what the
                    # recorded geometry alone cannot — a pair compiled
                    # from a *different* config (e.g. a windowed pair
                    # handed to a dense engine) whose slots/max_len
                    # happen to agree but whose region rows do not.
                    from ..core import regions as _regions
                    specs, _ = _regions.state_specs(cfg, slots, max_len)
                    want_specs = {s.name: s.shape for s in specs}
                    got_specs = {s.name: s.shape
                                 for s in (program.decode.plan
                                           .persistent_regions())}
                    checks = [(got_specs, want_specs)]
                if program.max_len is not None:
                    checks.append(((program.slots, program.max_len),
                                   (slots, max_len)))
                for got, want in checks:
                    if got != want:
                        raise ValueError(
                            f"ProgramPair compiled for slots/max_len "
                            f"{got}, engine configured for {want}")
            pair = program
            if pair is None:
                try:
                    pair = compile_program_pair(cfg, slots=slots,
                                                max_len=max_len,
                                                paged=paged,
                                                page_size=page_size,
                                                page_pool=page_pool,
                                                kv_quant=kv_quant)
                except NotImplementedError as e:
                    # Once per engine construction, never per tick.
                    # The lowering gate names the *specific* blocker
                    # (MoE dispatch, cross-attention, ...) — windowed
                    # attention is no longer one; it serves on the
                    # program path with window-sized KV regions.
                    self.fallback_reason = str(e)
                    # Structured twin of the warning below: a
                    # ``fallback`` flight event + a labeled gauge, so
                    # an exit-code-2 ``--program`` run is diagnosable
                    # from the metrics/flight artifacts alone.
                    self.obs.flight.event("fallback", reason=str(e))
                    self.obs.registry.gauge(
                        "serving_fallback",
                        help="1 when the engine fell back to the "
                             "legacy decode loop, labeled by blocker",
                        fallback_reason=str(e)).set(1)
                    warnings.warn(
                        f"no decode-Program lowering for {cfg.name} — "
                        f"{e}; serving through the legacy decode loop",
                        RuntimeWarning, stacklevel=2)
            if pair is not None:
                self.api = None
                self.cache = None
                self.program = pair
                self.state = executor.init_program_state(pair)
                # Families with admission-written persistent memory
                # (audio: read-only encoder cross K/V) expose
                # ``encode_memory`` on their ModelApi; admission runs
                # it once per request and scatters the returned rows
                # at the admitted slot *before* the prefill Program's
                # cross ops read them.
                fam_api = get_model(cfg)
                self._memory_writer = fam_api.encode_memory
                self._memory_input = fam_api.extra_input
                self._prefill = executor.jitted_prefill_runner(
                    pair.prefill, impl=impl)
                self._decode = executor.jitted_decode_runner(
                    pair.decode, impl=impl)
                if pair.paged is not None:
                    # Host-side page allocator: admission, on-demand
                    # decode pages, and COW forks are decided here
                    # between jitted calls; the device sees only the
                    # synced table and whole-page copies.
                    self._pool = executor.PagePool(pair.paged, slots)
                if chunk_size is not None:
                    if chunk_size < 1:
                        raise ValueError(
                            f"chunk_size must be >= 1, got {chunk_size}")
                    blocker = pair.chunk_blocker
                    if blocker is not None:
                        raise ValueError(
                            f"pair is not chunkable: {blocker}")
                self._chunk = (executor.jitted_chunk_runner(
                                   pair.prefill, impl=impl)
                               if (chunk_size is not None or spec_k)
                               else None)
                self._init_spec(pair, draft_cfg, draft_params)
                if self.obs.sample_ops_every:
                    # Stage-8 sampled op timing: every N-th decode tick
                    # is additionally walked eagerly through the
                    # Stage-7 trace recorder (TraceRecord schema) so
                    # tick wallclock attributes to op kinds without
                    # full trace mode.
                    self._op_sampler = executor.OpTimingSampler(
                        self.obs.sample_ops_every,
                        registry=self.obs.registry,
                        flight=self.obs.flight, impl=impl)
                self._lm_program = True
                return
        if chunk_size is not None or spec_k:
            raise ValueError(
                "chunked prefill / speculative decode need the stateful "
                "LM Program path (use_program=True on a lowerable dense "
                f"config); blocked by: {self.fallback_reason or cfg.name}")
        if (program is not None and not lm) or isinstance(cfg, CNNConfig):
            # Program fast path (CNN workloads): one compiled Program
            # per batch size, executed whole per tick — no token cache.
            from ..models.cnn import compile_program
            from ..runtime.executor import jitted_runner
            self.api = None
            self.cache = None
            self.program = (program if program is not None
                            else compile_program(cfg, batch=slots))
            self._infer = jitted_runner(self.program, impl=impl)
            return
        self.program = None
        self.api = get_model(cfg)
        self.cache = self.api.init_cache(cfg, slots, max_len)
        self._decode = jax.jit(
            lambda p, c, t: self.api.decode_step(p, c, t, cfg, impl=impl))

    def _init_metrics(self) -> None:
        """Register the engine's metric families on the bundle's
        registry.  The legacy ``n_*`` attributes below are read-through
        properties over these counters — same numbers, one source of
        truth, and the whole plane serializes via
        ``obs.registry.snapshot()`` / ``prometheus_text()``."""
        m = self.obs.registry
        c, g, h = m.counter, m.gauge, m.histogram
        # Stateful-program counters: the program path prefills each
        # request exactly once at admission, so prefill_recomputes
        # stays 0 by construction (CI-asserted from the snapshot).
        self._c_prefills = c("serving_prefills_total")
        self._c_prefill_recomputes = c("serving_prefill_recomputes_total")
        self._c_decode_ticks = c("serving_decode_ticks_total")
        # Chunked-prefill / tick-liveness: a live slot the tick failed
        # to advance shows up in starved_ticks (stays 0 — chunking
        # exists precisely so admission can never stall decode).
        self._c_prefill_chunks = c("serving_prefill_chunks_total")
        self._c_starved = c("serving_starved_ticks_total")
        # Speculative decode: draft tokens proposed / accepted by
        # target verification, and ticks whose acceptance stopped
        # short of k (rollback).
        self._c_spec_proposed = c("serving_spec_proposed_total")
        self._c_spec_accepted = c("serving_spec_accepted_total")
        self._c_spec_rollbacks = c("serving_spec_rollbacks_total")
        # Paged KV: donor pages mapped at admission (prompt rows *not*
        # prefilled thanks to prefix sharing) and copy-on-write forks.
        self._c_shared_pages = c("serving_shared_pages_total")
        self._c_cow_forks = c("serving_cow_forks_total")
        self._c_requests = c("serving_requests_total",
                             help="requests submitted")
        self._c_finished = c("serving_requests_finished_total")
        self._c_tokens = c("serving_tokens_total",
                           help="generated tokens emitted")
        self._g_live = g("serving_live_slots")
        self._g_queue = g("serving_queue_depth")
        self._g_free_pages = g("serving_free_pages")
        self._h_tick = h("tick_ms", help="engine tick wallclock")
        self._h_ttft = h("ttft_ms", help="enqueue to first token")
        self._h_itl = h("itl_ms", help="inter-token latency")

    # Read-through compatibility properties: the counters moved onto
    # the metrics registry; every existing consumer (benchmarks, CI
    # greps, tests) still reads the same integers here.
    @property
    def n_prefills(self) -> int:
        return int(self._c_prefills.value)

    @property
    def n_prefill_recomputes(self) -> int:
        return int(self._c_prefill_recomputes.value)

    @property
    def n_decode_ticks(self) -> int:
        return int(self._c_decode_ticks.value)

    @property
    def n_prefill_chunks(self) -> int:
        return int(self._c_prefill_chunks.value)

    @property
    def n_starved_ticks(self) -> int:
        return int(self._c_starved.value)

    @property
    def n_spec_proposed(self) -> int:
        return int(self._c_spec_proposed.value)

    @property
    def n_spec_accepted(self) -> int:
        return int(self._c_spec_accepted.value)

    @property
    def n_spec_rollbacks(self) -> int:
        return int(self._c_spec_rollbacks.value)

    @property
    def n_shared_pages(self) -> int:
        return int(self._c_shared_pages.value)

    @property
    def n_cow_forks(self) -> int:
        return int(self._c_cow_forks.value)

    @property
    def on_program_path(self) -> bool:
        """True when LM tokens are served through the compiled
        (prefill, decode) Program pair — the public signal for callers
        that *require* the program path (launch/serve.py --program);
        False means the legacy decode loop, with ``fallback_reason``
        naming why."""
        return self._lm_program

    def _init_spec(self, pair, draft_cfg, draft_params) -> None:
        """Wire the speculative-decode draft pair: a second (prefill,
        decode) Program pair — ``compile_program_pair`` verbatim, same
        geometry — whose decode proposes ``spec_k`` tokens per tick for
        the target's batched verification."""
        if not self.spec_k:
            return
        if self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
        if not self.greedy:
            raise ValueError(
                "speculative decode verifies greedy argmax proposals; "
                "sampling acceptance is out of scope (greedy=True)")
        if pair.paged is not None:
            raise NotImplementedError(
                "speculative decode over paged KV: the verify burst "
                "would need per-row page preparation (COW forks) "
                "inside the tick; serve paged configs without spec_k")
        if pair.caps is not None and not pair.caps.speculatable:
            raise NotImplementedError(
                f"speculative decode needs speculatable family state "
                f"({self.cfg.name} is family={self.cfg.family}): "
                f"rollback truncates lengths, which cannot rewind "
                f"recurrent or capacity-routed state")
        from ..models.transformer import compile_draft_pair
        from ..runtime import executor
        if draft_cfg is None:
            draft_cfg = self.cfg
            if draft_params is None:
                # Self-draft: the degenerate (but valid) configuration
                # where the draft is the target itself — every
                # proposal verifies, which is what the CI smoke pins.
                draft_params = self.params
        if draft_params is None:
            raise ValueError(
                f"draft_cfg {draft_cfg.name} needs draft_params "
                f"(the draft is a separate model)")
        dpair = compile_draft_pair(self.cfg, draft_cfg, slots=self.slots,
                                   max_len=self.max_len)
        self._draft_params = draft_params
        self._draft_pair = dpair
        self._draft_state = executor.init_program_state(dpair)
        self._draft_prefill = executor.jitted_prefill_runner(
            dpair.prefill, impl=self.impl)
        self._draft_decode = executor.jitted_decode_runner(
            dpair.decode, impl=self.impl)
        self._spec = True

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> AdmissionTicket:
        """Enqueue a request; returns the admission ticket (rejected
        with reason ``queue_full`` when the bounded queue is at
        capacity — the request is *not* held).  Stamps the enqueue
        time (TTFT starts here) and records the lifecycle events."""
        req._enqueue_t = self.obs.clock()
        self._c_requests.inc()
        prompt_len = (len(req.prompt)
                      if getattr(req.prompt, "ndim", 1) == 1 else 0)
        self.obs.flight.event("enqueue", uid=req.uid,
                              prompt_len=prompt_len)
        if self._lm_program:
            ticket = self.admission.submit(req)
        else:
            self.queue.append(req)
            ticket = AdmissionTicket(True, "queued", len(self.queue) - 1)
        self.obs.flight.event("admission", uid=req.uid,
                              accepted=ticket.accepted,
                              reason=ticket.reason,
                              position=ticket.position)
        return ticket

    def _free_slots(self):
        return [s for s in range(self.slots)
                if s not in self.live and s not in self._prefilling]

    def _admit(self):
        """Prefill queued requests into free slots through the decode
        path (slot-local prefill keeps the batch cache layout intact;
        batched prefill is the launch/steps.py path used for the large
        cells).

        All admissions in a tick are batched: one merge resets every
        admitted slot's cache, then the prompts are teacher-forced
        *together* — at prefill step t every admitted slot still inside
        its prompt advances at once, and a single masked merge per step
        folds exactly those slots' cache updates back (live slots'
        caches stay frozen).  Previously each admitted request ran a
        full ``jax.tree.map`` copy over all slots per prompt token."""
        admitted: list[tuple[int, Request]] = []
        for slot in self._free_slots():
            if not self.queue:
                break
            admitted.append((slot, self.queue.pop(0)))
        if not admitted:
            return
        self._reset_slots([slot for slot, _ in admitted])
        steps = max(len(req.prompt) - 1 for _, req in admitted)
        for t in range(steps):
            toks = np.zeros((self.slots,), np.int32)
            mask = np.zeros((self.slots,), bool)
            for slot, req in admitted:
                if t < len(req.prompt) - 1:
                    toks[slot] = int(req.prompt[t])
                    mask[slot] = True
            self._step_masked(jnp.asarray(toks), mask)
        for slot, req in admitted:
            req._last_token = int(req.prompt[-1])
            self.live[slot] = req

    @staticmethod
    def _batch_axis(leaf) -> int:
        """Model caches carry batch at axis 1 ((L, B, ...)); the shared
        ``pos`` vector is (B,)."""
        return 0 if leaf.ndim == 1 else 1

    def _reset_slots(self, slots: list[int]):
        """Zero the admitted slots' cache — one merge for all of them."""
        fresh = self.api.init_cache(self.cfg, 1, self.max_len)
        idx = np.asarray(slots)
        def put(c, f):
            axis = self._batch_axis(c)
            shape = list(c.shape)
            shape[axis] = len(idx)
            sel = [slice(None)] * c.ndim
            sel[axis] = idx
            return c.at[tuple(sel)].set(
                jnp.broadcast_to(f.astype(c.dtype), shape))
        self.cache = jax.tree.map(put, self.cache, fresh)

    def _step_masked(self, toks, mask: np.ndarray):
        """One batched decode step keeping only the masked slots' cache
        updates (the other slots' caches stay frozen)."""
        old_cache = self.cache
        logits, new_cache = self._decode(self.params, self.cache, toks)
        def merge(old, new):
            axis = self._batch_axis(old)
            shape = [1] * old.ndim
            shape[axis] = self.slots
            m = jnp.asarray(mask).reshape(shape)
            return jnp.where(m, new, old)
        self.cache = jax.tree.map(merge, old_cache, new_cache)
        return logits

    # -- program fast path (CNN) -------------------------------------------------
    def _program_step(self) -> list[Request]:
        """One tick on the program path: batch up to ``slots`` queued
        images, execute the compiled Program once, retire them all.
        ``out_tokens`` carries the argmax class id."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        images = np.stack([np.asarray(r.prompt) for r in batch])
        if len(batch) < self.slots:        # pad to the compiled batch
            pad = np.zeros((self.slots - len(batch),) + images.shape[1:],
                           images.dtype)
            images = np.concatenate([images, pad])
        logits = np.asarray(self._infer(
            self.params, jnp.asarray(images, self.cfg.jdtype)))
        for r, lg in zip(batch, logits):
            r.out_tokens.append(int(np.argmax(lg)))
            r.done = True
        return batch

    # -- LM program fast path ----------------------------------------------------
    def _next_token(self, req: Request, logits_row: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits_row))
        return int(np.random.default_rng(req.uid + len(req.out_tokens))
                   .choice(self.cfg.vocab, p=_softmax(logits_row)))

    def _emit_tokens(self, slot: int, req: Request, toks, finished: list,
                     ) -> int:
        """Append generated tokens in order until EOS or the request's
        budget retires it; returns how many were kept.  A speculative
        tick hands several accepted tokens at once — the truncation
        here is what keeps its output stream identical to the
        one-token-per-tick path."""
        kept = 0
        flight = self.obs.flight
        for nxt in toks:
            now = self.obs.clock()
            first = not req.out_tokens
            req.out_tokens.append(nxt)
            req._last_token = nxt
            kept += 1
            self._c_tokens.inc()
            if first:
                ttft_ms = (now - req._enqueue_t) * 1e3 \
                    if hasattr(req, "_enqueue_t") else 0.0
                self._h_ttft.observe(ttft_ms)
                flight.event("first_token", uid=req.uid, slot=slot,
                             token=nxt, ttft_ms=ttft_ms)
            else:
                itl_ms = (now - req._last_emit_t) * 1e3
                self._h_itl.observe(itl_ms)
                flight.event("token", uid=req.uid, slot=slot,
                             token=nxt, itl_ms=itl_ms)
            req._last_emit_t = now
            if ((self.eos is not None and nxt == self.eos)
                    or len(req.out_tokens) >= req.max_new_tokens):
                req.done = True
                finished.append(req)
                self.live.pop(slot, None)
                self._c_finished.inc()
                flight.event(
                    "release", uid=req.uid, slot=slot,
                    n_tokens=len(req.out_tokens),
                    reason=("eos" if (self.eos is not None
                                      and nxt == self.eos)
                            else "max_new_tokens"))
                if self._pool is not None:
                    # Retire the slot's pages: unref (a donor's shared
                    # prefix stays resident while any sharer holds a
                    # refcount) and drop it from the donor registry.
                    self._pool.release(slot)
                    self._slot_prompts.pop(slot, None)
                    self._slot_len.pop(slot, None)
                break
        return kept

    def _retire_if_done(self, slot: int, req: Request, nxt: int,
                        finished: list) -> None:
        self._emit_tokens(slot, req, [nxt], finished)

    def _lm_admit(self, finished: list) -> None:
        """Prefill queued prompts into free slots — once per request,
        ever.  Each admission runs the prefill Program: the full causal
        forward over the right-padded prompt, the block K/V written
        into the persistent cache regions at the slot, and the first
        generated token read off the prompt's last position.  Prompts
        longer than ``max_len`` condition on their most recent
        ``max_len`` tokens (the cache holds at most that much
        history).

        Free slots are recomputed per admission: a slot freed *during*
        this loop (EOS or ``max_new_tokens == 1`` on the prefill token
        retires the request inside ``_retire_if_done``) is immediately
        reusable for the next queued request instead of idling a
        tick.

        With ``chunk_size`` set, admission only *assigns* the slot and
        registers an ``_InFlightPrefill``; the prompt is prefilled one
        chunk per tick by ``_advance_prefills`` so decode ticks for the
        other slots interleave between chunks.  Admission stalls record
        their typed backpressure reason and — for pool exhaustion,
        where the request was already dequeued — requeue at the *head*
        so no later arrival can overtake a starved request."""
        flight = self.obs.flight
        while self.admission:
            free = self._free_slots()
            if not free:
                self.admission.note_blocked(adm.NO_FREE_SLOT)
                flight.event("admission", accepted=False,
                             reason=adm.NO_FREE_SLOT)
                break
            req = self.admission.pop()
            if req is None:
                break
            slot = free[0]
            if len(req.prompt) == 0:
                raise ValueError(f"request {req.uid}: empty prompt")
            win = np.asarray(req.prompt, np.int32)[-self.max_len:]
            write_from = 0
            if self._pool is not None:
                write_from = self._paged_admit(slot, win)
                if write_from is None:
                    # Pool exhausted: the request waits at the head of
                    # the queue until a retirement frees pages.
                    self.admission.requeue_front(req, adm.PAGES_EXHAUSTED)
                    flight.event("admission", accepted=False,
                                 reason=adm.PAGES_EXHAUSTED, uid=req.uid)
                    break
            flight.event("prefill_start", uid=req.uid, slot=slot,
                         length=len(win), write_from=write_from)
            if self._memory_writer is not None:
                self._write_encoder_memory(slot, req)
            if self.chunk_size is not None:
                padded = np.zeros((self.max_len,), np.int32)
                padded[:len(win)] = win
                # A fully page-shared prompt still owes the chunk that
                # computes the last row's logits (the write is
                # redirected, the first token is not).
                self._prefilling[slot] = _InFlightPrefill(
                    req=req, tokens=padded, length=len(win),
                    done=min(write_from, len(win) - 1),
                    write_from=write_from,
                    admitted_tick=self.n_decode_ticks)
                continue
            padded = np.zeros((1, self.max_len), np.int32)
            padded[0, :len(win)] = win
            logits, self.state = self._prefill(
                self.params, jnp.asarray(padded), self.state, slot,
                len(win), write_from)
            self._finish_prefill(slot, req, padded,
                                 np.asarray(logits[0, len(win) - 1]),
                                 len(win), finished)

    def _write_encoder_memory(self, slot: int, req: Request) -> None:
        """Run the family's admission-time memory writer (the whisper
        encoder + cross K/V projection) over the request's ``extra``
        input and scatter the returned rows into the pair's read-only
        persistent regions at the admitted slot.  Happens before the
        prefill Program runs — its cross-attention ops read these
        regions — and exactly once per admission: the regions are
        ``read_only`` in the §5.1 plan, so no decode tick touches
        them until the slot is re-admitted."""
        if req.extra is None:
            raise ValueError(
                f"request {req.uid}: {self.cfg.family} serving needs "
                f"Request.extra ({self._memory_input}) to fill the "
                f"persistent encoder memory at admission")
        rows = self._memory_writer(
            self.params, jnp.asarray(req.extra, self.cfg.jdtype),
            self.cfg, impl=self.impl)
        persistent = self.program.persistent
        for name, row in rows.items():
            rid = persistent[name]
            buf = self.state.caches[rid]
            self.state.caches[rid] = buf.at[slot].set(row.astype(buf.dtype))

    def _finish_prefill(self, slot: int, req: Request, padded,
                        last_logits, length: int, finished: list) -> None:
        """Shared tail of both prefill flavors: accounting, the first
        generated token, liveness — and the draft prefill when
        speculative decode is on (the draft cache must hold the same
        history before it can propose)."""
        # Real accounting, not a constant: a second prefill of the
        # same request (any future re-admission/recompute path)
        # shows up here — CI asserts the count stays at zero.
        if getattr(req, "_prefilled", False):
            self._c_prefill_recomputes.inc()
        req._prefilled = True
        self._c_prefills.inc()
        self.live[slot] = req
        if self._spec:
            _, self._draft_state = self._draft_prefill(
                self._draft_params,
                jnp.asarray(padded.reshape(1, self.max_len)),
                self._draft_state, slot, length, 0)
        nxt = self._next_token(req, last_logits)
        self._retire_if_done(slot, req, nxt, finished)

    def _advance_prefills(self, finished: list) -> None:
        """Advance every in-flight chunked prefill by one chunk — a
        single batched chunk-Program call for all of them (they share
        the prefill Program, so the geometry always allows it).  An
        admission that reaches its prompt length emits its first token
        and goes live; by construction that happens within
        ``ceil(length / chunk_size)`` ticks of slot assignment."""
        if not self._prefilling:
            return
        items = sorted(self._prefilling.items())
        lengths = np.array([p.length for _, p in items], np.int32)
        starts = np.array([p.done for _, p in items], np.int32)
        stops = np.minimum(starts + self.chunk_size, lengths)
        logits, self.state = self._chunk(
            self.params,
            jnp.asarray(np.stack([p.tokens for _, p in items])),
            self.state,
            jnp.asarray(np.array([s for s, _ in items], np.int32)),
            jnp.asarray(starts), jnp.asarray(stops), jnp.asarray(lengths),
            jnp.asarray(np.array([p.write_from for _, p in items],
                                 np.int32)))
        self._c_prefill_chunks.inc(len(items))
        done_rows = None
        for i, (slot, p) in enumerate(items):
            self.obs.flight.event("prefill_chunk", uid=p.req.uid,
                                  slot=slot, start=int(starts[i]),
                                  stop=int(stops[i]))
            p.done = int(stops[i])
            if p.done < p.length:
                continue
            if done_rows is None:
                done_rows = np.asarray(logits)
            del self._prefilling[slot]
            self._finish_prefill(slot, p.req, p.tokens,
                                 done_rows[i, p.length - 1], p.length,
                                 finished)

    def _paged_admit(self, slot: int, win: np.ndarray) -> int | None:
        """Map an admitted prompt onto pool pages.  Finds the live
        donor with the longest *full-page* common prompt prefix,
        refcount-shares those donor pages into the slot's table row,
        and allocates fresh pages for the private remainder.  Returns
        ``write_from`` — the first prompt row the prefill Program
        actually writes (shared rows are scatter-redirected to the null
        page) — or None when the pool cannot hold the private pages.

        Donors whose ring write wrapped past ``max_len`` are skipped:
        the rolling overwrite has recycled their early pages, so the
        prompt is no longer resident there (sharers that mapped those
        pages *before* the wrap stay safe — the wrap write saw
        refcount > 1 and forked).  Donors still mid-chunked-prefill are
        skipped too: their prefix pages are mapped but not yet
        *written*, and a sharer's chunk would read rows the donor's
        later chunks still owe."""
        from ..runtime import executor
        pool = self._pool
        prompt = tuple(int(t) for t in win)
        shared: tuple[int, ...] = ()
        for s, donor in self._slot_prompts.items():
            if s in self._prefilling:
                continue
            if self._slot_len.get(s, 0) > pool.plan.cache_len:
                continue
            cand = pool.shared_prefix_pages(s, donor, prompt)
            if len(cand) > len(shared):
                shared = cand
        if not pool.can_admit(len(prompt), len(shared)):
            return None
        write_from = pool.admit(slot, len(prompt), shared)
        self._c_shared_pages.inc(len(shared))
        self._slot_prompts[slot] = prompt
        self._slot_len[slot] = len(prompt)
        executor.sync_page_table(self.state, self.program, pool)
        return write_from

    def _lm_program_step(self) -> list[Request]:
        """One tick on the stateful LM program path: prefill-admit
        queued requests (whole, or one chunk per tick when
        ``chunk_size`` is set), then advance every live slot through
        the decode Program — O(1) in prompt length, no recompute ever.
        The ProgramState (persistent cache buffers + per-slot lengths)
        is donated through the jitted runners, so the cache updates in
        place across ticks.

        The scheduling rule is decode-first fairness: slots live at
        tick start *always* get their decode advance this tick —
        admission only assigns slots and chunk work is bounded at
        ``chunk_size`` rows per in-flight prefill — so a long prompt
        can never stall the in-flight streams (``n_starved_ticks``
        counts violations; it stays 0 by construction)."""
        finished: list[Request] = []
        self._lm_admit(finished)
        self._advance_prefills(finished)
        if not self.live:
            return finished
        starved = set(self.live)
        toks = np.zeros((self.slots,), np.int32)
        occupied = np.zeros((self.slots,), bool)
        for slot, req in self.live.items():
            toks[slot] = req._last_token
            occupied[slot] = True
        # The occupancy mask keeps dead slots inert inside run_decode:
        # no length advance, no cache-row write (slot-cache hygiene for
        # the rolling-window plans, whose prefill does not rewrite the
        # whole row region on re-admission).
        if self._pool is not None:
            # Make each live slot's write page real and private before
            # the jitted tick: allocate on-demand past the prompt,
            # COW-fork shared pages (device page copy), then push the
            # decided table.
            from ..runtime import executor
            copies = []
            for slot in self.live:
                c = self._pool.prepare_decode(slot, self._slot_len[slot])
                if c is not None:
                    copies.append(c)
                    self.obs.flight.event("cow_fork", slot=slot,
                                          src_page=int(c[0]),
                                          dst_page=int(c[1]))
            executor.sync_page_table(self.state, self.program, self._pool)
            if copies:
                executor.apply_page_copies(self.state, self.program,
                                           copies)
                self._c_cow_forks.inc(len(copies))
        if self._spec:
            advanced = self._spec_tick(toks, occupied, finished)
        else:
            if self._op_sampler is not None:
                # Sample *before* the jitted decode: the runner donates
                # the state buffers, so an eager trace afterwards would
                # walk invalidated caches.
                self._op_sampler.tick(self.program.decode, self.params,
                                      jnp.asarray(toks), state=self.state,
                                      mask=jnp.asarray(occupied))
            logits, self.state = self._decode(self.params,
                                              jnp.asarray(toks),
                                              self.state,
                                              jnp.asarray(occupied))
            if self._pool is not None:
                for slot in self.live:
                    self._slot_len[slot] += 1
            logits = np.asarray(logits)
            advanced = set()
            for slot, req in list(self.live.items()):
                nxt = self._next_token(req, logits[slot])
                self._retire_if_done(slot, req, nxt, finished)
                advanced.add(slot)
        self._c_decode_ticks.inc()
        if starved - advanced:
            self._c_starved.inc(len(starved - advanced))
        return finished

    def _spec_tick(self, toks: np.ndarray, occupied: np.ndarray,
                   finished: list) -> set:
        """One speculative tick: the draft decode proposes up to
        ``spec_k`` tokens per live slot (k batched draft steps), the
        target verifies the whole burst in a single chunk-Program call
        per tick — rows ``[n, n + k_s]`` of each slot, standard greedy
        accept/rollback:

        * slot feeds ``[x0, d_1..d_k]``; target row ``n+j`` yields
          ``y_{j+1} = argmax`` — exactly what sequential decode would
          have produced given the prefix, because the verified rows'
          K/V are written by the same pass;
        * accept the longest prefix with ``d_j == y_j`` (``a`` tokens),
          emit ``y_1..y_{a+1}`` (the first mismatch is *corrected*, not
          discarded — a >= 0 tokens always advance);
        * rollback = truncate both pairs' lengths to ``n + a + 1``;
          rows past the truncation are unattendable (``ring_kv_len``)
          and the next tick's write overwrites the first stale row.

        Returns the set of slots that advanced (all live ones)."""
        from ..runtime import executor
        lens = np.asarray(self.state.lengths)
        all_live = sorted(self.live)
        # Slots whose absolute position reached max_len decode through
        # the ring (rolling overwrite) — the verify chunk is row-
        # addressed, so they take a plain decode step this tick.
        live_slots = [s for s in all_live if int(lens[s]) < self.max_len]
        wrapped = [s for s in all_live if int(lens[s]) >= self.max_len]
        advanced = set()
        if wrapped:
            wmask = np.zeros((self.slots,), bool)
            wmask[wrapped] = True
            wlogits, self.state = self._decode(self.params,
                                               jnp.asarray(toks),
                                               self.state,
                                               jnp.asarray(wmask))
            wlogits = np.asarray(wlogits)
            for s in wrapped:
                req = self.live[s]
                self._retire_if_done(s, req,
                                     self._next_token(req, wlogits[s]),
                                     finished)
                advanced.add(s)
        if not live_slots:
            return advanced
        # Per-slot burst: the verify writes rows [n, n+k_s], so cap at
        # the compiled max_len; a slot at the boundary degenerates to
        # k_s = 0 — a plain (verified) single-token step.
        k_s = {s: max(0, min(self.spec_k, self.max_len - 1 - int(lens[s])))
               for s in live_slots}
        max_k = max(k_s.values())
        # Draft proposal rounds: round i feeds the previous proposal
        # and advances only the slots still inside their burst.
        proposals = {s: [] for s in live_slots}
        cur = toks.copy()
        for i in range(max_k):
            dmask = np.zeros((self.slots,), bool)
            for s in live_slots:
                dmask[s] = i < k_s[s]
            dlogits, self._draft_state = self._draft_decode(
                self._draft_params, jnp.asarray(cur), self._draft_state,
                jnp.asarray(dmask))
            dlogits = np.asarray(dlogits)
            for s in live_slots:
                if i < k_s[s]:
                    d = int(np.argmax(dlogits[s]))
                    proposals[s].append(d)
                    cur[s] = d
        # Target verification: one batched chunk call over all live
        # slots — slot rows [n, n+k_s] carry [x0, d_1..d_k]; length is
        # pinned past stop so no final-chunk tail write triggers.
        B = len(live_slots)
        vtoks = np.zeros((B, self.max_len), np.int32)
        starts = np.zeros((B,), np.int32)
        stops = np.zeros((B,), np.int32)
        for i, s in enumerate(live_slots):
            n = int(lens[s])
            starts[i], stops[i] = n, n + k_s[s] + 1
            vtoks[i, n] = toks[s]
            for j, d in enumerate(proposals[s]):
                vtoks[i, n + 1 + j] = d
        vlogits, self.state = self._chunk(
            self.params, jnp.asarray(vtoks), self.state,
            jnp.asarray(np.array(live_slots, np.int32)),
            jnp.asarray(starts), jnp.asarray(stops),
            jnp.asarray(np.full((B,), self.max_len + 1, np.int32)),
            jnp.asarray(np.zeros((B,), np.int32)))
        vlogits = np.asarray(vlogits)
        # Accept / emit / rollback, then mirror the rolled-back lengths
        # into the draft state so the next burst proposes from the
        # accepted history.
        new_lens = np.asarray(self.state.lengths).copy()
        for i, s in enumerate(live_slots):
            req = self.live[s]
            n = int(lens[s])
            y = [int(np.argmax(vlogits[i, n + j]))
                 for j in range(k_s[s] + 1)]
            a = 0
            while a < k_s[s] and proposals[s][a] == y[a]:
                a += 1
            self._c_spec_proposed.inc(k_s[s])
            self._c_spec_accepted.inc(a)
            if a < k_s[s]:
                self._c_spec_rollbacks.inc()
            self.obs.flight.event("spec", slot=s, uid=req.uid,
                                  proposed=k_s[s], accepted=a,
                                  rollback=a < k_s[s])
            kept = self._emit_tokens(s, req, y[:a + 1], finished)
            new_lens[s] = n + kept
            advanced.add(s)
        # Two separate device arrays: the states are donated through
        # different runner calls, so they must never share a buffer.
        self.state = executor.ProgramState(self.state.caches,
                                           jnp.asarray(new_lens))
        self._draft_state = executor.ProgramState(
            self._draft_state.caches, jnp.asarray(new_lens))
        return advanced

    # -- decode ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """One engine tick: admit, decode one token for all live slots,
        retire finished requests.  Returns requests finished this tick.

        Every tick is timed onto the ``tick_ms`` histogram and — when a
        flight recorder is attached — lands one ``tick`` snapshot event
        (live slots, queue depth, free pages, cumulative starved
        ticks), the engine-level heartbeat the flight replay and the
        console dashboard read."""
        t0 = self.obs.clock()
        finished = self._step_inner()
        dt_ms = (self.obs.clock() - t0) * 1e3
        self._tick_no += 1
        self._h_tick.observe(dt_ms)
        qd = len(self.admission) if self._lm_program else len(self.queue)
        self._g_live.set(len(self.live))
        self._g_queue.set(qd)
        free_pages = self._pool.free_pages if self._pool is not None else -1
        self._g_free_pages.set(free_pages)
        self.obs.flight.event(
            "tick", tick=self._tick_no, dt_ms=dt_ms, live=len(self.live),
            queue_depth=qd, free_pages=free_pages,
            starved=int(self._c_starved.value))
        return finished

    def dashboard_line(self) -> str:
        """One-line console dashboard: the numbers an operator watches,
        read off the same registry the artifacts serialize."""
        snap_p = self._h_ttft.percentile
        itl_p = self._h_itl.percentile
        return (f"tick {self._tick_no:>6} | live {len(self.live):>3} "
                f"| queue {int(self._g_queue.value):>3} "
                f"| toks {int(self._c_tokens.value):>7} "
                f"| ttft_p50 {snap_p(50.0):8.1f}ms "
                f"| itl_p50 {itl_p(50.0):7.2f}ms "
                f"| starved {int(self._c_starved.value)}")

    def _step_inner(self) -> list[Request]:
        if self._lm_program:
            return self._lm_program_step()
        if self.program is not None:
            return self._program_step()
        self._admit()
        if not self.live:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for slot, req in self.live.items():
            toks[slot] = req._last_token
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        logits = np.asarray(logits)
        finished: list[Request] = []
        for slot, req in list(self.live.items()):
            nxt = self._next_token(req, logits[slot])
            self._retire_if_done(slot, req, nxt, finished)
        return finished

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        done = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if (not self.live and not self.queue and not self.admission
                    and not self._prefilling):
                break
        return done


def _softmax(x):
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()

"""Batched serving engine: continuous batching over a fixed-slot cache.

One prefill step admits a request into a free slot (its KV/state cache
written at that slot); every decode step advances all live slots by one
token.  Slots whose sequence emits EOS (or hits max_len) are freed and
refilled from the queue — the standard continuous-batching loop, sized
so the decode step is always full-batch (the bandwidth-bound regime the
decode_32k / long_500k cells measure).

Per-slot positions come from the models' per-sequence ``pos`` vector,
so mixed-progress batches are exact (verified in tests against
single-request decoding).

CNN workloads take the **program fast path**: a ``CNNConfig`` (or an
explicit ``program=``) makes the engine stateless — each tick batches
up to ``slots`` queued image requests and executes the compiled
``core/program.py::Program`` once through ``runtime/executor.py``, so
the compiler's schedule is what serves the traffic.

Dense-LM workloads are served **statefully** (``use_program=True``):
the engine compiles the (prefill, decode) Program pair
(``models/transformer.py::compile_program_pair``) whose persistent
KV-cache regions are owned by the §5.1 allocator, and keeps one
``runtime/executor.py::ProgramState`` across ticks.  Admission runs
the prefill Program once per request — full causal forward, cache
written at the admitted slot, first token emitted from the prompt's
last position — and every subsequent tick runs the decode Program: one
token per live slot against the cache, O(1) in prompt length.  Nothing
is ever prefilled twice (``n_prefill_recomputes`` stays 0 by
construction).  Windowed-attention configs serve on the same path with
persistent KV regions sized to the window (``min(max_len,
attn_window)`` rows per slot, rolling eviction-by-overwrite — the
§5.1 plan shrinks resident state by max_len/window).  ``paged=True``
compiles the third region scheme — the paged plan: fixed-size page
pools plus a per-slot page table, with admission, copy-on-write prefix
sharing, and on-demand page allocation decided host-side by a
``runtime/executor.py::PagePool`` between jitted calls
(``n_shared_pages`` / ``n_cow_forks`` count the wins; ``kv_quant=
"int8"`` additionally halves resident page bytes).  Families without
a lowering fall back to the legacy ``decode_step`` loop with a single
warning at engine construction naming the specific blocker
(``fallback_reason``).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, CNNConfig
from ..models import get_model

__all__ = ["Request", "ServingEngine"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (len,) int32 tokens, or (H, W, C) image
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 impl: str = "auto", greedy: bool = True, program=None,
                 use_program: bool = False, paged: bool = False,
                 page_size: int = 16, page_pool: int | None = None,
                 kv_quant: str | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_id
        self.impl = impl
        self.greedy = greedy
        self.live: dict[int, Request] = {}       # slot -> request
        self.queue: list[Request] = []
        self._lm_program = False
        # Why an LM config requested on the program path fell back to
        # the legacy decode loop (None = no fallback happened); callers
        # that *require* the program path (launch/serve.py --program)
        # check this instead of re-parsing the warning.
        self.fallback_reason: str | None = None
        # Stateful-program counters (exposed for benchmarks / CI): the
        # program path prefills each request exactly once at admission,
        # so n_prefill_recomputes stays 0 by construction.
        self.n_prefills = 0
        self.n_prefill_recomputes = 0
        self.n_decode_ticks = 0
        # Paged-KV counters: donor pages mapped at admission (prompt
        # rows *not* prefilled thanks to prefix sharing) and pages
        # forked by copy-on-write when a sharer's ring write reached a
        # shared page.
        self.n_shared_pages = 0
        self.n_cow_forks = 0
        self._pool = None                 # runtime/executor.py::PagePool
        self._slot_prompts: dict[int, tuple] = {}   # donor registry
        self._slot_len: dict[int, int] = {}         # host length mirror
        lm = isinstance(cfg, ArchConfig)
        if (program is not None or use_program) and lm:
            # Stateful LM program path: (prefill, decode) Program pair
            # sharing persistent KV regions + one ProgramState.
            from ..core.program import ProgramPair
            from ..models.transformer import compile_program_pair
            from ..runtime import executor
            if program is not None and not isinstance(program, ProgramPair):
                raise TypeError(
                    f"LM serving is stateful: pass the (prefill, decode) "
                    f"ProgramPair from models/transformer.py::"
                    f"compile_program_pair, got {type(program).__name__} "
                    f"(the per-tick prefill-recompute path was removed)")
            if program is not None:
                # Catch a geometry mismatch at construction, not as a
                # shape error mid-serve.  The pair records its compiled
                # (slots, max_len); the persistent-region shapes alone
                # cannot recover max_len for a windowed config (the row
                # count collapses to the window), so prefer the
                # recorded geometry and fall back to the region shape
                # for externally assembled pairs that left it unset.
                from ..models.transformer import kv_cache_len
                if program.paged is not None:
                    # Paged plans: pools are slot-agnostic, so geometry
                    # lives in the page table (slots rows) and the
                    # plan's virtual extent (pages_per_slot * page_size
                    # == max_len).
                    pt = next(s for s in program.decode.plan
                              .persistent_regions()
                              if s.name == "page_table")
                    checks = [(pt.shape,
                               (slots, program.paged.pages_per_slot)),
                              ((program.paged.cache_len,), (max_len,))]
                else:
                    checks = [((program.decode.plan
                                .persistent_regions()[0].shape[:2]),
                               (slots, kv_cache_len(cfg, max_len)))]
                if program.max_len is not None:
                    checks.append(((program.slots, program.max_len),
                                   (slots, max_len)))
                for got, want in checks:
                    if got != want:
                        raise ValueError(
                            f"ProgramPair compiled for slots/max_len "
                            f"{got}, engine configured for {want}")
            pair = program
            if pair is None:
                try:
                    pair = compile_program_pair(cfg, slots=slots,
                                                max_len=max_len,
                                                paged=paged,
                                                page_size=page_size,
                                                page_pool=page_pool,
                                                kv_quant=kv_quant)
                except NotImplementedError as e:
                    # Once per engine construction, never per tick.
                    # The lowering gate names the *specific* blocker
                    # (MoE dispatch, cross-attention, ...) — windowed
                    # attention is no longer one; it serves on the
                    # program path with window-sized KV regions.
                    self.fallback_reason = str(e)
                    warnings.warn(
                        f"no decode-Program lowering for {cfg.name} — "
                        f"{e}; serving through the legacy decode loop",
                        RuntimeWarning, stacklevel=2)
            if pair is not None:
                self.api = None
                self.cache = None
                self.program = pair
                self.state = executor.init_program_state(pair)
                self._prefill = executor.jitted_prefill_runner(
                    pair.prefill, impl=impl)
                self._decode = executor.jitted_decode_runner(
                    pair.decode, impl=impl)
                if pair.paged is not None:
                    # Host-side page allocator: admission, on-demand
                    # decode pages, and COW forks are decided here
                    # between jitted calls; the device sees only the
                    # synced table and whole-page copies.
                    self._pool = executor.PagePool(pair.paged, slots)
                self._lm_program = True
                return
        if (program is not None and not lm) or isinstance(cfg, CNNConfig):
            # Program fast path (CNN workloads): one compiled Program
            # per batch size, executed whole per tick — no token cache.
            from ..models.cnn import compile_program
            from ..runtime.executor import jitted_runner
            self.api = None
            self.cache = None
            self.program = (program if program is not None
                            else compile_program(cfg, batch=slots))
            self._infer = jitted_runner(self.program, impl=impl)
            return
        self.program = None
        self.api = get_model(cfg)
        self.cache = self.api.init_cache(cfg, slots, max_len)
        self._decode = jax.jit(
            lambda p, c, t: self.api.decode_step(p, c, t, cfg, impl=impl))

    @property
    def on_program_path(self) -> bool:
        """True when LM tokens are served through the compiled
        (prefill, decode) Program pair — the public signal for callers
        that *require* the program path (launch/serve.py --program);
        False means the legacy decode loop, with ``fallback_reason``
        naming why."""
        return self._lm_program

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [s for s in range(self.slots) if s not in self.live]

    def _admit(self):
        """Prefill queued requests into free slots through the decode
        path (slot-local prefill keeps the batch cache layout intact;
        batched prefill is the launch/steps.py path used for the large
        cells).

        All admissions in a tick are batched: one merge resets every
        admitted slot's cache, then the prompts are teacher-forced
        *together* — at prefill step t every admitted slot still inside
        its prompt advances at once, and a single masked merge per step
        folds exactly those slots' cache updates back (live slots'
        caches stay frozen).  Previously each admitted request ran a
        full ``jax.tree.map`` copy over all slots per prompt token."""
        admitted: list[tuple[int, Request]] = []
        for slot in self._free_slots():
            if not self.queue:
                break
            admitted.append((slot, self.queue.pop(0)))
        if not admitted:
            return
        self._reset_slots([slot for slot, _ in admitted])
        steps = max(len(req.prompt) - 1 for _, req in admitted)
        for t in range(steps):
            toks = np.zeros((self.slots,), np.int32)
            mask = np.zeros((self.slots,), bool)
            for slot, req in admitted:
                if t < len(req.prompt) - 1:
                    toks[slot] = int(req.prompt[t])
                    mask[slot] = True
            self._step_masked(jnp.asarray(toks), mask)
        for slot, req in admitted:
            req._last_token = int(req.prompt[-1])
            self.live[slot] = req

    @staticmethod
    def _batch_axis(leaf) -> int:
        """Model caches carry batch at axis 1 ((L, B, ...)); the shared
        ``pos`` vector is (B,)."""
        return 0 if leaf.ndim == 1 else 1

    def _reset_slots(self, slots: list[int]):
        """Zero the admitted slots' cache — one merge for all of them."""
        fresh = self.api.init_cache(self.cfg, 1, self.max_len)
        idx = np.asarray(slots)
        def put(c, f):
            axis = self._batch_axis(c)
            shape = list(c.shape)
            shape[axis] = len(idx)
            sel = [slice(None)] * c.ndim
            sel[axis] = idx
            return c.at[tuple(sel)].set(
                jnp.broadcast_to(f.astype(c.dtype), shape))
        self.cache = jax.tree.map(put, self.cache, fresh)

    def _step_masked(self, toks, mask: np.ndarray):
        """One batched decode step keeping only the masked slots' cache
        updates (the other slots' caches stay frozen)."""
        old_cache = self.cache
        logits, new_cache = self._decode(self.params, self.cache, toks)
        def merge(old, new):
            axis = self._batch_axis(old)
            shape = [1] * old.ndim
            shape[axis] = self.slots
            m = jnp.asarray(mask).reshape(shape)
            return jnp.where(m, new, old)
        self.cache = jax.tree.map(merge, old_cache, new_cache)
        return logits

    # -- program fast path (CNN) -------------------------------------------------
    def _program_step(self) -> list[Request]:
        """One tick on the program path: batch up to ``slots`` queued
        images, execute the compiled Program once, retire them all.
        ``out_tokens`` carries the argmax class id."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        images = np.stack([np.asarray(r.prompt) for r in batch])
        if len(batch) < self.slots:        # pad to the compiled batch
            pad = np.zeros((self.slots - len(batch),) + images.shape[1:],
                           images.dtype)
            images = np.concatenate([images, pad])
        logits = np.asarray(self._infer(
            self.params, jnp.asarray(images, self.cfg.jdtype)))
        for r, lg in zip(batch, logits):
            r.out_tokens.append(int(np.argmax(lg)))
            r.done = True
        return batch

    # -- LM program fast path ----------------------------------------------------
    def _next_token(self, req: Request, logits_row: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits_row))
        return int(np.random.default_rng(req.uid + len(req.out_tokens))
                   .choice(self.cfg.vocab, p=_softmax(logits_row)))

    def _retire_if_done(self, slot: int, req: Request, nxt: int,
                        finished: list) -> None:
        req.out_tokens.append(nxt)
        req._last_token = nxt
        if ((self.eos is not None and nxt == self.eos)
                or len(req.out_tokens) >= req.max_new_tokens):
            req.done = True
            finished.append(req)
            self.live.pop(slot, None)
            if self._pool is not None:
                # Retire the slot's pages: unref (a donor's shared
                # prefix stays resident while any sharer holds a
                # refcount) and drop it from the donor registry.
                self._pool.release(slot)
                self._slot_prompts.pop(slot, None)
                self._slot_len.pop(slot, None)

    def _lm_admit(self, finished: list) -> None:
        """Prefill queued prompts into free slots — once per request,
        ever.  Each admission runs the prefill Program: the full causal
        forward over the right-padded prompt, the block K/V written
        into the persistent cache regions at the slot, and the first
        generated token read off the prompt's last position.  Prompts
        longer than ``max_len`` condition on their most recent
        ``max_len`` tokens (the cache holds at most that much
        history).

        Free slots are recomputed per admission: a slot freed *during*
        this loop (EOS or ``max_new_tokens == 1`` on the prefill token
        retires the request inside ``_retire_if_done``) is immediately
        reusable for the next queued request instead of idling a
        tick."""
        while self.queue:
            free = self._free_slots()
            if not free:
                break
            slot = free[0]
            req = self.queue.pop(0)
            if len(req.prompt) == 0:
                raise ValueError(f"request {req.uid}: empty prompt")
            win = np.asarray(req.prompt, np.int32)[-self.max_len:]
            write_from = 0
            if self._pool is not None:
                write_from = self._paged_admit(slot, win)
                if write_from is None:
                    # Pool exhausted: the request waits (at the head of
                    # the queue) until a retirement frees pages.
                    self.queue.insert(0, req)
                    break
            padded = np.zeros((1, self.max_len), np.int32)
            padded[0, :len(win)] = win
            logits, self.state = self._prefill(
                self.params, jnp.asarray(padded), self.state, slot,
                len(win), write_from)
            # Real accounting, not a constant: a second prefill of the
            # same request (any future re-admission/recompute path)
            # shows up here — CI asserts the count stays at zero.
            if getattr(req, "_prefilled", False):
                self.n_prefill_recomputes += 1
            req._prefilled = True
            self.n_prefills += 1
            self.live[slot] = req
            nxt = self._next_token(
                req, np.asarray(logits[0, len(win) - 1]))
            self._retire_if_done(slot, req, nxt, finished)

    def _paged_admit(self, slot: int, win: np.ndarray) -> int | None:
        """Map an admitted prompt onto pool pages.  Finds the live
        donor with the longest *full-page* common prompt prefix,
        refcount-shares those donor pages into the slot's table row,
        and allocates fresh pages for the private remainder.  Returns
        ``write_from`` — the first prompt row the prefill Program
        actually writes (shared rows are scatter-redirected to the null
        page) — or None when the pool cannot hold the private pages.

        Donors whose ring write wrapped past ``max_len`` are skipped:
        the rolling overwrite has recycled their early pages, so the
        prompt is no longer resident there (sharers that mapped those
        pages *before* the wrap stay safe — the wrap write saw
        refcount > 1 and forked)."""
        from ..runtime import executor
        pool = self._pool
        prompt = tuple(int(t) for t in win)
        shared: tuple[int, ...] = ()
        for s, donor in self._slot_prompts.items():
            if self._slot_len.get(s, 0) > pool.plan.cache_len:
                continue
            cand = pool.shared_prefix_pages(s, donor, prompt)
            if len(cand) > len(shared):
                shared = cand
        if not pool.can_admit(len(prompt), len(shared)):
            return None
        write_from = pool.admit(slot, len(prompt), shared)
        self.n_shared_pages += len(shared)
        self._slot_prompts[slot] = prompt
        self._slot_len[slot] = len(prompt)
        executor.sync_page_table(self.state, self.program, pool)
        return write_from

    def _lm_program_step(self) -> list[Request]:
        """One tick on the stateful LM program path: prefill-admit
        queued requests, then advance every live slot by one token
        through the decode Program — O(1) in prompt length, no
        recompute ever.  The ProgramState (persistent cache buffers +
        per-slot lengths) is donated through the jitted runners, so the
        cache updates in place across ticks."""
        finished: list[Request] = []
        self._lm_admit(finished)
        if not self.live:
            return finished
        toks = np.zeros((self.slots,), np.int32)
        occupied = np.zeros((self.slots,), bool)
        for slot, req in self.live.items():
            toks[slot] = req._last_token
            occupied[slot] = True
        # The occupancy mask keeps dead slots inert inside run_decode:
        # no length advance, no cache-row write (slot-cache hygiene for
        # the rolling-window plans, whose prefill does not rewrite the
        # whole row region on re-admission).
        if self._pool is not None:
            # Make each live slot's write page real and private before
            # the jitted tick: allocate on-demand past the prompt,
            # COW-fork shared pages (device page copy), then push the
            # decided table.
            from ..runtime import executor
            copies = []
            for slot in self.live:
                c = self._pool.prepare_decode(slot, self._slot_len[slot])
                if c is not None:
                    copies.append(c)
            executor.sync_page_table(self.state, self.program, self._pool)
            if copies:
                executor.apply_page_copies(self.state, self.program,
                                           copies)
                self.n_cow_forks += len(copies)
        logits, self.state = self._decode(self.params, jnp.asarray(toks),
                                          self.state,
                                          jnp.asarray(occupied))
        self.n_decode_ticks += 1
        if self._pool is not None:
            for slot in self.live:
                self._slot_len[slot] += 1
        logits = np.asarray(logits)
        for slot, req in list(self.live.items()):
            nxt = self._next_token(req, logits[slot])
            self._retire_if_done(slot, req, nxt, finished)
        return finished

    # -- decode ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """One engine tick: admit, decode one token for all live slots,
        retire finished requests.  Returns requests finished this tick."""
        if self._lm_program:
            return self._lm_program_step()
        if self.program is not None:
            return self._program_step()
        self._admit()
        if not self.live:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for slot, req in self.live.items():
            toks[slot] = req._last_token
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        logits = np.asarray(logits)
        finished: list[Request] = []
        for slot, req in list(self.live.items()):
            nxt = self._next_token(req, logits[slot])
            self._retire_if_done(slot, req, nxt, finished)
        return finished

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        done = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.live and not self.queue:
                break
        return done


def _softmax(x):
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()

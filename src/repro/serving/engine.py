"""Batched serving engine: continuous batching over a fixed-slot cache.

One prefill step admits a request into a free slot (its KV/state cache
written at that slot); every decode step advances all live slots by one
token.  Slots whose sequence emits EOS (or hits max_len) are freed and
refilled from the queue — the standard continuous-batching loop, sized
so the decode step is always full-batch (the bandwidth-bound regime the
decode_32k / long_500k cells measure).

Per-slot positions come from the models' per-sequence ``pos`` vector,
so mixed-progress batches are exact (verified in tests against
single-request decoding).

CNN workloads take the **program fast path**: a ``CNNConfig`` (or an
explicit ``program=``) makes the engine stateless — each tick batches
up to ``slots`` queued image requests and executes the compiled
``core/program.py::Program`` once through ``runtime/executor.py``, so
the compiler's schedule is what serves the traffic.

Dense-LM workloads are served **statefully** (``use_program=True``):
the engine compiles the (prefill, decode) Program pair
(``models/transformer.py::compile_program_pair``) whose persistent
KV-cache regions are owned by the §5.1 allocator, and keeps one
``runtime/executor.py::ProgramState`` across ticks.  Admission runs
the prefill Program once per request — full causal forward, cache
written at the admitted slot, first token emitted from the prompt's
last position — and every subsequent tick runs the decode Program: one
token per live slot against the cache, O(1) in prompt length.  Nothing
is ever prefilled twice (``n_prefill_recomputes`` stays 0 by
construction).  Windowed-attention configs serve on the same path with
persistent KV regions sized to the window (``min(max_len,
attn_window)`` rows per slot, rolling eviction-by-overwrite — the
§5.1 plan shrinks resident state by max_len/window).  Families without
a lowering fall back to the legacy ``decode_step`` loop with a single
warning at engine construction naming the specific blocker
(``fallback_reason``).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, CNNConfig
from ..models import get_model

__all__ = ["Request", "ServingEngine"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (len,) int32 tokens, or (H, W, C) image
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 impl: str = "auto", greedy: bool = True, program=None,
                 use_program: bool = False):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_id
        self.impl = impl
        self.greedy = greedy
        self.live: dict[int, Request] = {}       # slot -> request
        self.queue: list[Request] = []
        self._lm_program = False
        # Why an LM config requested on the program path fell back to
        # the legacy decode loop (None = no fallback happened); callers
        # that *require* the program path (launch/serve.py --program)
        # check this instead of re-parsing the warning.
        self.fallback_reason: str | None = None
        # Stateful-program counters (exposed for benchmarks / CI): the
        # program path prefills each request exactly once at admission,
        # so n_prefill_recomputes stays 0 by construction.
        self.n_prefills = 0
        self.n_prefill_recomputes = 0
        self.n_decode_ticks = 0
        lm = isinstance(cfg, ArchConfig)
        if (program is not None or use_program) and lm:
            # Stateful LM program path: (prefill, decode) Program pair
            # sharing persistent KV regions + one ProgramState.
            from ..core.program import ProgramPair
            from ..models.transformer import compile_program_pair
            from ..runtime import executor
            if program is not None and not isinstance(program, ProgramPair):
                raise TypeError(
                    f"LM serving is stateful: pass the (prefill, decode) "
                    f"ProgramPair from models/transformer.py::"
                    f"compile_program_pair, got {type(program).__name__} "
                    f"(the per-tick prefill-recompute path was removed)")
            if program is not None:
                # Catch a geometry mismatch at construction, not as a
                # shape error mid-serve.  The pair records its compiled
                # (slots, max_len); the persistent-region shapes alone
                # cannot recover max_len for a windowed config (the row
                # count collapses to the window), so prefer the
                # recorded geometry and fall back to the region shape
                # for externally assembled pairs that left it unset.
                from ..models.transformer import kv_cache_len
                checks = [((program.decode.plan
                            .persistent_regions()[0].shape[:2]),
                           (slots, kv_cache_len(cfg, max_len)))]
                if program.max_len is not None:
                    checks.append(((program.slots, program.max_len),
                                   (slots, max_len)))
                for got, want in checks:
                    if got != want:
                        raise ValueError(
                            f"ProgramPair compiled for slots/max_len "
                            f"{got}, engine configured for {want}")
            pair = program
            if pair is None:
                try:
                    pair = compile_program_pair(cfg, slots=slots,
                                                max_len=max_len)
                except NotImplementedError as e:
                    # Once per engine construction, never per tick.
                    # The lowering gate names the *specific* blocker
                    # (MoE dispatch, cross-attention, ...) — windowed
                    # attention is no longer one; it serves on the
                    # program path with window-sized KV regions.
                    self.fallback_reason = str(e)
                    warnings.warn(
                        f"no decode-Program lowering for {cfg.name} — "
                        f"{e}; serving through the legacy decode loop",
                        RuntimeWarning, stacklevel=2)
            if pair is not None:
                self.api = None
                self.cache = None
                self.program = pair
                self.state = executor.init_program_state(pair)
                self._prefill = executor.jitted_prefill_runner(
                    pair.prefill, impl=impl)
                self._decode = executor.jitted_decode_runner(
                    pair.decode, impl=impl)
                self._lm_program = True
                return
        if (program is not None and not lm) or isinstance(cfg, CNNConfig):
            # Program fast path (CNN workloads): one compiled Program
            # per batch size, executed whole per tick — no token cache.
            from ..models.cnn import compile_program
            from ..runtime.executor import jitted_runner
            self.api = None
            self.cache = None
            self.program = (program if program is not None
                            else compile_program(cfg, batch=slots))
            self._infer = jitted_runner(self.program, impl=impl)
            return
        self.program = None
        self.api = get_model(cfg)
        self.cache = self.api.init_cache(cfg, slots, max_len)
        self._decode = jax.jit(
            lambda p, c, t: self.api.decode_step(p, c, t, cfg, impl=impl))

    @property
    def on_program_path(self) -> bool:
        """True when LM tokens are served through the compiled
        (prefill, decode) Program pair — the public signal for callers
        that *require* the program path (launch/serve.py --program);
        False means the legacy decode loop, with ``fallback_reason``
        naming why."""
        return self._lm_program

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [s for s in range(self.slots) if s not in self.live]

    def _admit(self):
        """Prefill queued requests into free slots through the decode
        path (slot-local prefill keeps the batch cache layout intact;
        batched prefill is the launch/steps.py path used for the large
        cells).

        All admissions in a tick are batched: one merge resets every
        admitted slot's cache, then the prompts are teacher-forced
        *together* — at prefill step t every admitted slot still inside
        its prompt advances at once, and a single masked merge per step
        folds exactly those slots' cache updates back (live slots'
        caches stay frozen).  Previously each admitted request ran a
        full ``jax.tree.map`` copy over all slots per prompt token."""
        admitted: list[tuple[int, Request]] = []
        for slot in self._free_slots():
            if not self.queue:
                break
            admitted.append((slot, self.queue.pop(0)))
        if not admitted:
            return
        self._reset_slots([slot for slot, _ in admitted])
        steps = max(len(req.prompt) - 1 for _, req in admitted)
        for t in range(steps):
            toks = np.zeros((self.slots,), np.int32)
            mask = np.zeros((self.slots,), bool)
            for slot, req in admitted:
                if t < len(req.prompt) - 1:
                    toks[slot] = int(req.prompt[t])
                    mask[slot] = True
            self._step_masked(jnp.asarray(toks), mask)
        for slot, req in admitted:
            req._last_token = int(req.prompt[-1])
            self.live[slot] = req

    @staticmethod
    def _batch_axis(leaf) -> int:
        """Model caches carry batch at axis 1 ((L, B, ...)); the shared
        ``pos`` vector is (B,)."""
        return 0 if leaf.ndim == 1 else 1

    def _reset_slots(self, slots: list[int]):
        """Zero the admitted slots' cache — one merge for all of them."""
        fresh = self.api.init_cache(self.cfg, 1, self.max_len)
        idx = np.asarray(slots)
        def put(c, f):
            axis = self._batch_axis(c)
            shape = list(c.shape)
            shape[axis] = len(idx)
            sel = [slice(None)] * c.ndim
            sel[axis] = idx
            return c.at[tuple(sel)].set(
                jnp.broadcast_to(f.astype(c.dtype), shape))
        self.cache = jax.tree.map(put, self.cache, fresh)

    def _step_masked(self, toks, mask: np.ndarray):
        """One batched decode step keeping only the masked slots' cache
        updates (the other slots' caches stay frozen)."""
        old_cache = self.cache
        logits, new_cache = self._decode(self.params, self.cache, toks)
        def merge(old, new):
            axis = self._batch_axis(old)
            shape = [1] * old.ndim
            shape[axis] = self.slots
            m = jnp.asarray(mask).reshape(shape)
            return jnp.where(m, new, old)
        self.cache = jax.tree.map(merge, old_cache, new_cache)
        return logits

    # -- program fast path (CNN) -------------------------------------------------
    def _program_step(self) -> list[Request]:
        """One tick on the program path: batch up to ``slots`` queued
        images, execute the compiled Program once, retire them all.
        ``out_tokens`` carries the argmax class id."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        images = np.stack([np.asarray(r.prompt) for r in batch])
        if len(batch) < self.slots:        # pad to the compiled batch
            pad = np.zeros((self.slots - len(batch),) + images.shape[1:],
                           images.dtype)
            images = np.concatenate([images, pad])
        logits = np.asarray(self._infer(
            self.params, jnp.asarray(images, self.cfg.jdtype)))
        for r, lg in zip(batch, logits):
            r.out_tokens.append(int(np.argmax(lg)))
            r.done = True
        return batch

    # -- LM program fast path ----------------------------------------------------
    def _next_token(self, req: Request, logits_row: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits_row))
        return int(np.random.default_rng(req.uid + len(req.out_tokens))
                   .choice(self.cfg.vocab, p=_softmax(logits_row)))

    def _retire_if_done(self, slot: int, req: Request, nxt: int,
                        finished: list) -> None:
        req.out_tokens.append(nxt)
        req._last_token = nxt
        if ((self.eos is not None and nxt == self.eos)
                or len(req.out_tokens) >= req.max_new_tokens):
            req.done = True
            finished.append(req)
            self.live.pop(slot, None)

    def _lm_admit(self, finished: list) -> None:
        """Prefill queued prompts into free slots — once per request,
        ever.  Each admission runs the prefill Program: the full causal
        forward over the right-padded prompt, the block K/V written
        into the persistent cache regions at the slot, and the first
        generated token read off the prompt's last position.  Prompts
        longer than ``max_len`` condition on their most recent
        ``max_len`` tokens (the cache holds at most that much
        history).

        Free slots are recomputed per admission: a slot freed *during*
        this loop (EOS or ``max_new_tokens == 1`` on the prefill token
        retires the request inside ``_retire_if_done``) is immediately
        reusable for the next queued request instead of idling a
        tick."""
        while self.queue:
            free = self._free_slots()
            if not free:
                break
            slot = free[0]
            req = self.queue.pop(0)
            if len(req.prompt) == 0:
                raise ValueError(f"request {req.uid}: empty prompt")
            win = np.asarray(req.prompt, np.int32)[-self.max_len:]
            padded = np.zeros((1, self.max_len), np.int32)
            padded[0, :len(win)] = win
            logits, self.state = self._prefill(
                self.params, jnp.asarray(padded), self.state, slot,
                len(win))
            # Real accounting, not a constant: a second prefill of the
            # same request (any future re-admission/recompute path)
            # shows up here — CI asserts the count stays at zero.
            if getattr(req, "_prefilled", False):
                self.n_prefill_recomputes += 1
            req._prefilled = True
            self.n_prefills += 1
            self.live[slot] = req
            nxt = self._next_token(
                req, np.asarray(logits[0, len(win) - 1]))
            self._retire_if_done(slot, req, nxt, finished)

    def _lm_program_step(self) -> list[Request]:
        """One tick on the stateful LM program path: prefill-admit
        queued requests, then advance every live slot by one token
        through the decode Program — O(1) in prompt length, no
        recompute ever.  The ProgramState (persistent cache buffers +
        per-slot lengths) is donated through the jitted runners, so the
        cache updates in place across ticks."""
        finished: list[Request] = []
        self._lm_admit(finished)
        if not self.live:
            return finished
        toks = np.zeros((self.slots,), np.int32)
        occupied = np.zeros((self.slots,), bool)
        for slot, req in self.live.items():
            toks[slot] = req._last_token
            occupied[slot] = True
        # The occupancy mask keeps dead slots inert inside run_decode:
        # no length advance, no cache-row write (slot-cache hygiene for
        # the rolling-window plans, whose prefill does not rewrite the
        # whole row region on re-admission).
        logits, self.state = self._decode(self.params, jnp.asarray(toks),
                                          self.state,
                                          jnp.asarray(occupied))
        self.n_decode_ticks += 1
        logits = np.asarray(logits)
        for slot, req in list(self.live.items()):
            nxt = self._next_token(req, logits[slot])
            self._retire_if_done(slot, req, nxt, finished)
        return finished

    # -- decode ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """One engine tick: admit, decode one token for all live slots,
        retire finished requests.  Returns requests finished this tick."""
        if self._lm_program:
            return self._lm_program_step()
        if self.program is not None:
            return self._program_step()
        self._admit()
        if not self.live:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for slot, req in self.live.items():
            toks[slot] = req._last_token
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        logits = np.asarray(logits)
        finished: list[Request] = []
        for slot, req in list(self.live.items()):
            nxt = self._next_token(req, logits[slot])
            self._retire_if_done(slot, req, nxt, finished)
        return finished

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        done = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.live and not self.queue:
                break
        return done


def _softmax(x):
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()

"""Batched serving engine: continuous batching over a fixed-slot cache.

One prefill step admits a request into a free slot (its KV/state cache
written at that slot); every decode step advances all live slots by one
token.  Slots whose sequence emits EOS (or hits max_len) are freed and
refilled from the queue — the standard continuous-batching loop, sized
so the decode step is always full-batch (the bandwidth-bound regime the
decode_32k / long_500k cells measure).

Per-slot positions come from the models' per-sequence ``pos`` vector,
so mixed-progress batches are exact (verified in tests against
single-request decoding).

CNN workloads take the **program fast path**: a ``CNNConfig`` (or an
explicit ``program=``) makes the engine stateless — each tick batches
up to ``slots`` queued image requests and executes the compiled
``core/program.py::Program`` once through ``runtime/executor.py``, so
the compiler's schedule is what serves the traffic.

Dense-LM workloads have the same fast path (``use_program=True``):
the engine compiles one Program for (slots, max_len), right-pads every
live sequence to ``max_len`` and recomputes the causal prefill each
tick — the logits at each sequence's last position are exact because
padding only sits *after* it under causal masking.  One token per live
slot per tick, continuous batching, zero cache state; the compiler's
instruction stream (matmul blocks, flash-attention tiles, fused
residual writebacks) is what serves the traffic.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, CNNConfig
from ..models import get_model

__all__ = ["Request", "ServingEngine"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (len,) int32 tokens, or (H, W, C) image
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 impl: str = "auto", greedy: bool = True, program=None,
                 use_program: bool = False):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos = eos_id
        self.impl = impl
        self.greedy = greedy
        self.live: dict[int, Request] = {}       # slot -> request
        self.queue: list[Request] = []
        self._lm_program = False
        lm = isinstance(cfg, ArchConfig)
        if (program is not None or use_program) and lm:
            # LM program fast path: one Program for (slots, max_len),
            # causal prefill recomputed per tick — no cache state.
            from ..models.transformer import compile_program
            from ..runtime.executor import jitted_runner
            self.api = None
            self.cache = None
            self.program = (program if program is not None
                            else compile_program(cfg, batch=slots,
                                                 seq=max_len))
            self._infer = jitted_runner(self.program, impl=impl)
            self._lm_program = True
            return
        if program is not None or isinstance(cfg, CNNConfig):
            # Program fast path (CNN workloads): one compiled Program
            # per batch size, executed whole per tick — no token cache.
            from ..models.cnn import compile_program
            from ..runtime.executor import jitted_runner
            self.api = None
            self.cache = None
            self.program = (program if program is not None
                            else compile_program(cfg, batch=slots))
            self._infer = jitted_runner(self.program, impl=impl)
            return
        self.program = None
        self.api = get_model(cfg)
        self.cache = self.api.init_cache(cfg, slots, max_len)
        self._decode = jax.jit(
            lambda p, c, t: self.api.decode_step(p, c, t, cfg, impl=impl))

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [s for s in range(self.slots) if s not in self.live]

    def _admit(self):
        """Prefill queued requests into free slots, one token at a time
        through the decode path (slot-local prefill keeps the batch
        cache layout intact; batched prefill is the launch/steps.py
        path used for the large cells)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self._reset_slot(slot)
            # feed the prompt token-by-token (teacher forcing)
            for t in req.prompt[:-1]:
                self._step_single(slot, int(t))
            req._last_token = int(req.prompt[-1])
            self.live[slot] = req

    @staticmethod
    def _batch_axis(leaf) -> int:
        """Model caches carry batch at axis 1 ((L, B, ...)); the shared
        ``pos`` vector is (B,)."""
        return 0 if leaf.ndim == 1 else 1

    def _reset_slot(self, slot: int):
        fresh = self.api.init_cache(self.cfg, 1, self.max_len)
        def put(c, f):
            axis = self._batch_axis(c)
            idx = [slice(None)] * c.ndim
            idx[axis] = slice(slot, slot + 1)
            return c.at[tuple(idx)].set(f.astype(c.dtype))
        self.cache = jax.tree.map(put, self.cache, fresh)

    def _step_single(self, slot: int, token: int):
        """Advance one slot only (prefill path): run the batched decode
        with the other slots' outputs discarded but their caches frozen."""
        toks = np.zeros((self.slots,), np.int32)
        toks[slot] = token
        old_cache = self.cache
        logits, new_cache = self._decode(self.params, self.cache,
                                         jnp.asarray(toks))
        # keep only this slot's cache updates
        def merge(old, new):
            axis = self._batch_axis(old)
            idx = [slice(None)] * old.ndim
            idx[axis] = slice(slot, slot + 1)
            return old.at[tuple(idx)].set(
                jax.lax.slice_in_dim(new, slot, slot + 1, axis=axis))
        self.cache = jax.tree.map(merge, old_cache, new_cache)
        return logits[slot]

    # -- program fast path (CNN) -------------------------------------------------
    def _program_step(self) -> list[Request]:
        """One tick on the program path: batch up to ``slots`` queued
        images, execute the compiled Program once, retire them all.
        ``out_tokens`` carries the argmax class id."""
        if not self.queue:
            return []
        batch, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        images = np.stack([np.asarray(r.prompt) for r in batch])
        if len(batch) < self.slots:        # pad to the compiled batch
            pad = np.zeros((self.slots - len(batch),) + images.shape[1:],
                           images.dtype)
            images = np.concatenate([images, pad])
        logits = np.asarray(self._infer(
            self.params, jnp.asarray(images, self.cfg.jdtype)))
        for r, lg in zip(batch, logits):
            r.out_tokens.append(int(np.argmax(lg)))
            r.done = True
        return batch

    # -- LM program fast path ----------------------------------------------------
    def _next_token(self, req: Request, logits_row: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits_row))
        return int(np.random.default_rng(req.uid + len(req.out_tokens))
                   .choice(self.cfg.vocab, p=_softmax(logits_row)))

    def _lm_program_step(self) -> list[Request]:
        """One tick on the LM program path: admit queued prompts into
        free slots, run the compiled Program once over all live
        sequences (right-padded to ``max_len``; causal masking keeps
        logits at the last live position exact), append one token per
        slot, retire finished requests.  Sequences longer than
        ``max_len`` condition on a sliding window of the most recent
        ``max_len`` tokens (the program-path analogue of the legacy
        rolling cache), so ``max_new_tokens`` is always honored."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            if len(req.prompt) == 0:
                raise ValueError(f"request {req.uid}: empty prompt")
            req._tokens = [int(t) for t in req.prompt]
            self.live[slot] = req
        if not self.live:
            return []
        toks = np.zeros((self.slots, self.max_len), np.int32)
        last = np.zeros((self.slots,), np.int32)  # slot -> live logit index
        for slot, req in self.live.items():
            win = req._tokens[-self.max_len:]
            toks[slot, :len(win)] = win
            last[slot] = len(win) - 1
        out = self._infer(self.params, jnp.asarray(toks))
        # Gather each slot's one live vocab row on device; copying the
        # full (slots, max_len, vocab) logits to host every tick would
        # dominate the tick.
        logits = np.asarray(out[jnp.arange(self.slots), jnp.asarray(last)])
        finished = []
        for slot, req in list(self.live.items()):
            nxt = self._next_token(req, logits[slot])
            req.out_tokens.append(nxt)
            req._tokens.append(nxt)
            if ((self.eos is not None and nxt == self.eos)
                    or len(req.out_tokens) >= req.max_new_tokens):
                req.done = True
                finished.append(req)
                del self.live[slot]
        return finished

    # -- decode ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """One engine tick: admit, decode one token for all live slots,
        retire finished requests.  Returns requests finished this tick."""
        if self._lm_program:
            return self._lm_program_step()
        if self.program is not None:
            return self._program_step()
        self._admit()
        if not self.live:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for slot, req in self.live.items():
            toks[slot] = req._last_token
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        logits = np.asarray(logits)
        finished = []
        for slot, req in list(self.live.items()):
            nxt = self._next_token(req, logits[slot])
            req.out_tokens.append(nxt)
            req._last_token = nxt
            if ((self.eos is not None and nxt == self.eos)
                    or len(req.out_tokens) >= req.max_new_tokens):
                req.done = True
                finished.append(req)
                del self.live[slot]
        return finished

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        done = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.live and not self.queue:
                break
        return done


def _softmax(x):
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()

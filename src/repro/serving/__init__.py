from .admission import AdmissionQueue, AdmissionTicket
from .engine import Request, ServingEngine

__all__ = ["AdmissionQueue", "AdmissionTicket", "Request", "ServingEngine"]

"""Async request admission: a bounded queue with typed backpressure.

Request ingestion is decoupled from the engine tick loop: producers
call ``AdmissionQueue.submit`` (thread-safe, so an RPC/IO thread can
feed a serving loop running elsewhere) and get back an
``AdmissionTicket`` *immediately* — accepted-and-queued, or rejected
with a typed reason when the queue is at capacity.  The engine drains
the queue at tick boundaries; when admission itself stalls (no free
slot, page pool exhausted), the engine records the typed reason here
and the stalled request is requeued **at the head**, so a starved
request can never be overtaken by later arrivals — FIFO admission is a
liveness guarantee, not a best effort (regression-tested in
tests/test_serving_loop.py).

Backpressure states (``AdmissionTicket.reason`` / ``last_blocked``):

* ``queue_full``       — rejected at submit; the caller sheds or retries.
* ``no_free_slot``     — queued; every slot is live or mid-prefill.
* ``pages_exhausted``  — queued at head; the §5.1 page pool cannot hold
  the prompt's private pages until a retirement frees some.

All accounting lives on an ``obs.MetricsRegistry`` —
``admission_rejected_total`` / ``admission_requeued_total`` /
``admission_blocked_total{reason=...}`` — shared with the engine that
owns this queue (one metrics plane per serving process); the legacy
``n_rejected`` / ``n_requeued`` / ``blocked`` attributes are kept as
read-through views so existing callers and tests see the same numbers.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass

from ..obs import MetricsRegistry

__all__ = ["AdmissionQueue", "AdmissionTicket", "QUEUE_FULL",
           "NO_FREE_SLOT", "PAGES_EXHAUSTED"]

QUEUE_FULL = "queue_full"
NO_FREE_SLOT = "no_free_slot"
PAGES_EXHAUSTED = "pages_exhausted"


@dataclass(frozen=True)
class AdmissionTicket:
    """What ``submit`` hands back: ``accepted`` means the request is in
    the queue (``position`` = 0-based depth at enqueue time);
    ``reason`` is ``"queued"`` or the typed backpressure reason the
    request bounced on (``queue_full``)."""
    accepted: bool
    reason: str
    position: int | None = None


class AdmissionQueue:
    """Bounded FIFO between request producers and the engine tick loop.

    All mutation is under one lock — ``submit`` may run on any thread;
    ``pop``/``requeue_front`` are engine-side (tick boundary).  The
    queue never blocks: a full queue *rejects* (typed ticket) rather
    than parking the producer, which keeps backpressure visible to the
    caller instead of hidden in a blocked thread."""

    def __init__(self, capacity: int | None = None,
                 registry: MetricsRegistry | None = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"queue capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._dq: collections.deque = collections.deque()
        self._lock = threading.Lock()
        # Typed-backpressure accounting on the metrics plane (the
        # engine passes its registry; a standalone queue gets its own).
        self._registry = registry if registry is not None \
            else MetricsRegistry()
        self._c_rejected = self._registry.counter(
            "admission_rejected_total",
            help="queue_full bounces at submit")
        self._c_requeued = self._registry.counter(
            "admission_requeued_total",
            help="head requeues (pages_exhausted)")
        self._c_blocked: dict = {}
        self.last_blocked: str | None = None

    def _blocked_counter(self, reason: str):
        c = self._c_blocked.get(reason)
        if c is None:
            c = self._registry.counter(
                "admission_blocked_total",
                help="backpressure stalls by typed reason",
                reason=reason)
            self._c_blocked[reason] = c
        return c

    @property
    def n_rejected(self) -> int:
        return int(self._c_rejected.value)

    @property
    def n_requeued(self) -> int:
        return int(self._c_requeued.value)

    @property
    def blocked(self) -> collections.Counter:
        """Read-through view of ``admission_blocked_total`` by reason
        (a ``collections.Counter``, so absent reasons read as 0)."""
        return collections.Counter(
            {r: int(c.value) for r, c in self._c_blocked.items()})

    def submit(self, req) -> AdmissionTicket:
        with self._lock:
            if (self.capacity is not None
                    and len(self._dq) >= self.capacity):
                self._c_rejected.inc()
                self._blocked_counter(QUEUE_FULL).inc()
                self.last_blocked = QUEUE_FULL
                return AdmissionTicket(False, QUEUE_FULL)
            self._dq.append(req)
            return AdmissionTicket(True, "queued", len(self._dq) - 1)

    def pop(self):
        """Next request to admit, or None when empty (engine-side)."""
        with self._lock:
            return self._dq.popleft() if self._dq else None

    def requeue_front(self, req, reason: str) -> None:
        """Put a request the engine could not admit back at the *head*
        of the queue: it retries before anything that arrived after it
        (no overtaking), and the typed ``reason`` is recorded."""
        with self._lock:
            self._dq.appendleft(req)
            self._c_requeued.inc()
            self._blocked_counter(reason).inc()
            self.last_blocked = reason

    def note_blocked(self, reason: str) -> None:
        """Record a backpressure stall that did not dequeue anything
        (e.g. ``no_free_slot`` observed before a pop)."""
        with self._lock:
            self._blocked_counter(reason).inc()
            self.last_blocked = reason

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._dq)

    def __len__(self) -> int:
        return self.pending

    def __bool__(self) -> bool:
        return self.pending > 0

"""RWKV6 (Finch) — attention-free LM with data-dependent per-channel
decay.  Time-mix (WKV recurrence via kernels/rwkv6) + channel-mix
blocks, token-shift interpolation, LoRA-generated decay.

State per layer for decode: the (H, D, D) WKV state plus the two
token-shift vectors — O(1) in sequence length, which is why rwkv6 runs
the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from ..configs.base import ArchConfig
from ..core.ir import ModelGraph, embed_node, matmul_node, norm_node, wkv_node
from ..core.regions import PersistentSpec, StateCaps, register_state_family
from ..kernels.rwkv6 import wkv6, wkv6_decode_step
from ..parallel.act_sharding import shard_act
from .common import ParamDef, layer_norm, rms_norm

__all__ = ["param_defs", "forward", "init_cache", "decode_step",
           "to_graph", "to_decode_graph", "block_prefill", "block_decode"]

_LORA = 64


def param_defs(cfg: ArchConfig) -> dict:
    dt = cfg.jdtype
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    H = D // cfg.hd

    def p(shape, axes, init="normal"):
        return ParamDef((L,) + shape, ("layers",) + axes, dt, init)

    blocks = {
        "ln1": p((D,), ("embed",), "ones"),
        "ln1_b": p((D,), ("embed",), "zeros"),
        "ln2": p((D,), ("embed",), "ones"),
        "ln2_b": p((D,), ("embed",), "zeros"),
        # time mix
        "mu_r": p((D,), ("embed",), "zeros"),
        "mu_k": p((D,), ("embed",), "zeros"),
        "mu_v": p((D,), ("embed",), "zeros"),
        "mu_w": p((D,), ("embed",), "zeros"),
        "mu_g": p((D,), ("embed",), "zeros"),
        "w_base": p((D,), ("embed",), "zeros"),
        "w_lora_a": p((D, _LORA), ("embed", None)),
        "w_lora_b": p((_LORA, D), (None, "embed")),
        "u": p((H, cfg.hd), (None, None), "zeros"),
        "wr": p((D, D), ("embed", "heads")),
        "wk": p((D, D), ("embed", "heads")),
        "wv": p((D, D), ("embed", "heads")),
        "wg": p((D, D), ("embed", "heads")),
        "wo": p((D, D), ("heads", "embed")),
        "ln_x": p((D,), ("embed",), "ones"),
        # channel mix
        "mu_ck": p((D,), ("embed",), "zeros"),
        "mu_cr": p((D,), ("embed",), "zeros"),
        "wc_r": p((D, D), ("embed", "ff")),
        "wc_in": p((D, F), ("embed", "ff")),
        "wc_out": p((F, D), ("ff", "embed")),
    }
    return {
        "embed": ParamDef((cfg.vocab, D), ("vocab", "embed"), dt, "embed"),
        "ln_in": ParamDef((D,), ("embed",), dt, "ones"),
        "ln_in_b": ParamDef((D,), ("embed",), dt, "zeros"),
        "blocks": blocks,
        "final_norm": ParamDef((D,), ("embed",), dt, "ones"),
        "final_norm_b": ParamDef((D,), ("embed",), dt, "zeros"),
        "lm_head": ParamDef((D, cfg.vocab), ("embed", "vocab"), dt),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros or carried state at t=0)."""
    prev = x[:, :-1]
    first = (jnp.zeros_like(x[:, :1]) if last is None else last[:, None])
    return jnp.concatenate([first, prev], axis=1)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu[None, None]


def _last_row(h, length):
    """h (B, S, D) -> the last *valid* row (B, D): S-1, or length-1 on
    a right-padded block (Program prefill pins (1, max_len))."""
    if length is None:
        return h[:, -1]
    return h[:, length - 1]


def _time_mix(h, p, hd, *, impl, wkv_state=None, shift_state=None,
              length=None, return_state=False):
    B, S, D = h.shape
    H = D // hd
    xx = _shift(h, shift_state)
    r = _lerp(h, xx, p["mu_r"]) @ p["wr"]
    k = _lerp(h, xx, p["mu_k"]) @ p["wk"]
    v = _lerp(h, xx, p["mu_v"]) @ p["wv"]
    g = jax.nn.silu((_lerp(h, xx, p["mu_g"]) @ p["wg"]).astype(jnp.float32))
    xw = _lerp(h, xx, p["mu_w"])
    w_log = (p["w_base"][None, None].astype(jnp.float32)
             + jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(
                 jnp.float32)) @ p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log))                       # (B, S, D) in (0,1)
    if length is not None:
        # Right-padded rows are recurrence identities: k=0 contributes
        # nothing, w=1 decays nothing, so the state after the scan is
        # exactly the state at the true length (pad-row *outputs* are
        # garbage, but causality keeps them out of every valid row).
        valid = (jnp.arange(S) < length)[None, :, None]
        k = jnp.where(valid, k, 0.0)
        w = jnp.where(valid, w, 1.0)

    def heads(a):
        return a.reshape(B, S, H, hd)

    y, s_new = wkv6(heads(r), heads(k), heads(v),
                    heads(w.astype(h.dtype)), p["u"], s0=wkv_state,
                    return_state=True, impl=impl)
    y = y.reshape(B, S, D)
    y = rms_norm(y, p["ln_x"])                         # per-channel norm
    out = (y.astype(jnp.float32) * g).astype(h.dtype) @ p["wo"]
    if return_state:
        return out, s_new, _last_row(h, length)
    return out


def _channel_mix(h, p, *, shift_state=None, length=None,
                 return_state=False):
    xx = _shift(h, shift_state)
    kx = _lerp(h, xx, p["mu_ck"]) @ p["wc_in"]
    k = jnp.square(jnp.maximum(kx.astype(jnp.float32), 0.0))
    r = jax.nn.sigmoid((_lerp(h, xx, p["mu_cr"]) @ p["wc_r"]
                        ).astype(jnp.float32))
    out = (r * (k.astype(h.dtype) @ p["wc_out"]).astype(jnp.float32)
           ).astype(h.dtype)
    if return_state:
        return out, _last_row(h, length)
    return out


def _block_seq(carry, p_i, hd, *, impl, wkv_state=None, shift_t=None,
               shift_c=None, length=None, want_state=False):
    """One rwkv block over a (B, S, D) sequence — ln1 + time-mix +
    residual, ln2 + channel-mix + residual.  The single emitter behind
    the legacy ``forward`` body, and the Program executor's ``wkv``
    prefill op (length-masked), so the two can never drift apart."""
    a_in = layer_norm(carry, p_i["ln1"], p_i["ln1_b"])
    if want_state:
        a, s_new, sh1 = _time_mix(a_in, p_i, hd, impl=impl,
                                  wkv_state=wkv_state, shift_state=shift_t,
                                  length=length, return_state=True)
    else:
        a = _time_mix(a_in, p_i, hd, impl=impl, wkv_state=wkv_state,
                      shift_state=shift_t, length=length)
        s_new = sh1 = None
    carry = carry + a
    c_in = layer_norm(carry, p_i["ln2"], p_i["ln2_b"])
    if want_state:
        c, sh2 = _channel_mix(c_in, p_i, shift_state=shift_c,
                              length=length, return_state=True)
    else:
        c = _channel_mix(c_in, p_i, shift_state=shift_c, length=length)
        sh2 = None
    carry = shard_act(carry + c, "hidden")
    return carry, (s_new, sh1, sh2)


def forward(params, tokens, cfg: ArchConfig, *, impl: str = "auto",
            return_cache: bool = False, cache_len: int | None = None,
            remat: bool = False, return_hidden: bool = False):
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.jdtype)
    h = layer_norm(h, params["ln_in"], params["ln_in_b"])
    h = shard_act(h, "hidden")

    def body(carry, p_i):
        carry, states = _block_seq(carry, p_i, cfg.hd, impl=impl,
                                   want_state=return_cache)
        return carry, (states if return_cache else None)

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, ys = jax.lax.scan(body, h, params["blocks"])
    h = layer_norm(h, params["final_norm"], params["final_norm_b"])
    logits = (None if return_hidden
              else shard_act(h @ params["lm_head"], "logits"))
    out = {"logits": logits, "aux": {}}
    if return_hidden:
        out["hidden"] = h
    if return_cache:
        s_stack, sh1_stack, sh2_stack = ys
        out["cache"] = {"wkv": s_stack, "shift_t": sh1_stack,
                        "shift_c": sh2_stack,
                        "pos": jnp.full((B,), S, jnp.int32)}
    return out


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    D = cfg.d_model
    H, hd = D // cfg.hd, cfg.hd
    L = cfg.n_layers
    return {
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((L, batch, D), cfg.jdtype),
        "shift_c": jnp.zeros((L, batch, D), cfg.jdtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _block_step(carry, p_i, s_i, sh1_i, sh2_i):
    """One rwkv block for one token per sequence — carry (B, D), wkv
    state (B, H, hd, hd) f32, shift rows (B, D).  Shared by the legacy
    ``decode_step`` scan body and the executor's ``wkv`` decode op;
    head geometry derives from the params (u is (H, hd)), so the
    executor never consults the model config."""
    B, D = carry.shape
    H, hd = p_i["u"].shape
    x1 = layer_norm(carry, p_i["ln1"], p_i["ln1_b"])
    xx = sh1_i
    def mix(mu):
        return x1 + (xx - x1) * mu[None]
    r = (mix(p_i["mu_r"]) @ p_i["wr"]).reshape(B, H, hd)
    k = (mix(p_i["mu_k"]) @ p_i["wk"]).reshape(B, H, hd)
    v = (mix(p_i["mu_v"]) @ p_i["wv"]).reshape(B, H, hd)
    g = jax.nn.silu((mix(p_i["mu_g"]) @ p_i["wg"]).astype(jnp.float32))
    w_log = (p_i["w_base"][None].astype(jnp.float32)
             + jnp.tanh(mix(p_i["mu_w"]).astype(jnp.float32)
                        @ p_i["w_lora_a"].astype(jnp.float32))
             @ p_i["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, H, hd)
    y, s_new = wkv6_decode_step(s_i, r, k, v.astype(jnp.float32), w,
                                p_i["u"])
    y = rms_norm(y.reshape(B, D), p_i["ln_x"])
    carry = carry + (y.astype(jnp.float32) * g).astype(carry.dtype) \
        @ p_i["wo"]
    x2 = layer_norm(carry, p_i["ln2"], p_i["ln2_b"])
    xx2 = sh2_i
    kx = (x2 + (xx2 - x2) * p_i["mu_ck"][None]) @ p_i["wc_in"]
    kk = jnp.square(jnp.maximum(kx.astype(jnp.float32), 0.0))
    rr = jax.nn.sigmoid(((x2 + (xx2 - x2) * p_i["mu_cr"][None])
                         @ p_i["wc_r"]).astype(jnp.float32))
    carry = carry + (rr * (kk.astype(carry.dtype) @ p_i["wc_out"]
                           ).astype(jnp.float32)).astype(carry.dtype)
    return carry, (s_new, x1, x2)


def decode_step(params, cache, tokens, cfg: ArchConfig, *,
                impl: str = "auto"):
    h = params["embed"][tokens].astype(cfg.jdtype)
    h = layer_norm(h, params["ln_in"], params["ln_in_b"])

    def body(carry, xs):
        p_i, s_i, sh1_i, sh2_i = xs
        return _block_step(carry, p_i, s_i, sh1_i, sh2_i)

    h, (s_new, sh1_new, sh2_new) = jax.lax.scan(
        body, h, (params["blocks"], cache["wkv"], cache["shift_t"],
                  cache["shift_c"]))
    h = layer_norm(h, params["final_norm"], params["final_norm_b"])
    logits = h @ params["lm_head"]
    new_cache = {"wkv": s_new, "shift_t": sh1_new, "shift_c": sh2_new,
                 "pos": cache["pos"] + 1}
    return logits, new_cache


# --- Program lowering (generic named state) ---------------------------------------
def block_prefill(h, p_i, *, impl="auto", length=None):
    """Executor entry for one ``wkv`` prefill op: h (B, S, D) right-
    padded to S with ``length`` valid rows, states zero-initialised
    (prefill always restarts a slot).  Returns (out (B, S, D),
    (wkv (B, H, hd, hd) f32, shift_t (B, D), shift_c (B, D)))."""
    hd = p_i["u"].shape[1]
    out, (s, sh1, sh2) = _block_seq(h, p_i, hd, impl=impl, length=length,
                                    want_state=True)
    return out, (s, sh1, sh2)


def block_decode(h, p_i, wkv_state, shift_t, shift_c):
    """Executor entry for one ``wkv`` decode op: h (slots, D), one
    token per slot against the per-slot states."""
    return _block_step(h, p_i, wkv_state, shift_t, shift_c)


def _state_names(i: int) -> tuple[str, str, str]:
    """Per-layer persistent state names, in ProgramOp.state_regions
    order (wkv matrix, time-mix shift row, channel-mix shift row)."""
    return (f"l{i}.wkv_s", f"l{i}.shift_t", f"l{i}.shift_c")


def to_graph(cfg: ArchConfig, batch: int = 1, seq: int = 64,
             dtype_bytes: int | None = None,
             write_cache: bool = False) -> ModelGraph:
    """Lower rwkv6 to the compiler IR: embed -> input LN -> one coarse
    ``wkv`` block op per layer (ln1 + time-mix + ln2 + channel-mix,
    both residuals internal) -> final LN -> lm head.  The block is one
    op because its recurrence is a single fused kernel anyway
    (kernels/rwkv6); ``write_cache`` names the per-layer persistent
    state regions the op scatters at the admitted slot."""
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    D = cfg.d_model
    H, hd = D // cfg.hd, cfg.hd
    g = ModelGraph(cfg.name)
    g.add(embed_node("embed", batch * seq, cfg.vocab, D, dtype_bytes=by,
                     param="embed"))
    g.add(norm_node("ln_in", batch * seq * D, dtype_bytes=by,
                    inputs=["embed"], norm="layernorm", param="ln_in",
                    param_b="ln_in_b"))
    prev = "ln_in"
    for i in range(cfg.n_layers):
        names = _state_names(i)
        g.add(wkv_node(
            f"l{i}.wkv", seq=seq, heads=H, head_dim=hd, d_model=D,
            batch=batch, dtype_bytes=by, inputs=[prev],
            param=f"blocks:{i}",
            **({"states": names} if write_cache else {})))
        prev = f"l{i}.wkv"
    g.add(norm_node("final_norm", batch * seq * D, dtype_bytes=by,
                    inputs=[prev], norm="layernorm", param="final_norm",
                    param_b="final_norm_b"))
    g.add(matmul_node("lm_head", batch * seq, D, cfg.vocab,
                      dtype_bytes=by, inputs=["final_norm"],
                      param="lm_head"))
    return g


def to_decode_graph(cfg: ArchConfig, slots: int = 8,
                    max_len: int = 256,
                    dtype_bytes: int | None = None) -> ModelGraph:
    """One token per slot (M = slots, seq = 1); the same coarse block
    op reads/writes the per-slot states — O(1) in ``max_len``, which is
    exactly why the spec shapes carry no sequence axis."""
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    D = cfg.d_model
    H, hd = D // cfg.hd, cfg.hd
    g = ModelGraph(cfg.name + ".decode")
    g.add(embed_node("embed", slots, cfg.vocab, D, dtype_bytes=by,
                     param="embed"))
    g.add(norm_node("ln_in", slots * D, dtype_bytes=by, inputs=["embed"],
                    norm="layernorm", param="ln_in", param_b="ln_in_b"))
    prev = "ln_in"
    for i in range(cfg.n_layers):
        g.add(wkv_node(
            f"l{i}.wkv", seq=1, heads=H, head_dim=hd, d_model=D,
            batch=slots, dtype_bytes=by, inputs=[prev],
            param=f"blocks:{i}", states=_state_names(i), decode=True))
        prev = f"l{i}.wkv"
    g.add(norm_node("final_norm", slots * D, dtype_bytes=by,
                    inputs=[prev], norm="layernorm", param="final_norm",
                    param_b="final_norm_b"))
    g.add(matmul_node("lm_head", slots, D, cfg.vocab, dtype_bytes=by,
                      inputs=["final_norm"], param="lm_head"))
    return g


def _rwkv_state_specs(cfg: ArchConfig, slots: int, max_len: int):
    """Per-layer wkv matrix (f32, like the legacy cache) + the two
    token-shift rows.  No sequence axis anywhere: rwkv state is O(1)
    in ``max_len``, so none of the KV serving features apply — not
    pageable (nothing row-granular to page), not windowed, not
    chunkable (the recurrence is order-sensitive), not speculatable
    (no length-truncation rollback)."""
    D = cfg.d_model
    H, hd = D // cfg.hd, cfg.hd
    dt = jnp.dtype(cfg.jdtype)
    specs = []
    for i in range(cfg.n_layers):
        wkv_name, sh1, sh2 = _state_names(i)
        s_shape = (slots, H, hd, hd)
        r_shape = (slots, D)
        specs.append(PersistentSpec(
            wkv_name, s_shape, "float32", int(np.prod(s_shape)) * 4))
        specs.append(PersistentSpec(
            sh1, r_shape, dt.name, int(np.prod(r_shape)) * dt.itemsize))
        specs.append(PersistentSpec(
            sh2, r_shape, dt.name, int(np.prod(r_shape)) * dt.itemsize))
    return tuple(specs), StateCaps()


register_state_family("ssm", _rwkv_state_specs)

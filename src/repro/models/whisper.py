"""Whisper-style encoder-decoder backbone.

Per the assignment spec the modality frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, T_enc, D) standing in for the
log-mel + conv1d stem.  The backbone is faithful: pre-LN layernorm
blocks, non-gated GELU MLPs, sinusoidal encoder positions, learned
decoder positions, tied decoder embedding head, cross-attention in every
decoder layer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

import numpy as np

from ..configs.base import ArchConfig
from ..core.ir import (ModelGraph, attention_node, cross_attention_node,
                       decode_attention_node, embed_node, matmul_node,
                       norm_node)
from ..core.regions import (PersistentSpec, StateCaps,
                            register_state_family)
from ..kernels.decode_attention import decode_attention
from ..parallel.act_sharding import shard_act
from .common import ParamDef, layer_norm
from .transformer import (_attention, _attn_defs, _heads, _mlp,
                          _write_cache)

__all__ = ["param_defs", "forward", "init_cache", "decode_step",
           "encode_memory", "to_graph", "to_decode_graph"]


def _ln_defs(cfg, L, name):
    dt = cfg.jdtype
    shape = (L, cfg.d_model) if L else (cfg.d_model,)
    axes = ("layers", "embed") if L else ("embed",)
    return {name: ParamDef(shape, axes, dt, "ones"),
            name + "_b": ParamDef(shape, axes, dt, "zeros")}


def param_defs(cfg: ArchConfig) -> dict:
    dt = cfg.jdtype
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    enc = {}
    enc.update(_ln_defs(cfg, Le, "attn_norm"))
    enc.update(_attn_defs(cfg, Le))
    enc.update(_ln_defs(cfg, Le, "mlp_norm"))
    enc["w_gate"] = ParamDef((Le, cfg.d_model, cfg.d_ff),
                             ("layers", "embed", "ff"), dt)
    enc["w_down"] = ParamDef((Le, cfg.d_ff, cfg.d_model),
                             ("layers", "ff", "embed"), dt)
    dec = {}
    dec.update(_ln_defs(cfg, Ld, "attn_norm"))
    dec.update(_attn_defs(cfg, Ld))
    dec.update(_ln_defs(cfg, Ld, "cross_norm"))
    dec.update({"x" + k: v for k, v in _attn_defs(cfg, Ld).items()})
    dec.update(_ln_defs(cfg, Ld, "mlp_norm"))
    dec["w_gate"] = ParamDef((Ld, cfg.d_model, cfg.d_ff),
                             ("layers", "embed", "ff"), dt)
    dec["w_down"] = ParamDef((Ld, cfg.d_ff, cfg.d_model),
                             ("layers", "ff", "embed"), dt)
    defs = {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                          dt, "embed"),
        "pos_embed": ParamDef((cfg.max_pos or 4096, cfg.d_model),
                              (None, "embed"), dt, "embed"),
        "enc_blocks": enc,
        "dec_blocks": dec,
    }
    defs.update(_ln_defs(cfg, None, "enc_final_norm"))
    defs.update(_ln_defs(cfg, None, "final_norm"))
    return defs


def _sinusoid(T: int, D: int) -> jax.Array:
    half = D // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(T)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class _WhisperCfg:
    """Proxy making the shared transformer helpers use layernorm."""

    def __init__(self, cfg):
        object.__setattr__(self, "_c", cfg)

    def __getattr__(self, k):
        if k == "norm":
            return "layernorm"
        if k in ("gated_mlp",):
            return False
        if k == "activation":
            return "gelu"
        if k == "n_experts":
            return 0
        return getattr(self._c, k)


def encode(params, frames, cfg: ArchConfig, *, impl="auto"):
    """frames: (B, T_enc, D) stub embeddings -> (B, T_enc, D)."""
    c = _WhisperCfg(cfg)
    h = frames.astype(cfg.jdtype) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(cfg.jdtype)[None]
    h = shard_act(h, "hidden")

    def body(carry, p_i):
        a = _attention(layer_norm(carry, p_i["attn_norm"],
                                  p_i["attn_norm_b"]),
                       p_i, c, None, None, impl=impl, causal=False)
        carry = carry + a
        m, _ = _mlp(layer_norm(carry, p_i["mlp_norm"], p_i["mlp_norm_b"]),
                    p_i, c)
        return shard_act(carry + m, "hidden"), None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return layer_norm(h, params["enc_final_norm"],
                      params["enc_final_norm_b"])


def forward(params, tokens, cfg: ArchConfig, *, encoder_frames=None,
            impl: str = "auto", return_cache: bool = False,
            cache_len: int | None = None, remat: bool = False,
            return_hidden: bool = False):
    """Decoder forward given stub encoder frames."""
    assert encoder_frames is not None, "whisper needs encoder_frames"
    c = _WhisperCfg(cfg)
    enc_out = encode(params, encoder_frames, cfg, impl=impl)
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.jdtype)
    h = h + params["pos_embed"][:S][None].astype(cfg.jdtype)
    h = shard_act(h, "hidden")

    def body(carry, p_i):
        a, kv = _attention(layer_norm(carry, p_i["attn_norm"],
                                      p_i["attn_norm_b"]),
                           p_i, c, None, None, impl=impl, causal=True,
                           return_kv=True)
        carry = carry + a
        xp = {k[1:]: v for k, v in p_i.items() if k.startswith("x")}
        xa = _attention(layer_norm(carry, p_i["cross_norm"],
                                   p_i["cross_norm_b"]),
                        xp, c, None, None, impl=impl, causal=False,
                        kv_override=enc_out)
        carry = carry + xa
        m, _ = _mlp(layer_norm(carry, p_i["mlp_norm"], p_i["mlp_norm_b"]),
                    p_i, c)
        carry = shard_act(carry + m, "hidden")
        return carry, kv if return_cache else None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, kvs = jax.lax.scan(body, h, params["dec_blocks"])
    h = layer_norm(h, params["final_norm"], params["final_norm_b"])
    logits = (None if return_hidden
              else shard_act(h @ params["embed"].T, "logits"))
    out = {"logits": logits, "aux": {}}
    if return_hidden:
        out["hidden"] = h
    if return_cache:
        k_stack, v_stack = kvs
        CL = cache_len or S
        if CL > S:
            padw = ((0, 0),) * 3 + ((0, CL - S), (0, 0))
            k_stack = jnp.pad(k_stack, padw)
            v_stack = jnp.pad(v_stack, padw)
        cache = {"k": k_stack.astype(cfg.kv_jdtype),
                 "v": v_stack.astype(cfg.kv_jdtype),
                 "pos": jnp.full((B,), S, jnp.int32)}
        xk, xv = _cross_kv(params, cfg, enc_out)
        cache["cross_k"] = xk.astype(cfg.kv_jdtype)
        cache["cross_v"] = xv.astype(cfg.kv_jdtype)
        out["cache"] = cache
    return out


def _cross_kv(params, cfg, enc_out):
    KV, hd = cfg.n_kv_heads, cfg.hd
    def one(p):
        return (_heads(enc_out @ p["xwk"], KV, hd),
                _heads(enc_out @ p["xwv"], KV, hd))
    return jax.vmap(one)(params["dec_blocks"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               encoder_seq: int | None = None) -> dict:
    KV, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    dt = cfg.kv_jdtype
    Te = encoder_seq or cfg.encoder_seq
    return {
        "k": jnp.zeros((L, batch, KV, max_len, hd), dt),
        "v": jnp.zeros((L, batch, KV, max_len, hd), dt),
        "cross_k": jnp.zeros((L, batch, KV, Te, hd), dt),
        "cross_v": jnp.zeros((L, batch, KV, Te, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg: ArchConfig, *,
                impl: str = "auto"):
    c = _WhisperCfg(cfg)
    B = tokens.shape[0]
    pos = cache["pos"]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = params["embed"][tokens].astype(cfg.jdtype)
    h = h + params["pos_embed"][pos].astype(cfg.jdtype)

    def body(carry, xs):
        p_i, ck, cv, xk, xv = xs
        a_in = layer_norm(carry, p_i["attn_norm"], p_i["attn_norm_b"])
        q = (a_in @ p_i["wq"]).reshape(B, H, hd)
        k = (a_in @ p_i["wk"]).reshape(B, KV, hd)
        v = (a_in @ p_i["wv"]).reshape(B, KV, hd)
        ck, cv = _write_cache(ck, cv, k.astype(ck.dtype),
                              v.astype(cv.dtype), pos % ck.shape[2])
        a = decode_attention(q, ck, cv,
                             kv_len=jnp.minimum(pos + 1, ck.shape[2]),
                             impl=impl)
        carry = carry + a.reshape(B, H * hd) @ p_i["wo"]
        x_in = layer_norm(carry, p_i["cross_norm"], p_i["cross_norm_b"])
        xq = (x_in @ p_i["xwq"]).reshape(B, H, hd)
        xa = decode_attention(xq, xk, xv, impl=impl)
        carry = carry + xa.reshape(B, H * hd) @ p_i["xwo"]
        m, _ = _mlp(layer_norm(carry, p_i["mlp_norm"],
                               p_i["mlp_norm_b"])[:, None], p_i, c)
        return carry + m[:, 0], (ck, cv)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    h = layer_norm(h, params["final_norm"], params["final_norm_b"])
    logits = h @ params["embed"].T
    new_cache = dict(cache)
    new_cache.update({"k": k_new, "v": v_new, "pos": pos + 1})
    return logits, new_cache


# --- Program lowering (generic named state) ---------------------------------------
def encode_memory(params, frames, cfg: ArchConfig, *,
                  impl: str = "auto") -> dict:
    """Run the encoder once and project the per-layer cross K/V — the
    admission-time write into the decoder Program's *read-only*
    persistent memory regions.  ``frames`` is one request's (T_enc, D)
    stub embedding (or (1, T_enc, D)); returns {region name: (T_enc,
    KV, hd) row} for the engine to place at the admitted slot."""
    if frames.ndim == 2:
        frames = frames[None]
    enc_out = encode(params, frames, cfg, impl=impl)
    xk, xv = _cross_kv(params, cfg, enc_out)        # (L, 1, KV, Te, hd)
    rows = {}
    for i in range(cfg.n_layers):
        rows[f"l{i}.cross_k"] = xk[i, 0].transpose(1, 0, 2)
        rows[f"l{i}.cross_v"] = xv[i, 0].transpose(1, 0, 2)
    return rows


def to_graph(cfg: ArchConfig, batch: int = 1, seq: int = 64,
             dtype_bytes: int | None = None,
             write_cache: bool = False) -> ModelGraph:
    """Lower the whisper *decoder* to the compiler IR: pre-LN layernorm
    blocks with a causal self-attention arm (standard dense KV plan)
    and a ``cross_attention`` arm per layer reading the persistent
    encoder memory (``encode_memory`` fills it at admission — the
    encoder itself runs once per request, outside the token loop, so it
    never appears in the per-token instruction stream).  The tied head
    reuses the embedding table transposed."""
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    D, H, KV, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                       cfg.d_ff)
    Te = cfg.encoder_seq
    M = batch * seq
    g = ModelGraph(cfg.name)
    g.add(embed_node("embed", M, cfg.vocab, D, dtype_bytes=by,
                     param="embed", param_b="pos_embed"))
    resid = "embed"
    for i in range(cfg.n_layers):
        def bp(k, i=i):
            return f"dec_blocks/{k}:{i}"
        an = f"l{i}.attn_norm"
        g.add(norm_node(an, M * D, dtype_bytes=by, inputs=[resid],
                        norm="layernorm", param=bp("attn_norm"),
                        param_b=bp("attn_norm_b")))
        g.add(matmul_node(f"l{i}.wq", M, D, H * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wq")))
        g.add(matmul_node(f"l{i}.wk", M, D, KV * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wk")))
        g.add(matmul_node(f"l{i}.wv", M, D, KV * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wv")))
        cache_meta = ({"k_cache": f"l{i}.k_cache",
                       "v_cache": f"l{i}.v_cache"} if write_cache else {})
        g.add(attention_node(
            f"l{i}.attn", seq_q=seq, seq_kv=seq, heads=H, kv_heads=KV,
            head_dim=hd, batch=batch, causal=True, dtype_bytes=by,
            inputs=[f"l{i}.wq", f"l{i}.wk", f"l{i}.wv"], **cache_meta))
        wo = f"l{i}.wo"
        g.add(matmul_node(wo, M, H * hd, D, dtype_bytes=by,
                          inputs=[f"l{i}.attn"], bypass_of=resid,
                          param=bp("wo")))
        cn = f"l{i}.cross_norm"
        g.add(norm_node(cn, M * D, dtype_bytes=by, inputs=[wo],
                        norm="layernorm", param=bp("cross_norm"),
                        param_b=bp("cross_norm_b")))
        g.add(matmul_node(f"l{i}.xwq", M, D, H * hd, dtype_bytes=by,
                          inputs=[cn], param=bp("xwq")))
        g.add(cross_attention_node(
            f"l{i}.cross", seq_q=seq, mem_len=Te, heads=H, kv_heads=KV,
            head_dim=hd, batch=batch, k_mem=f"l{i}.cross_k",
            v_mem=f"l{i}.cross_v", dtype_bytes=by,
            inputs=[f"l{i}.xwq"]))
        xwo = f"l{i}.xwo"
        g.add(matmul_node(xwo, M, H * hd, D, dtype_bytes=by,
                          inputs=[f"l{i}.cross"], bypass_of=wo,
                          param=bp("xwo")))
        mn = f"l{i}.mlp_norm"
        g.add(norm_node(mn, M * D, dtype_bytes=by, inputs=[xwo],
                        norm="layernorm", param=bp("mlp_norm"),
                        param_b=bp("mlp_norm_b")))
        g.add(matmul_node(f"l{i}.w_gate", M, D, F, dtype_bytes=by,
                          inputs=[mn], fused_activation="gelu",
                          param=bp("w_gate")))
        g.add(matmul_node(f"l{i}.w_down", M, F, D, dtype_bytes=by,
                          inputs=[f"l{i}.w_gate"], bypass_of=xwo,
                          param=bp("w_down")))
        resid = f"l{i}.w_down"
    g.add(norm_node("final_norm", M * D, dtype_bytes=by, inputs=[resid],
                    norm="layernorm", param="final_norm",
                    param_b="final_norm_b"))
    g.add(matmul_node("lm_head", M, D, cfg.vocab, dtype_bytes=by,
                      inputs=["final_norm"], param="embed",
                      transpose_w=True))
    return g


def to_decode_graph(cfg: ArchConfig, slots: int = 8, max_len: int = 256,
                    dtype_bytes: int | None = None) -> ModelGraph:
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    D, H, KV, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                       cfg.d_ff)
    Te = cfg.encoder_seq
    g = ModelGraph(cfg.name + ".decode")
    g.add(embed_node("embed", slots, cfg.vocab, D, dtype_bytes=by,
                     param="embed", param_b="pos_embed"))
    resid = "embed"
    for i in range(cfg.n_layers):
        def bp(k, i=i):
            return f"dec_blocks/{k}:{i}"
        an = f"l{i}.attn_norm"
        g.add(norm_node(an, slots * D, dtype_bytes=by, inputs=[resid],
                        norm="layernorm", param=bp("attn_norm"),
                        param_b=bp("attn_norm_b")))
        g.add(matmul_node(f"l{i}.wq", slots, D, H * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wq")))
        g.add(matmul_node(f"l{i}.wk", slots, D, KV * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wk")))
        g.add(matmul_node(f"l{i}.wv", slots, D, KV * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wv")))
        g.add(decode_attention_node(
            f"l{i}.attn", cache_len=max_len, heads=H, kv_heads=KV,
            head_dim=hd, slots=slots, dtype_bytes=by,
            inputs=[f"l{i}.wq", f"l{i}.wk", f"l{i}.wv"],
            k_cache=f"l{i}.k_cache", v_cache=f"l{i}.v_cache"))
        wo = f"l{i}.wo"
        g.add(matmul_node(wo, slots, H * hd, D, dtype_bytes=by,
                          inputs=[f"l{i}.attn"], bypass_of=resid,
                          param=bp("wo")))
        cn = f"l{i}.cross_norm"
        g.add(norm_node(cn, slots * D, dtype_bytes=by, inputs=[wo],
                        norm="layernorm", param=bp("cross_norm"),
                        param_b=bp("cross_norm_b")))
        g.add(matmul_node(f"l{i}.xwq", slots, D, H * hd, dtype_bytes=by,
                          inputs=[cn], param=bp("xwq")))
        g.add(cross_attention_node(
            f"l{i}.cross", seq_q=1, mem_len=Te, heads=H, kv_heads=KV,
            head_dim=hd, batch=slots, k_mem=f"l{i}.cross_k",
            v_mem=f"l{i}.cross_v", dtype_bytes=by, decode=True,
            inputs=[f"l{i}.xwq"]))
        xwo = f"l{i}.xwo"
        g.add(matmul_node(xwo, slots, H * hd, D, dtype_bytes=by,
                          inputs=[f"l{i}.cross"], bypass_of=wo,
                          param=bp("xwo")))
        mn = f"l{i}.mlp_norm"
        g.add(norm_node(mn, slots * D, dtype_bytes=by, inputs=[xwo],
                        norm="layernorm", param=bp("mlp_norm"),
                        param_b=bp("mlp_norm_b")))
        g.add(matmul_node(f"l{i}.w_gate", slots, D, F, dtype_bytes=by,
                          inputs=[mn], fused_activation="gelu",
                          param=bp("w_gate")))
        g.add(matmul_node(f"l{i}.w_down", slots, F, D, dtype_bytes=by,
                          inputs=[f"l{i}.w_gate"], bypass_of=xwo,
                          param=bp("w_down")))
        resid = f"l{i}.w_down"
    g.add(norm_node("final_norm", slots * D, dtype_bytes=by,
                    inputs=[resid], norm="layernorm", param="final_norm",
                    param_b="final_norm_b"))
    g.add(matmul_node("lm_head", slots, D, cfg.vocab, dtype_bytes=by,
                      inputs=["final_norm"], param="embed",
                      transpose_w=True))
    return g


def _audio_state_specs(cfg: ArchConfig, slots: int, max_len: int):
    """Per-layer self-attention KV (standard dense ring) plus the
    *read-only* encoder memory pair written once at admission.  No
    serving capability survives the encoder coupling: memory rows are
    admission-bound (not pageable/speculatable) and the cross arm needs
    them before the first decoder row computes (not chunkable)."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    kdt = jnp.dtype(cfg.kv_jdtype)
    Te = cfg.encoder_seq
    specs = []
    for i in range(cfg.n_layers):
        for side, rows, ro in (("k_cache", max_len, False),
                               ("v_cache", max_len, False),
                               ("cross_k", Te, True),
                               ("cross_v", Te, True)):
            shape = (slots, rows, KV, hd)
            specs.append(PersistentSpec(
                f"l{i}.{side}", shape, kdt.name,
                int(np.prod(shape)) * kdt.itemsize, read_only=ro))
    return tuple(specs), StateCaps()


register_state_family("audio", _audio_state_specs)

"""Uniform model API: family -> (param_defs, forward, init_cache,
decode_step).  Launchers, tests and the dry-run all go through this."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from ..configs.base import ArchConfig
from . import rwkv, transformer, whisper, zamba2

__all__ = ["ModelApi", "get_model", "FAMILIES"]


@dataclass(frozen=True)
class ModelApi:
    param_defs: Callable[[ArchConfig], dict]
    forward: Callable[..., dict]
    init_cache: Callable[..., dict]
    decode_step: Callable[..., tuple]
    extra_input: str | None = None   # "vision_embeds" | "encoder_frames"
    # Admission-time writer for families whose decode Program reads
    # *read-only* persistent memory (whisper: encoder cross K/V).
    # Called once per admitted request with the request's extra input;
    # returns {persistent region name: per-slot row} for the serving
    # engine to scatter at the admitted slot.
    encode_memory: Callable[..., dict] | None = None


FAMILIES: dict[str, ModelApi] = {
    "dense": ModelApi(transformer.param_defs, transformer.forward,
                      transformer.init_cache, transformer.decode_step),
    "moe": ModelApi(transformer.param_defs, transformer.forward,
                    transformer.init_cache, transformer.decode_step),
    "vlm": ModelApi(transformer.param_defs, transformer.forward,
                    transformer.init_cache, transformer.decode_step,
                    extra_input="vision_embeds"),
    "audio": ModelApi(whisper.param_defs, whisper.forward,
                      whisper.init_cache, whisper.decode_step,
                      extra_input="encoder_frames",
                      encode_memory=whisper.encode_memory),
    "hybrid": ModelApi(zamba2.param_defs, zamba2.forward,
                       zamba2.init_cache, zamba2.decode_step),
    "ssm": ModelApi(rwkv.param_defs, rwkv.forward, rwkv.init_cache,
                    rwkv.decode_step),
}


def get_model(cfg: ArchConfig) -> ModelApi:
    return FAMILIES[cfg.family]

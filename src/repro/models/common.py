"""Model substrate: parameter definition trees, init, abstract params,
logical-axis sharding specs, norms, rotary embeddings.

Parameters are declared as ``ParamDef`` trees (shape + dtype + logical
axes + init kind).  From one declaration we derive:
  * concrete initialization (``init_params``),
  * allocation-free abstract params for the dry-run (``abstract_params``),
  * ``PartitionSpec`` trees from logical->mesh axis rules
    (``param_pspecs``) — the distributed half of the schedule compiler
    plugs in here (parallel/rules.py chooses the rules per layer class).

Repeated transformer blocks are *stacked* on a leading "layers" axis and
executed with ``jax.lax.scan`` so the HLO stays one-block-sized — which
keeps the 512-device dry-run compile tractable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamDef", "init_params", "abstract_params", "param_pspecs",
    "tree_paths", "rms_norm", "layer_norm", "Rotary", "apply_rope",
    "cross_entropy_loss", "count_params",
]


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]                 # logical axis names (or None)
    dtype: Any = jnp.bfloat16
    init: str = "normal"                  # normal | zeros | ones | embed
    init_scale: float | None = None       # overrides fan-in scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    if len(shape) == 1:
        return shape[0]
    return int(jnp.prod(jnp.array(shape[:-1])).item()) if False else \
        math.prod(shape[:-1])


def _init_leaf(rng, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        scale = d.init_scale if d.init_scale is not None else 0.02
        return (jax.random.normal(rng, d.shape, jnp.float32)
                * scale).astype(d.dtype)
    # fan-in scaled normal; stacked layer axes excluded from fan-in.
    shape = d.shape
    fan_shape = shape[1:] if (d.axes and d.axes[0] == "layers") else shape
    fan = _fan_in(fan_shape) if len(fan_shape) > 1 else fan_shape[0]
    scale = d.init_scale if d.init_scale is not None else fan ** -0.5
    return (jax.random.normal(rng, d.shape, jnp.float32)
            * scale).astype(d.dtype)


def tree_paths(defs: dict, prefix: str = "") -> list[str]:
    out = []
    for k, v in defs.items():
        p = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.extend(tree_paths(v, p))
        else:
            out.append(p)
    return out


def init_params(defs: dict, rng: jax.Array) -> dict:
    """Initialize a ParamDef tree to concrete arrays (deterministic per
    path, so restores and re-inits agree regardless of traversal order)."""
    paths = tree_paths(defs)
    keys = {p: jax.random.fold_in(rng, hash(p) % (2 ** 31)) for p in paths}

    def go(sub: dict, prefix: str) -> dict:
        out = {}
        for k, v in sub.items():
            p = f"{prefix}/{k}" if prefix else k
            out[k] = go(v, p) if isinstance(v, dict) else _init_leaf(keys[p], v)
        return out

    return go(defs, "")


def abstract_params(defs: dict) -> dict:
    """ShapeDtypeStruct tree — the dry-run's allocation-free params."""
    def go(sub):
        return {k: go(v) if isinstance(v, dict)
                else jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in sub.items()}
    return go(defs)


def param_pspecs(defs: dict, rules: dict,
                 overrides: dict | None = None,
                 axis_sizes: dict | None = None) -> dict:
    """Map logical axes -> mesh axes (rules values: None, str, or tuple).

    A mesh axis may appear only once per tensor; when two logical axes
    map to the same mesh axis, the earlier tensor axis wins (e.g. MoE
    weights (experts, embed, ff) with experts->model keep ff unsharded).
    Entries whose dimension is not divisible by the mesh-axis size are
    dropped (jit in/out shardings require even sharding).
    ``overrides``: path-suffix -> rules dict, for per-layer-class
    strategies chosen by the distributed Mloop/Kloop cost model.
    """
    def spec(d: ParamDef, ruleset: dict) -> P:
        entries = []
        used: set[str] = set()
        for ax, dim in zip(d.axes, d.shape):
            r = ruleset.get(ax) if ax is not None else None
            names = (r,) if isinstance(r, str) else tuple(r or ())
            if axis_sizes is not None and names:
                total = 1
                for n in names:
                    total *= axis_sizes.get(n, 1)
                if total and dim % total != 0:
                    r, names = None, ()
            if any(n in used for n in names):
                r = None
            else:
                used.update(names)
            entries.append(r)
        return P(*entries)

    def pick_rules(path: str) -> dict:
        if overrides:
            best = None
            for suffix, rs in overrides.items():
                if path.endswith(suffix):
                    if best is None or len(suffix) > len(best[0]):
                        best = (suffix, rs)
            if best is not None:
                return best[1]
        return rules

    def go(sub, prefix=""):
        out = {}
        for k, v in sub.items():
            p = f"{prefix}/{k}" if prefix else k
            out[k] = (go(v, p) if isinstance(v, dict)
                      else spec(v, pick_rules(p)))
        return out
    return go(defs)


def count_params(defs: dict) -> int:
    def go(sub):
        t = 0
        for v in sub.values():
            t += go(v) if isinstance(v, dict) else math.prod(v.shape)
        return t
    return go(defs)


# --- norms --------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array | None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(x: jax.Array, weight=None, bias=None, eps: float = 1e-5):
    """LayerNorm; with weight=bias=None this is OLMo's non-parametric LN."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# --- rotary -------------------------------------------------------------------
@dataclass(frozen=True)
class Rotary:
    head_dim: int
    theta: float = 10000.0

    def freqs(self, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
        """positions: (...,) int -> (cos, sin) of shape (..., head_dim/2)."""
        half = self.head_dim // 2
        inv = self.theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = positions.astype(jnp.float32)[..., None] * inv
        return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, D); cos/sin: (S, D/2) or broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    while cos.ndim < x1.ndim:
        cos, sin = cos[None], sin[None]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# --- loss ---------------------------------------------------------------------
def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE.  logits (B, L, V) f32-upcast; labels (B, L)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()

"""Model zoo: pure-pytree params + functional apply, schedule-driven kernels."""
from .common import (ParamDef, abstract_params, count_params,
                     cross_entropy_loss, init_params, param_pspecs)
from .registry import FAMILIES, ModelApi, get_model

__all__ = ["ParamDef", "abstract_params", "count_params",
           "cross_entropy_loss", "init_params", "param_pspecs",
           "FAMILIES", "ModelApi", "get_model"]

"""Zamba2-style hybrid: a Mamba2 backbone with one *shared* attention
block applied every ``shared_attn_every`` layers (weights reused at each
application — the arch's signature trick).

Mamba2 mixer per layer: in_proj -> [z | x | B | C | dt], short causal
depthwise conv over (x|B|C), selective scan (kernels/mamba2), gated
RMSNorm, out_proj.  The shared attention block is a full transformer
block (attn + MLP) with a sliding window (``attn_window``), which is
what makes the long_500k decode cell sub-quadratic (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.mamba2 import mamba2_decode_step, mamba2_scan
from ..parallel.act_sharding import shard_act
from .common import ParamDef, Rotary, rms_norm
from .transformer import (_attention, _attention_decode, _attn_defs, _mlp,
                          _norm)

__all__ = ["param_defs", "forward", "init_cache", "decode_step"]

_CONV_K = 4


def _n_apps(cfg: ArchConfig) -> int:
    e = cfg.shared_attn_every
    return (cfg.n_layers + e - 1) // e


def param_defs(cfg: ArchConfig) -> dict:
    dt = cfg.jdtype
    L, D = cfg.n_layers, cfg.d_model
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    blocks = {
        "norm": ParamDef((L, D), ("layers", "embed"), dt, "ones"),
        "in_proj": ParamDef((L, D, 2 * di + 2 * N + H),
                            ("layers", "embed", "ff"), dt),
        "conv_w": ParamDef((L, _CONV_K, conv_ch), ("layers", None, "ff"),
                           dt, init_scale=0.5),
        "A_log": ParamDef((L, H), ("layers", None), jnp.float32, "zeros"),
        "dt_bias": ParamDef((L, H), ("layers", None), jnp.float32, "zeros"),
        "D_skip": ParamDef((L, H), ("layers", None), jnp.float32, "ones"),
        "gate_norm": ParamDef((L, di), ("layers", "ff"), dt, "ones"),
        "out_proj": ParamDef((L, di, D), ("layers", "ff", "embed"), dt),
    }
    shared = {}
    shared["attn_norm"] = ParamDef((D,), ("embed",), dt, "ones")
    shared.update({k: ParamDef(v.shape[1:], v.axes[1:], v.dtype)
                   for k, v in _attn_defs(cfg, L).items()})
    shared["mlp_norm"] = ParamDef((D,), ("embed",), dt, "ones")
    shared["w_gate"] = ParamDef((D, cfg.d_ff), ("embed", "ff"), dt)
    shared["w_up"] = ParamDef((D, cfg.d_ff), ("embed", "ff"), dt)
    shared["w_down"] = ParamDef((cfg.d_ff, D), ("ff", "embed"), dt)
    return {
        "embed": ParamDef((cfg.vocab, D), ("vocab", "embed"), dt, "embed"),
        "blocks": blocks,
        "shared": shared,
        "final_norm": ParamDef((D,), ("embed",), dt, "ones"),
        "lm_head": ParamDef((D, cfg.vocab), ("embed", "vocab"), dt),
    }


def _split_proj(zxbcdt, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w):
    """Depthwise causal conv, kernel _CONV_K.  xBC (B, S, C); conv_w (K, C)."""
    K = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * conv_w[i][None, None]
              for i in range(K))
    return jax.nn.silu(out.astype(jnp.float32)).astype(xBC.dtype)


def _mamba_mixer(h, p, cfg, *, impl, state=None, conv_state=None):
    """h (B, S, D) -> (out, new_ssm_state, new_conv_state)."""
    B, S, D = h.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _split_proj(h @ p["in_proj"], cfg)
    if conv_state is not None:      # decode: roll the conv window
        window = jnp.concatenate([conv_state, xBC], axis=1)   # (B, K-1+S, C)
        new_conv_state = window[:, -(_CONV_K - 1):]
        xBC = _causal_conv(window, p["conv_w"])[:, -S:]
    else:
        zeros = jnp.zeros((B, _CONV_K - 1, xBC.shape[-1]), xBC.dtype)
        new_conv_state = jnp.concatenate([zeros, xBC],
                                         axis=1)[:, -(_CONV_K - 1):]
        xBC = _causal_conv(xBC, p["conv_w"])
    x, Bm, Cm = xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]
    xh = x.reshape(B, S, H, P)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"][None, None])          # (B,S,H)
    A = -jnp.exp(p["A_log"])
    y, h_fin = mamba2_scan(xh, dtv, A, Bm, Cm, D_skip=p["D_skip"],
                           h0=state, return_state=True, impl=impl)
    y = y.reshape(B, S, di)
    y = rms_norm(y, p["gate_norm"]) * jax.nn.silu(z.astype(jnp.float32)
                                                  ).astype(y.dtype)
    return y @ p["out_proj"], h_fin, new_conv_state


def forward(params, tokens, cfg: ArchConfig, *, impl: str = "auto",
            return_cache: bool = False, cache_len: int | None = None,
            remat: bool = False, return_hidden: bool = False):
    B, S = tokens.shape
    e = cfg.shared_attn_every
    h = params["embed"][tokens].astype(cfg.jdtype)
    h = shard_act(h, "hidden")
    rot = Rotary(cfg.hd, cfg.rope_theta)
    cos, sin = rot.freqs(jnp.arange(S))
    shared = params["shared"]

    def shared_block(x):
        if return_cache:
            a, kv = _attention(rms_norm(x, shared["attn_norm"]), shared,
                               cfg, cos, sin, impl=impl,
                               window=cfg.attn_window, return_kv=True)
        else:
            a = _attention(rms_norm(x, shared["attn_norm"]), shared, cfg,
                           cos, sin, impl=impl, window=cfg.attn_window)
            kv = None
        x = x + a
        m, _ = _mlp(rms_norm(x, shared["mlp_norm"]), shared,
                    _DenseCfg(cfg))
        return shard_act(x + m, "hidden"), kv

    def body(carry, xs):
        p_i, idx = xs
        is_attn = idx % e == 0
        if return_cache:
            def yes(x):
                return shared_block(x)
            def no(x):
                KV, hd = cfg.n_kv_heads, cfg.hd
                zero = (jnp.zeros((B, KV, S, hd), cfg.jdtype),) * 2
                return x, zero
            carry, kv = jax.lax.cond(is_attn, yes, no, carry)
        else:
            carry = jax.lax.cond(is_attn,
                                 lambda x: shared_block(x)[0],
                                 lambda x: x, carry)
            kv = None
        mixed, s_fin, c_fin = _mamba_mixer(rms_norm(carry, p_i["norm"]),
                                           p_i, cfg, impl=impl)
        carry = shard_act(carry + mixed, "hidden")
        ys = (kv, s_fin, c_fin) if return_cache else kv
        return carry, ys

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    idxs = jnp.arange(cfg.n_layers)
    h, ys = jax.lax.scan(body, h, (params["blocks"], idxs))
    h = rms_norm(h, params["final_norm"])
    logits = (None if return_hidden
              else shard_act(h @ params["lm_head"], "logits"))
    out = {"logits": logits, "aux": {}}
    if return_hidden:
        out["hidden"] = h
    if return_cache:
        kvs, ssm_stack, conv_stack = ys
        # keep only the layers where the shared block actually ran
        app_layers = jnp.arange(0, cfg.n_layers, e)
        k_stack = kvs[0][app_layers]
        v_stack = kvs[1][app_layers]
        cache = _prefill_cache(cfg, k_stack, v_stack, B, S)
        cache["ssm"] = ssm_stack
        cache["conv"] = conv_stack
        out["cache"] = cache
    return out


class _DenseCfg:
    """Proxy hiding MoE fields so _mlp runs the dense path."""

    def __init__(self, cfg):
        object.__setattr__(self, "_c", cfg)

    def __getattr__(self, k):
        if k == "n_experts":
            return 0
        return getattr(self._c, k)


def _prefill_cache(cfg, k_stack, v_stack, B, S):
    """Convert prefill KV (full S) into the rolling window cache."""
    W = cfg.attn_window or S
    if S >= W:
        # last W positions, laid out so slot = pos % W matches.
        idx = (jnp.arange(S - W, S)) % W
        kw = jnp.zeros(k_stack.shape[:3] + (W,) + k_stack.shape[4:],
                       k_stack.dtype)
        kw = kw.at[:, :, :, idx].set(k_stack[:, :, :, S - W:])
        vw = jnp.zeros_like(kw).at[:, :, :, idx].set(
            v_stack[:, :, :, S - W:])
    else:
        pad = W - S
        kw = jnp.pad(k_stack, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        vw = jnp.pad(v_stack, ((0, 0),) * 3 + ((0, pad), (0, 0)))
    cache = init_cache(cfg, B, W)
    kw = kw.astype(cfg.kv_jdtype)
    vw = vw.astype(cfg.kv_jdtype)
    cache.update({"attn_k": kw, "attn_v": vw,
                  "pos": jnp.full((B,), S, jnp.int32)})
    return cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    dt = cfg.jdtype
    L, di, N = cfg.n_layers, cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    KV, hd = cfg.n_kv_heads, cfg.hd
    W = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    napp = _n_apps(cfg)
    kdt = cfg.kv_jdtype
    return {
        "ssm": jnp.zeros((L, batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((L, batch, _CONV_K - 1, di + 2 * N), dt),
        "attn_k": jnp.zeros((napp, batch, KV, W, hd), kdt),
        "attn_v": jnp.zeros((napp, batch, KV, W, hd), kdt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg: ArchConfig, *,
                impl: str = "auto"):
    B = tokens.shape[0]
    e = cfg.shared_attn_every
    pos = cache["pos"]
    h = params["embed"][tokens].astype(cfg.jdtype)
    rot = Rotary(cfg.hd, cfg.rope_theta)
    cos, sin = rot.freqs(pos)
    shared = params["shared"]
    napp = _n_apps(cfg)

    # Shared attention applications, gathered outside the mamba scan so
    # each application indexes its own rolling KV slot.
    kc, vc = cache["attn_k"], cache["attn_v"]

    def shared_apply(x, app_idx, kc, vc):
        a_in = rms_norm(x, shared["attn_norm"])
        a, ck, cv = _attention_decode(a_in, shared, cfg, kc[app_idx],
                                      vc[app_idx], pos, cos, sin, impl=impl)
        x = x + a
        m, _ = _mlp(rms_norm(x, shared["mlp_norm"])[:, None], shared,
                    _DenseCfg(cfg))
        x = x + m[:, 0]
        return x, kc.at[app_idx].set(ck), vc.at[app_idx].set(cv)

    def body(carry, xs):
        p_i, s_i, c_i, idx = xs
        h_c, kc, vc = carry
        def yes(args):
            h_c, kc, vc = args
            return shared_apply(h_c, idx // e, kc, vc)
        h_c, kc, vc = jax.lax.cond(idx % e == 0, yes,
                                   lambda a: a, (h_c, kc, vc))
        mixed, s_new, c_new = _mamba_mixer(
            rms_norm(h_c, p_i["norm"])[:, None], p_i, cfg, impl=impl,
            state=s_i, conv_state=c_i)
        h_c = h_c + mixed[:, 0]
        return (h_c, kc, vc), (s_new, c_new)

    idxs = jnp.arange(cfg.n_layers)
    (h, kc, vc), (ssm_new, conv_new) = jax.lax.scan(
        body, (h, kc, vc),
        (params["blocks"], cache["ssm"], cache["conv"], idxs))
    h = rms_norm(h, params["final_norm"])
    logits = h @ params["lm_head"]
    new_cache = {"ssm": ssm_new, "conv": conv_new, "attn_k": kc,
                 "attn_v": vc, "pos": pos + 1}
    return logits, new_cache

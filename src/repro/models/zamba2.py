"""Zamba2-style hybrid: a Mamba2 backbone with one *shared* attention
block applied every ``shared_attn_every`` layers (weights reused at each
application — the arch's signature trick).

Mamba2 mixer per layer: in_proj -> [z | x | B | C | dt], short causal
depthwise conv over (x|B|C), selective scan (kernels/mamba2), gated
RMSNorm, out_proj.  The shared attention block is a full transformer
block (attn + MLP) with a sliding window (``attn_window``), which is
what makes the long_500k decode cell sub-quadratic (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from ..configs.base import ArchConfig
from ..core.ir import (ModelGraph, attention_node, decode_attention_node,
                       elementwise_node, embed_node, matmul_node, norm_node,
                       ssm_scan_node)
from ..core.regions import PersistentSpec, StateCaps, register_state_family
from ..kernels.mamba2 import mamba2_decode_step, mamba2_scan
from ..parallel.act_sharding import shard_act
from .common import ParamDef, Rotary, rms_norm
from .transformer import (_attention, _attention_decode, _attn_defs, _mlp,
                          _norm)

__all__ = ["param_defs", "forward", "init_cache", "decode_step",
           "to_graph", "to_decode_graph", "block_prefill", "block_decode"]

_CONV_K = 4


def _n_apps(cfg: ArchConfig) -> int:
    e = cfg.shared_attn_every
    if not e:          # pure-mamba2 config: no shared attention at all
        return 0
    return (cfg.n_layers + e - 1) // e


def param_defs(cfg: ArchConfig) -> dict:
    dt = cfg.jdtype
    L, D = cfg.n_layers, cfg.d_model
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    blocks = {
        "norm": ParamDef((L, D), ("layers", "embed"), dt, "ones"),
        "in_proj": ParamDef((L, D, 2 * di + 2 * N + H),
                            ("layers", "embed", "ff"), dt),
        "conv_w": ParamDef((L, _CONV_K, conv_ch), ("layers", None, "ff"),
                           dt, init_scale=0.5),
        "A_log": ParamDef((L, H), ("layers", None), jnp.float32, "zeros"),
        "dt_bias": ParamDef((L, H), ("layers", None), jnp.float32, "zeros"),
        "D_skip": ParamDef((L, H), ("layers", None), jnp.float32, "ones"),
        "gate_norm": ParamDef((L, di), ("layers", "ff"), dt, "ones"),
        "out_proj": ParamDef((L, di, D), ("layers", "ff", "embed"), dt),
    }
    defs = {
        "embed": ParamDef((cfg.vocab, D), ("vocab", "embed"), dt, "embed"),
        "blocks": blocks,
        "final_norm": ParamDef((D,), ("embed",), dt, "ones"),
        "lm_head": ParamDef((D, cfg.vocab), ("embed", "vocab"), dt),
    }
    if cfg.shared_attn_every:
        shared = {}
        shared["attn_norm"] = ParamDef((D,), ("embed",), dt, "ones")
        shared.update({k: ParamDef(v.shape[1:], v.axes[1:], v.dtype)
                       for k, v in _attn_defs(cfg, L).items()})
        shared["mlp_norm"] = ParamDef((D,), ("embed",), dt, "ones")
        shared["w_gate"] = ParamDef((D, cfg.d_ff), ("embed", "ff"), dt)
        shared["w_up"] = ParamDef((D, cfg.d_ff), ("embed", "ff"), dt)
        shared["w_down"] = ParamDef((cfg.d_ff, D), ("ff", "embed"), dt)
        defs["shared"] = shared
    return defs


def _mixer_dims(p) -> tuple[int, int, int, int]:
    """(d_inner, ssm_state, ssm_heads, ssm_head_dim) from the param
    shapes alone, so the executor's block entry points never consult
    the model config: A_log is (H,), gate_norm is (di,), and in_proj's
    output splits as [z(di) | x(di) | B(N) | C(N) | dt(H)]."""
    H = p["A_log"].shape[-1]
    di = p["gate_norm"].shape[-1]
    N = (p["in_proj"].shape[-1] - 2 * di - H) // 2
    return di, N, H, di // H


def _split_proj(zxbcdt, di, N):
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w):
    """Depthwise causal conv, kernel _CONV_K.  xBC (B, S, C); conv_w (K, C)."""
    K = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * conv_w[i][None, None]
              for i in range(K))
    return jax.nn.silu(out.astype(jnp.float32)).astype(xBC.dtype)


def _mamba_mixer(h, p, *, impl, state=None, conv_state=None, length=None):
    """h (B, S, D) -> (out, new_ssm_state, new_conv_state).

    ``length`` marks h as right-padded (Program prefill pins
    (1, max_len)): pad rows become scan identities — dt=0 after the
    softplus makes the decay exp(A*0)=1 and the dB*x contribution 0 —
    so the returned recurrent state is exactly the state at the true
    length, and the conv taps are gathered at rows
    [length-K+1, length) instead of the block tail."""
    B, S, D = h.shape
    di, N, H, P = _mixer_dims(p)
    z, xBC, dt = _split_proj(h @ p["in_proj"], di, N)
    if conv_state is not None:      # decode: roll the conv window
        window = jnp.concatenate([conv_state, xBC], axis=1)   # (B, K-1+S, C)
        new_conv_state = window[:, -(_CONV_K - 1):]
        xBC = _causal_conv(window, p["conv_w"])[:, -S:]
    elif length is not None:
        idx = length - (_CONV_K - 1) + jnp.arange(_CONV_K - 1)
        rows = xBC[:, jnp.clip(idx, 0, S - 1)]
        new_conv_state = jnp.where((idx >= 0)[None, :, None], rows,
                                   jnp.zeros((), xBC.dtype))
        xBC = _causal_conv(xBC, p["conv_w"])
    else:
        zeros = jnp.zeros((B, _CONV_K - 1, xBC.shape[-1]), xBC.dtype)
        new_conv_state = jnp.concatenate([zeros, xBC],
                                         axis=1)[:, -(_CONV_K - 1):]
        xBC = _causal_conv(xBC, p["conv_w"])
    x, Bm, Cm = xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]
    xh = x.reshape(B, S, H, P)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"][None, None])          # (B,S,H)
    if length is not None:
        dtv = jnp.where((jnp.arange(S) < length)[None, :, None], dtv, 0.0)
    A = -jnp.exp(p["A_log"])
    y, h_fin = mamba2_scan(xh, dtv, A, Bm, Cm, D_skip=p["D_skip"],
                           h0=state, return_state=True, impl=impl)
    y = y.reshape(B, S, di)
    y = rms_norm(y, p["gate_norm"]) * jax.nn.silu(z.astype(jnp.float32)
                                                  ).astype(y.dtype)
    return y @ p["out_proj"], h_fin, new_conv_state


def block_prefill(h, p_i, *, impl="auto", length=None):
    """Executor entry for one ``ssm_scan`` prefill op — the whole
    mamba block (pre-norm + mixer + residual) on (B, S, D), recurrent
    state zero-initialised (prefill always restarts a slot).  Returns
    (out, (ssm (B, H, N, P) f32, conv (B, K-1, di+2N)))."""
    mixed, s_fin, c_fin = _mamba_mixer(rms_norm(h, p_i["norm"]), p_i,
                                       impl=impl, length=length)
    return shard_act(h + mixed, "hidden"), (s_fin, c_fin)


def block_decode(h, p_i, ssm_state, conv_state, *, impl="auto"):
    """Executor entry for one ``ssm_scan`` decode op: h (slots, D),
    one token per slot against the per-slot recurrent states."""
    mixed, s_new, c_new = _mamba_mixer(
        rms_norm(h, p_i["norm"])[:, None], p_i, impl=impl,
        state=ssm_state, conv_state=conv_state)
    return h + mixed[:, 0], (s_new, c_new)


def forward(params, tokens, cfg: ArchConfig, *, impl: str = "auto",
            return_cache: bool = False, cache_len: int | None = None,
            remat: bool = False, return_hidden: bool = False):
    B, S = tokens.shape
    e = cfg.shared_attn_every
    h = params["embed"][tokens].astype(cfg.jdtype)
    h = shard_act(h, "hidden")
    rot = Rotary(cfg.hd, cfg.rope_theta)
    cos, sin = rot.freqs(jnp.arange(S))
    shared = params.get("shared")

    def shared_block(x):
        if return_cache:
            a, kv = _attention(rms_norm(x, shared["attn_norm"]), shared,
                               cfg, cos, sin, impl=impl,
                               window=cfg.attn_window, return_kv=True)
        else:
            a = _attention(rms_norm(x, shared["attn_norm"]), shared, cfg,
                           cos, sin, impl=impl, window=cfg.attn_window)
            kv = None
        x = x + a
        m, _ = _mlp(rms_norm(x, shared["mlp_norm"]), shared,
                    _DenseCfg(cfg))
        return shard_act(x + m, "hidden"), kv

    def body(carry, xs):
        p_i, idx = xs
        if e:
            is_attn = idx % e == 0
            if return_cache:
                def yes(x):
                    return shared_block(x)
                def no(x):
                    KV, hd = cfg.n_kv_heads, cfg.hd
                    zero = (jnp.zeros((B, KV, S, hd), cfg.jdtype),) * 2
                    return x, zero
                carry, kv = jax.lax.cond(is_attn, yes, no, carry)
            else:
                carry = jax.lax.cond(is_attn,
                                     lambda x: shared_block(x)[0],
                                     lambda x: x, carry)
                kv = None
        else:
            kv = None
        carry, (s_fin, c_fin) = block_prefill(carry, p_i, impl=impl)
        ys = (kv, s_fin, c_fin) if return_cache else kv
        return carry, ys

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    idxs = jnp.arange(cfg.n_layers)
    h, ys = jax.lax.scan(body, h, (params["blocks"], idxs))
    h = rms_norm(h, params["final_norm"])
    logits = (None if return_hidden
              else shard_act(h @ params["lm_head"], "logits"))
    out = {"logits": logits, "aux": {}}
    if return_hidden:
        out["hidden"] = h
    if return_cache:
        kvs, ssm_stack, conv_stack = ys
        if e:
            # keep only the layers where the shared block actually ran
            app_layers = jnp.arange(0, cfg.n_layers, e)
            k_stack = kvs[0][app_layers]
            v_stack = kvs[1][app_layers]
        else:
            KV, hd = cfg.n_kv_heads, cfg.hd
            k_stack = jnp.zeros((0, B, KV, S, hd), cfg.jdtype)
            v_stack = k_stack
        cache = _prefill_cache(cfg, k_stack, v_stack, B, S)
        cache["ssm"] = ssm_stack
        cache["conv"] = conv_stack
        out["cache"] = cache
    return out


class _DenseCfg:
    """Proxy hiding MoE fields so _mlp runs the dense path."""

    def __init__(self, cfg):
        object.__setattr__(self, "_c", cfg)

    def __getattr__(self, k):
        if k == "n_experts":
            return 0
        return getattr(self._c, k)


def _prefill_cache(cfg, k_stack, v_stack, B, S):
    """Convert prefill KV (full S) into the rolling window cache."""
    W = cfg.attn_window or S
    if S >= W:
        # last W positions, laid out so slot = pos % W matches.
        idx = (jnp.arange(S - W, S)) % W
        kw = jnp.zeros(k_stack.shape[:3] + (W,) + k_stack.shape[4:],
                       k_stack.dtype)
        kw = kw.at[:, :, :, idx].set(k_stack[:, :, :, S - W:])
        vw = jnp.zeros_like(kw).at[:, :, :, idx].set(
            v_stack[:, :, :, S - W:])
    else:
        pad = W - S
        kw = jnp.pad(k_stack, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        vw = jnp.pad(v_stack, ((0, 0),) * 3 + ((0, pad), (0, 0)))
    cache = init_cache(cfg, B, W)
    kw = kw.astype(cfg.kv_jdtype)
    vw = vw.astype(cfg.kv_jdtype)
    cache.update({"attn_k": kw, "attn_v": vw,
                  "pos": jnp.full((B,), S, jnp.int32)})
    return cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    dt = cfg.jdtype
    L, di, N = cfg.n_layers, cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    KV, hd = cfg.n_kv_heads, cfg.hd
    W = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    napp = _n_apps(cfg)
    kdt = cfg.kv_jdtype
    return {
        "ssm": jnp.zeros((L, batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((L, batch, _CONV_K - 1, di + 2 * N), dt),
        "attn_k": jnp.zeros((napp, batch, KV, W, hd), kdt),
        "attn_v": jnp.zeros((napp, batch, KV, W, hd), kdt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg: ArchConfig, *,
                impl: str = "auto"):
    B = tokens.shape[0]
    e = cfg.shared_attn_every
    pos = cache["pos"]
    h = params["embed"][tokens].astype(cfg.jdtype)
    rot = Rotary(cfg.hd, cfg.rope_theta)
    cos, sin = rot.freqs(pos)
    shared = params.get("shared")

    # Shared attention applications, gathered outside the mamba scan so
    # each application indexes its own rolling KV slot.
    kc, vc = cache["attn_k"], cache["attn_v"]

    def shared_apply(x, app_idx, kc, vc):
        a_in = rms_norm(x, shared["attn_norm"])
        a, ck, cv = _attention_decode(a_in, shared, cfg, kc[app_idx],
                                      vc[app_idx], pos, cos, sin, impl=impl)
        x = x + a
        m, _ = _mlp(rms_norm(x, shared["mlp_norm"])[:, None], shared,
                    _DenseCfg(cfg))
        x = x + m[:, 0]
        return x, kc.at[app_idx].set(ck), vc.at[app_idx].set(cv)

    def body(carry, xs):
        p_i, s_i, c_i, idx = xs
        h_c, kc, vc = carry
        if e:
            def yes(args):
                h_c, kc, vc = args
                return shared_apply(h_c, idx // e, kc, vc)
            h_c, kc, vc = jax.lax.cond(idx % e == 0, yes,
                                       lambda a: a, (h_c, kc, vc))
        h_c, (s_new, c_new) = block_decode(h_c, p_i, s_i, c_i, impl=impl)
        return (h_c, kc, vc), (s_new, c_new)

    idxs = jnp.arange(cfg.n_layers)
    (h, kc, vc), (ssm_new, conv_new) = jax.lax.scan(
        body, (h, kc, vc),
        (params["blocks"], cache["ssm"], cache["conv"], idxs))
    h = rms_norm(h, params["final_norm"])
    logits = h @ params["lm_head"]
    new_cache = {"ssm": ssm_new, "conv": conv_new, "attn_k": kc,
                 "attn_v": vc, "pos": pos + 1}
    return logits, new_cache


# --- Program lowering (generic named state) ---------------------------------------
def _emit_shared_block(g, cfg, a: int, resid: str, M: int, by: int,
                       add_attention) -> str:
    """Emit one application of the shared attention block — standard
    transformer ops against the *unstacked* "shared/..." params (the
    same weights at every application; only the KV regions differ per
    application index ``a``)."""
    D, H, KV, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                       cfg.d_ff)
    an = f"app{a}.attn_norm"
    g.add(norm_node(an, M * D, dtype_bytes=by, inputs=[resid],
                    norm="rmsnorm", param="shared/attn_norm"))
    g.add(matmul_node(f"app{a}.wq", M, D, H * hd, dtype_bytes=by,
                      inputs=[an], param="shared/wq"))
    g.add(matmul_node(f"app{a}.wk", M, D, KV * hd, dtype_bytes=by,
                      inputs=[an], param="shared/wk"))
    g.add(matmul_node(f"app{a}.wv", M, D, KV * hd, dtype_bytes=by,
                      inputs=[an], param="shared/wv"))
    add_attention(g, a, [f"app{a}.wq", f"app{a}.wk", f"app{a}.wv"])
    wo = f"app{a}.wo"
    g.add(matmul_node(wo, M, H * hd, D, dtype_bytes=by,
                      inputs=[f"app{a}.attn"], bypass_of=resid,
                      param="shared/wo"))
    mn = f"app{a}.mlp_norm"
    g.add(norm_node(mn, M * D, dtype_bytes=by, inputs=[wo],
                    norm="rmsnorm", param="shared/mlp_norm"))
    g.add(matmul_node(f"app{a}.w_gate", M, D, F, dtype_bytes=by,
                      inputs=[mn], fused_activation=cfg.activation,
                      param="shared/w_gate"))
    g.add(matmul_node(f"app{a}.w_up", M, D, F, dtype_bytes=by,
                      inputs=[mn], param="shared/w_up"))
    g.add(elementwise_node(f"app{a}.glu_mul", "mul", M * F, dtype_bytes=by,
                           inputs=[f"app{a}.w_gate", f"app{a}.w_up"]))
    g.add(matmul_node(f"app{a}.w_down", M, F, D, dtype_bytes=by,
                      inputs=[f"app{a}.glu_mul"], bypass_of=wo,
                      param="shared/w_down"))
    return f"app{a}.w_down"


def _mamba_state_names(i: int) -> tuple[str, str]:
    """Per-layer persistent state names, in ProgramOp.state_regions
    order (recurrent SSM state, conv taps)."""
    return (f"l{i}.ssm", f"l{i}.conv")


def to_graph(cfg: ArchConfig, batch: int = 1, seq: int = 64,
             dtype_bytes: int | None = None,
             write_cache: bool = False) -> ModelGraph:
    """Lower the zamba2 hybrid to the compiler IR: the shared attention
    block (every ``shared_attn_every`` layers, *before* that layer's
    mamba block) lowers fine-grained — it IS a transformer block, so it
    reuses the whole dense op vocabulary including the windowed ring KV
    plan, one pair of KV regions per application — while each mamba
    block is one coarse ``ssm_scan`` op (pre-norm + conv + selective
    scan + gated out-proj + residual) against its recurrent state."""
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    D = cfg.d_model
    e = cfg.shared_attn_every
    M = batch * seq

    def add_attention(g, a, qkv):
        cache_meta = ({"k_cache": f"app{a}.k_cache",
                       "v_cache": f"app{a}.v_cache"} if write_cache else {})
        g.add(attention_node(
            f"app{a}.attn", seq_q=seq, seq_kv=seq, heads=cfg.n_heads,
            kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, batch=batch,
            causal=True, dtype_bytes=by, inputs=qkv,
            window=cfg.attn_window, rope_theta=cfg.rope_theta,
            **cache_meta))

    g = ModelGraph(cfg.name)
    g.add(embed_node("embed", M, cfg.vocab, D, dtype_bytes=by,
                     param="embed"))
    resid = "embed"
    for i in range(cfg.n_layers):
        if e and i % e == 0:
            resid = _emit_shared_block(g, cfg, i // e, resid, M, by,
                                       add_attention)
        g.add(ssm_scan_node(
            f"l{i}.mamba", seq=seq, heads=cfg.ssm_heads,
            head_dim=cfg.ssm_head_dim, state=cfg.ssm_state, d_model=D,
            batch=batch, dtype_bytes=by, inputs=[resid],
            param=f"blocks:{i}",
            **({"states": _mamba_state_names(i)} if write_cache else {})))
        resid = f"l{i}.mamba"
    g.add(norm_node("final_norm", M * D, dtype_bytes=by, inputs=[resid],
                    norm="rmsnorm", param="final_norm"))
    g.add(matmul_node("lm_head", M, D, cfg.vocab, dtype_bytes=by,
                      inputs=["final_norm"], param="lm_head"))
    return g


def to_decode_graph(cfg: ArchConfig, slots: int = 8, max_len: int = 256,
                    dtype_bytes: int | None = None) -> ModelGraph:
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    D = cfg.d_model
    e = cfg.shared_attn_every
    W = min(max_len, cfg.attn_window) if cfg.attn_window else max_len

    def add_attention(g, a, qkv):
        g.add(decode_attention_node(
            f"app{a}.attn", cache_len=W, heads=cfg.n_heads,
            kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, slots=slots,
            dtype_bytes=by, inputs=qkv, window=cfg.attn_window,
            rope_theta=cfg.rope_theta, k_cache=f"app{a}.k_cache",
            v_cache=f"app{a}.v_cache"))

    g = ModelGraph(cfg.name + ".decode")
    g.add(embed_node("embed", slots, cfg.vocab, D, dtype_bytes=by,
                     param="embed"))
    resid = "embed"
    for i in range(cfg.n_layers):
        if e and i % e == 0:
            resid = _emit_shared_block(g, cfg, i // e, resid, slots, by,
                                       add_attention)
        g.add(ssm_scan_node(
            f"l{i}.mamba", seq=1, heads=cfg.ssm_heads,
            head_dim=cfg.ssm_head_dim, state=cfg.ssm_state, d_model=D,
            batch=slots, dtype_bytes=by, inputs=[resid],
            param=f"blocks:{i}", states=_mamba_state_names(i),
            decode=True))
        resid = f"l{i}.mamba"
    g.add(norm_node("final_norm", slots * D, dtype_bytes=by,
                    inputs=[resid], norm="rmsnorm", param="final_norm"))
    g.add(matmul_node("lm_head", slots, D, cfg.vocab, dtype_bytes=by,
                      inputs=["final_norm"], param="lm_head"))
    return g


def _hybrid_state_specs(cfg: ArchConfig, slots: int, max_len: int):
    """Per-layer SSM recurrent state (f32, O(1) in ``max_len``) + conv
    taps, plus one ring KV pair per shared-attention *application*.
    Windowed is the only serving capability that survives the mix: the
    ring KV slides, but the recurrent state is neither pageable nor
    chunkable nor rollback-truncatable."""
    di, N = cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    dt = jnp.dtype(cfg.jdtype)
    kdt = jnp.dtype(cfg.kv_jdtype)
    W = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    kv_shape = (slots, W, cfg.n_kv_heads, cfg.hd)
    kv_size = int(np.prod(kv_shape)) * kdt.itemsize
    specs = []
    for a in range(_n_apps(cfg)):
        specs.append(PersistentSpec(f"app{a}.k_cache", kv_shape, kdt.name,
                                    kv_size))
        specs.append(PersistentSpec(f"app{a}.v_cache", kv_shape, kdt.name,
                                    kv_size))
    s_shape = (slots, H, N, P)
    c_shape = (slots, _CONV_K - 1, di + 2 * N)
    for i in range(cfg.n_layers):
        ssm_name, conv_name = _mamba_state_names(i)
        specs.append(PersistentSpec(
            ssm_name, s_shape, "float32", int(np.prod(s_shape)) * 4))
        specs.append(PersistentSpec(
            conv_name, c_shape, dt.name,
            int(np.prod(c_shape)) * dt.itemsize))
    return tuple(specs), StateCaps(windowed=True)


register_state_family("hybrid", _hybrid_state_specs)

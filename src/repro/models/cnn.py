"""CNN models — the paper's own workloads (AlexNetOWT, ResNet18/50).

Layer-list driven (CNNConfig).  The model itself makes *no* scheduling
decisions: ``to_graph`` lowers the config to the compiler IR, the
schedule compiler (core/schedule.py) decides strips / Mloop-Kloop /
strip storage / fusion, ``core/program.py`` lowers that schedule to an
executable ``Program`` with §5.1 memory regions, and ``forward`` is a
thin wrapper that compiles the Program once per (config, hw, batch) and
executes it through ``runtime/executor.py`` — the plan *is* the fast
path, exactly as the Snowflake compiler's emitted instruction stream is
what the accelerator runs.
"""
from __future__ import annotations

import functools

import jax

from ..configs.base import CNNConfig
from ..core.hw import TPU_V5E, HardwareModel
from ..core.ir import LayerKind, LayerNode, ModelGraph, conv_node, matmul_node
from ..core.program import Program, lower_to_program
from ..core.schedule import compile_model
from ..runtime.executor import jitted_runner
from .common import ParamDef

__all__ = ["param_defs", "forward", "reference_forward", "to_graph",
           "trace_shapes", "compile_program"]


def trace_shapes(cfg: CNNConfig) -> list[tuple[int, int, int]]:
    """(H, W, C) entering each layer; final output shape appended."""
    outs: list[tuple[int, int, int]] = []       # output shape per layer
    ins: list[tuple[int, int, int]] = []
    cur = (cfg.input_hw, cfg.input_hw, cfg.input_ch)
    for i, layer in enumerate(cfg.layers):
        src = outs[layer.input_of] if layer.input_of is not None else cur
        ins.append(src)
        h, w, c = src
        if layer.kind == "conv":
            h = (h + 2 * layer.pad - layer.k) // layer.stride + 1
            w = (w + 2 * layer.pad - layer.k) // layer.stride + 1
            c = layer.c_out
        elif layer.kind in ("maxpool", "avgpool"):
            h = (h + 2 * layer.pad - layer.k) // layer.stride + 1
            w = (w + 2 * layer.pad - layer.k) // layer.stride + 1
        elif layer.kind == "fc":
            h = w = 1
            c = layer.c_out
        cur = (h, w, c)
        outs.append(cur)
    return ins + [cur]


def param_defs(cfg: CNNConfig) -> dict:
    dt = cfg.jdtype
    shapes = trace_shapes(cfg)
    defs = {}
    for i, layer in enumerate(cfg.layers):
        h, w, c = shapes[i]
        if layer.kind == "conv":
            defs[f"layer_{i:02d}"] = {
                "w": ParamDef((layer.k, layer.k, c, layer.c_out),
                              (None, None, "embed", "ff"), dt),
                "b": ParamDef((layer.c_out,), ("ff",), dt, "zeros"),
            }
        elif layer.kind == "fc":
            defs[f"layer_{i:02d}"] = {
                "w": ParamDef((h * w * c, layer.c_out), ("embed", "ff"), dt),
                "b": ParamDef((layer.c_out,), ("ff",), dt, "zeros"),
            }
    return defs


def compile_program(cfg: CNNConfig, batch: int = 1,
                    hw: HardwareModel = TPU_V5E, *,
                    paper_faithful: bool = False) -> Program:
    """graph -> schedule -> regions -> Program, cached per (config, hw,
    batch, tuned-cache generation).  Every fusion / tiling / storage
    decision in the returned Program comes from ``compile_model`` — the
    single source of truth.  When a tuned cache is active
    (``core/autotune.activate``), its measured schedule decisions and
    calibrated cost model are threaded into the compile; the generation
    (content hash) in the memo key means re-tuning can never serve a
    stale Program."""
    from ..core import autotune
    return _compile_program(cfg, batch, hw, paper_faithful,
                            autotune.active_generation())


@functools.lru_cache(maxsize=128)
def _compile_program(cfg: CNNConfig, batch: int, hw: HardwareModel,
                     paper_faithful: bool, generation: str) -> Program:
    from ..core import autotune
    tuned = cost_model = None
    cache = autotune.active()
    if cache is not None and generation != "empty":
        fp = autotune.hw_fingerprint(hw)
        tuned = cache.view(cfg.name, fp, batch)
        cost_model = cache.cost_model(fp)
    dtype_bytes = jax.numpy.dtype(cfg.jdtype).itemsize
    graph = to_graph(cfg, batch=batch, dtype_bytes=dtype_bytes)
    schedule = compile_model(graph, hw, paper_faithful=paper_faithful,
                             tuned=tuned, cost_model=cost_model)
    return lower_to_program(graph, schedule)


def forward(params, x, cfg: CNNConfig, *, impl: str = "auto",
            hw: HardwareModel = TPU_V5E, interpret: bool | None = None):
    """x: (B, H, W, C) -> logits (B, n_classes).

    Compiles the config to a ``Program`` (cached) and executes it; the
    schedule's fusion and tiling flags drive the kernel calls — this
    function decides nothing itself.
    """
    program = compile_program(cfg, batch=x.shape[0], hw=hw)
    runner = jitted_runner(program, impl=impl, interpret=interpret)
    return runner(params, x.astype(cfg.jdtype))


def reference_forward(params, x, cfg: CNNConfig):
    """Unfused oracle: every layer as its own reference op, nothing
    scheduled, every intermediate materialized — the pre-Program
    semantics the parity tests and benchmarks/program_exec.py compare
    the compiled Program against.  Not a decision path: it executes the
    config literally."""
    from ..kernels.conv2d import avgpool2d_ref, conv2d_ref, maxpool2d_ref
    outputs: dict[int, jax.Array] = {}
    h = x.astype(cfg.jdtype)
    for i, layer in enumerate(cfg.layers):
        src = outputs[layer.input_of] if layer.input_of is not None else h
        if layer.kind == "conv":
            p = params[f"layer_{i:02d}"]
            byp = (outputs.get(layer.bypass_of)
                   if layer.bypass_of is not None else None)
            h = conv2d_ref(src, p["w"], stride=layer.stride, pad=layer.pad,
                           bias=p["b"], activation=layer.activation,
                           bypass=byp, bypass_first=layer.bypass_first)
        elif layer.kind == "maxpool":
            h = maxpool2d_ref(src, window=layer.k, stride=layer.stride,
                              pad=layer.pad)
        elif layer.kind == "avgpool":
            h = avgpool2d_ref(src, window=layer.k, stride=layer.stride,
                              pad=layer.pad)
        elif layer.kind == "fc":
            p = params[f"layer_{i:02d}"]
            h = src.reshape(src.shape[0], -1) @ p["w"] + p["b"]
            if layer.activation == "relu":
                h = jax.nn.relu(h)
        outputs[i] = h
    return h


def to_graph(cfg: CNNConfig, batch: int = 1,
             dtype_bytes: int = 2) -> ModelGraph:
    """Lower to the compiler IR (paper §5.1 steps 1-2).

    Pure lowering: dependency labelling and conv->pool fusion are the
    compiler's job (``mark_residuals`` / ``mark_pool_fusion`` inside
    ``compile_model``); the nodes carry the geometry and the execution
    metadata (param group, bypass order, pool window) the Program
    lowering needs.
    """
    g = ModelGraph(cfg.name)
    shapes = trace_shapes(cfg)
    prev_name = None
    names: dict[int, str] = {}
    for i, layer in enumerate(cfg.layers):
        h, w, c = shapes[i]
        name = f"{layer.kind}_{i:02d}"
        inp = (names[layer.input_of] if layer.input_of is not None
               else (prev_name or ""))
        inputs = [inp] if inp else []
        if layer.kind == "conv":
            g.add(conv_node(
                name, h, w, c, layer.c_out, layer.k, layer.k,
                stride=layer.stride, pad=layer.pad, batch=batch,
                dtype_bytes=dtype_bytes, inputs=inputs,
                bypass_of=names.get(layer.bypass_of)
                if layer.bypass_of is not None else None,
                fused_activation=layer.activation,
                param=f"layer_{i:02d}", bypass_first=layer.bypass_first))
        elif layer.kind in ("maxpool", "avgpool"):
            oh = (h + 2 * layer.pad - layer.k) // layer.stride + 1
            g.add(LayerNode(name=name, kind=LayerKind.POOL,
                            dims={"numel": batch * oh * oh * c},
                            dtype_bytes=dtype_bytes, inputs=inputs,
                            meta={"op": ("avg" if layer.kind == "avgpool"
                                         else "max"),
                                  "window": layer.k, "stride": layer.stride,
                                  "pad": layer.pad}))
        elif layer.kind == "fc":
            g.add(matmul_node(name, batch, h * w * c, layer.c_out,
                              dtype_bytes=dtype_bytes, inputs=inputs,
                              fused_bias=True,
                              fused_activation=layer.activation,
                              param=f"layer_{i:02d}", flatten_input=True))
        names[i] = name
        prev_name = name
    return g

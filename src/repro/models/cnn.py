"""CNN models — the paper's own workloads (AlexNetOWT, ResNet18/50).

Layer-list driven (CNNConfig); convs run through kernels/conv2d with
the schedule compiler choosing strips + Mloop/Kloop + strip storage per
layer, residual bypass fused into the consuming conv's epilogue exactly
as the paper fuses the VMOV add into the writeback.  A maxpool directly
following a conv (AlexNet / ResNet stems) is fused into that conv's
kernel epilogue, both in ``forward`` (one fused call) and in
``to_graph`` (meta flags the scheduler uses to zero the pool's
traffic).  ``input_of`` allows parallel paths (projection shortcuts);
``to_graph`` lowers a CNNConfig to the compiler IR for the benchmark
reproductions (Tables 1-3, Fig 4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import CNNConfig
from ..core.ir import LayerKind, LayerNode, ModelGraph, conv_node, matmul_node
from ..kernels.conv2d import avgpool2d_ref, conv2d, maxpool2d_ref
from .common import ParamDef

__all__ = ["param_defs", "forward", "to_graph", "trace_shapes"]


def trace_shapes(cfg: CNNConfig) -> list[tuple[int, int, int]]:
    """(H, W, C) entering each layer; final output shape appended."""
    outs: list[tuple[int, int, int]] = []       # output shape per layer
    ins: list[tuple[int, int, int]] = []
    cur = (cfg.input_hw, cfg.input_hw, cfg.input_ch)
    for i, layer in enumerate(cfg.layers):
        src = outs[layer.input_of] if layer.input_of is not None else cur
        ins.append(src)
        h, w, c = src
        if layer.kind == "conv":
            h = (h + 2 * layer.pad - layer.k) // layer.stride + 1
            w = (w + 2 * layer.pad - layer.k) // layer.stride + 1
            c = layer.c_out
        elif layer.kind in ("maxpool", "avgpool"):
            h = (h + 2 * layer.pad - layer.k) // layer.stride + 1
            w = (w + 2 * layer.pad - layer.k) // layer.stride + 1
        elif layer.kind == "fc":
            h = w = 1
            c = layer.c_out
        cur = (h, w, c)
        outs.append(cur)
    return ins + [cur]


def param_defs(cfg: CNNConfig) -> dict:
    dt = cfg.jdtype
    shapes = trace_shapes(cfg)
    defs = {}
    for i, layer in enumerate(cfg.layers):
        h, w, c = shapes[i]
        if layer.kind == "conv":
            defs[f"layer_{i:02d}"] = {
                "w": ParamDef((layer.k, layer.k, c, layer.c_out),
                              (None, None, "embed", "ff"), dt),
                "b": ParamDef((layer.c_out,), ("ff",), dt, "zeros"),
            }
        elif layer.kind == "fc":
            defs[f"layer_{i:02d}"] = {
                "w": ParamDef((h * w * c, layer.c_out), ("embed", "ff"), dt),
                "b": ParamDef((layer.c_out,), ("ff",), dt, "zeros"),
            }
    return defs


def _fusable_pool(cfg: CNNConfig, i: int, needed: set) -> int | None:
    """Index of a maxpool fusable into conv ``i``'s epilogue, or None.

    Fusable when the next layer is a maxpool fed by this conv and the
    raw conv output is not separately consumed (residual / parallel
    path) — then the pool runs on-chip and its HBM round trip vanishes.
    """
    j = i + 1
    if i in needed or j >= len(cfg.layers):
        return None
    nxt = cfg.layers[j]
    if nxt.kind != "maxpool" or nxt.input_of not in (None, i):
        return None
    return j


def forward(params, x, cfg: CNNConfig, *, impl: str = "auto"):
    """x: (B, H, W, C) -> logits (B, n_classes).

    conv -> maxpool pairs are executed as one fused kernel call (the
    pool in the conv's epilogue) when the conv output has no other
    consumer; numerics are identical to the unfused sequence.
    """
    outputs: dict[int, jax.Array] = {}
    needed = {l.bypass_of for l in cfg.layers if l.bypass_of is not None}
    needed |= {l.input_of for l in cfg.layers if l.input_of is not None}
    h = x.astype(cfg.jdtype)
    fused_pools: set[int] = set()
    for i, layer in enumerate(cfg.layers):
        if i in fused_pools:
            continue
        src = outputs[layer.input_of] if layer.input_of is not None else h
        if layer.kind == "conv":
            p = params[f"layer_{i:02d}"]
            bypass = outputs.get(layer.bypass_of) \
                if layer.bypass_of is not None else None
            j = _fusable_pool(cfg, i, needed)
            fuse_pool = None
            if j is not None:
                pool = cfg.layers[j]
                fuse_pool = (pool.k, pool.stride, pool.pad)
                fused_pools.add(j)
            h = conv2d(src, p["w"], stride=layer.stride, pad=layer.pad,
                       bias=p["b"], activation=layer.activation,
                       bypass=bypass, bypass_first=layer.bypass_first,
                       fuse_pool=fuse_pool, impl=impl)
            if j is not None and j in needed:
                outputs[j] = h
        elif layer.kind == "maxpool":
            h = maxpool2d_ref(src, window=layer.k, stride=layer.stride,
                              pad=layer.pad)
        elif layer.kind == "avgpool":
            h = avgpool2d_ref(src, window=layer.k, stride=layer.stride,
                              pad=layer.pad)
        elif layer.kind == "fc":
            p = params[f"layer_{i:02d}"]
            B = src.shape[0]
            h = src.reshape(B, -1) @ p["w"] + p["b"]
            if layer.activation == "relu":
                h = jax.nn.relu(h)
        if i in needed:
            outputs[i] = h
    return h


def to_graph(cfg: CNNConfig, batch: int = 1,
             dtype_bytes: int = 2) -> ModelGraph:
    """Lower to the compiler IR (paper §5.1 steps 1-2)."""
    g = ModelGraph(cfg.name)
    shapes = trace_shapes(cfg)
    prev_name = None
    names: dict[int, str] = {}
    for i, layer in enumerate(cfg.layers):
        h, w, c = shapes[i]
        name = f"{layer.kind}_{i:02d}"
        inp = (names[layer.input_of] if layer.input_of is not None
               else (prev_name or ""))
        inputs = [inp] if inp else []
        if layer.kind == "conv":
            g.add(conv_node(
                name, h, w, c, layer.c_out, layer.k, layer.k,
                stride=layer.stride, pad=layer.pad, batch=batch,
                dtype_bytes=dtype_bytes, inputs=inputs,
                bypass_of=names.get(layer.bypass_of)
                if layer.bypass_of is not None else None,
                fused_activation=layer.activation))
        elif layer.kind in ("maxpool", "avgpool"):
            oh = (h + 2 * layer.pad - layer.k) // layer.stride + 1
            g.add(LayerNode(name=name, kind=LayerKind.POOL,
                            dims={"numel": batch * oh * oh * c},
                            dtype_bytes=dtype_bytes, inputs=inputs))
        elif layer.kind == "fc":
            g.add(matmul_node(name, batch, h * w * c, layer.c_out,
                              dtype_bytes=dtype_bytes, inputs=inputs,
                              fused_bias=True))
        names[i] = name
        prev_name = name
    # Record conv->maxpool fusion (mirrors forward()): the pool runs in
    # the conv's epilogue, so the scheduler shrinks the conv's out
    # stream and zeroes the pool layer's traffic.
    needed = {l.bypass_of for l in cfg.layers if l.bypass_of is not None}
    needed |= {l.input_of for l in cfg.layers if l.input_of is not None}
    for i, layer in enumerate(cfg.layers):
        if layer.kind != "conv":
            continue
        j = _fusable_pool(cfg, i, needed)
        if j is None:
            continue
        pool = cfg.layers[j]
        g.get(names[i]).meta["fused_pool"] = {
            "window": pool.k, "stride": pool.stride, "pad": pool.pad}
        g.get(names[j]).meta["fused_into"] = names[i]
    g.mark_residuals()
    return g

"""Memory-efficient losses.

``chunked_cross_entropy`` never materializes the full (B, S, V) logits:
the head matmul + log-softmax run per sequence chunk under
``jax.checkpoint``, so the backward pass recomputes each chunk's logits
instead of saving them.  With a vocab-sharded head the live buffer is
(B, chunk, V/model) — the difference between a 47 GB and a 1.5 GB
training step for the 50k-200k-vocab archs (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["chunked_cross_entropy"]


def chunked_cross_entropy(h: jax.Array, head_w: jax.Array,
                          labels: jax.Array, *, chunk: int = 512,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean token CE of ``h @ head_w`` against ``labels``.

    h: (B, S, D); head_w: (D, V); labels: (B, S); mask: (B, S) or None.
    S must not need padding: chunk is clamped to a divisor of S.
    """
    B, S, D = h.shape
    V = head_w.shape[-1]
    c = min(chunk, S)
    while S % c != 0:
        c //= 2
    n_chunks = S // c
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)

    hc = h.reshape(B, n_chunks, c, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, c).transpose(1, 0, 2)
    mc = mask.reshape(B, n_chunks, c).transpose(1, 0, 2)

    @jax.checkpoint
    def one(hi, li, mi):
        logits = (hi @ head_w).astype(jnp.float32)      # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mi)

    def body(acc, xs):
        hi, li, mi = xs
        return acc + one(hi, li, mi), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (hc, lc, mc))
    return total / jnp.maximum(mask.sum(), 1.0)

"""Decoder-only transformer LM: dense (llama-family), MoE, and
cross-attention (VLM) variants — one implementation parameterized by
``ArchConfig``.

Layers are stacked on a leading "layers" axis and run under
``jax.lax.scan`` (one-block HLO; tractable 512-device dry-run compiles).
All matmul-shaped compute routes through kernels/ (schedule-driven
Pallas on TPU, reference on CPU); attention through the flash /
decode_attention kernels.

The dense family additionally lowers to the compiler pipeline exactly
like the CNNs (models/cnn.py): ``to_graph`` emits the layer graph
(embed -> N x {norm, qkv matmuls, flash attention, o-proj, MLP matmul
chain} -> final norm -> lm head) with the residual adds fused into the
o-/down-projection writebacks, ``compile_program`` runs it through
graph -> schedule -> regions -> Program, and ``program_forward``
executes the instruction stream through runtime/executor.py.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

import numpy as np

from ..configs.base import ArchConfig
from ..core.hw import TPU_V5E, HardwareModel
from ..core.ir import (ModelGraph, attention_node, decode_attention_node,
                       elementwise_node, embed_node, matmul_node, moe_node,
                       norm_node)
from ..core.program import Program, ProgramPair, lower_to_program
from ..core.regions import (PAGE_TABLE_REGION, PersistentSpec, StateCaps,
                            allocate_regions, extend_with_persistent,
                            paged_kv_specs, register_state_family,
                            state_specs)
from ..core.schedule import compile_model
from ..kernels.decode_attention import (decode_attention, ring_kv_len,
                                        ring_positions)
from ..kernels.flash_attention import flash_attention
from ..kernels.common import apply_activation
from ..parallel.act_sharding import shard_act
from .common import (ParamDef, Rotary, apply_rope, layer_norm, rms_norm)
from .moe import moe_mlp

__all__ = ["param_defs", "forward", "init_cache", "decode_step",
           "to_graph", "to_decode_graph", "compile_program",
           "compile_program_pair", "compile_draft_pair",
           "program_forward", "kv_cache_len"]


# --- parameter declaration -------------------------------------------------------
def _norm_defs(cfg: ArchConfig, L: int | None, name: str) -> dict:
    """Norm params; nonparametric LN (OLMo) contributes none."""
    if cfg.norm == "nonparametric":
        return {}
    dt = cfg.jdtype
    shape = (L, cfg.d_model) if L else (cfg.d_model,)
    axes = ("layers", "embed") if L else ("embed",)
    d = {name: ParamDef(shape, axes, dt, "ones")}
    if cfg.norm == "layernorm":
        d[name + "_b"] = ParamDef(shape, axes, dt, "zeros")
    return d


def _attn_defs(cfg: ArchConfig, L: int | None) -> dict:
    dt = cfg.jdtype
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    def p(shape, axes):
        if L:
            return ParamDef((L,) + shape, ("layers",) + axes, dt)
        return ParamDef(shape, axes, dt)
    return {
        "wq": p((D, H * hd), ("embed", "heads")),
        "wk": p((D, KV * hd), ("embed", "kv_heads")),
        "wv": p((D, KV * hd), ("embed", "kv_heads")),
        "wo": p((H * hd, D), ("heads", "embed")),
    }


def _mlp_defs(cfg: ArchConfig, L: int | None, moe: bool | None = None) -> dict:
    dt = cfg.jdtype
    D, F = cfg.d_model, cfg.d_ff
    def p(shape, axes):
        if L:
            return ParamDef((L,) + shape, ("layers",) + axes, dt)
        return ParamDef(shape, axes, dt)
    use_moe = cfg.n_experts > 0 if moe is None else moe
    if use_moe:
        E = cfg.n_experts
        d = {"router": p((D, E), ("embed", None)),
             "w_gate": p((E, D, F), ("experts", "embed", "ff")),
             "w_down": p((E, F, D), ("experts", "ff", "embed"))}
        if cfg.gated_mlp:
            d["w_up"] = p((E, D, F), ("experts", "embed", "ff"))
        return d
    d = {"w_gate": p((D, F), ("embed", "ff")),
         "w_down": p((F, D), ("ff", "embed"))}
    if cfg.gated_mlp:
        d["w_up"] = p((D, F), ("embed", "ff"))
    return d


def _block_defs(cfg: ArchConfig, L: int, moe: bool | None = None) -> dict:
    blocks = {}
    blocks.update(_norm_defs(cfg, L, "attn_norm"))
    blocks.update(_attn_defs(cfg, L))
    blocks.update(_norm_defs(cfg, L, "mlp_norm"))
    blocks.update(_mlp_defs(cfg, L, moe))
    return blocks


def param_defs(cfg: ArchConfig) -> dict:
    dt = cfg.jdtype
    L = cfg.n_layers
    interleaved = cfg.n_experts > 0 and cfg.moe_every > 1
    if interleaved:
        assert L % cfg.moe_every == 0, (L, cfg.moe_every)
        G = L // cfg.moe_every
        defs: dict[str, Any] = {
            "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                              dt, "embed"),
            "blocks": _block_defs(cfg, L - G, moe=False),
            "moe_blocks": _block_defs(cfg, G, moe=True),
        }
    else:
        defs = {
            "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                              dt, "embed"),
            "blocks": _block_defs(cfg, L),
        }
    defs.update(_norm_defs(cfg, None, "final_norm"))
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab),
                                   ("embed", "vocab"), dt)
    if cfg.cross_attn_every:
        G = cfg.n_layers // cfg.cross_attn_every
        cross = {}
        cross.update(_norm_defs(cfg, G, "attn_norm"))
        cross.update({k: ParamDef((G,) + v.shape[1:], v.axes, v.dtype)
                      for k, v in _attn_defs(cfg, L).items()})
        cross["gate"] = ParamDef((G,), ("layers",), dt, "zeros")
        defs["cross_blocks"] = cross
    return defs


# --- building blocks --------------------------------------------------------------
def _norm(h, p, cfg, name):
    if cfg.norm == "nonparametric":
        return layer_norm(h)
    if cfg.norm == "layernorm":
        return layer_norm(h, p[name], p.get(name + "_b"))
    return rms_norm(h, p[name])


def _heads(x, n, hd):
    B, S = x.shape[0], x.shape[1]
    return x.reshape(B, S, n, hd).transpose(0, 2, 1, 3)   # (B, n, S, hd)


def _attention(h, p, cfg, cos, sin, *, impl, causal=True, window=None,
               kv_override=None, return_kv=False):
    """Self- (or cross-, via kv_override) attention on (B, S, D)."""
    B, S, D = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _heads(h @ p["wq"], H, hd)
    if kv_override is None:
        k = _heads(h @ p["wk"], KV, hd)
        v = _heads(h @ p["wv"], KV, hd)
        if cos is not None:
            q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    else:
        src = kv_override                                  # (B, Skv, D)
        k = _heads(src @ p["wk"], KV, hd)
        v = _heads(src @ p["wv"], KV, hd)
        if cos is not None:
            q = apply_rope(q, cos, sin)
    q = shard_act(q, "attn_q")
    # Under sequence parallelism K/V must be whole before the chunked
    # attention scan: one small (GQA) all-gather per layer here instead
    # of a full-score all-reduce per kv chunk (§Perf H2 iter 2).
    k = shard_act(k, "attn_kv")
    v = shard_act(v, "attn_kv")
    out = flash_attention(q, k, v, causal=causal, window=window, impl=impl)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    out = out @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def _mlp(h, p, cfg):
    B, S, D = h.shape
    if "router" in p:
        out, aux = moe_mlp(h.reshape(B * S, D), p["router"], p["w_gate"],
                           p.get("w_up", p["w_gate"]), p["w_down"],
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           activation=cfg.activation, gated=cfg.gated_mlp)
        return out.reshape(B, S, D), aux
    g = apply_activation(h @ p["w_gate"], cfg.activation)
    if cfg.gated_mlp:
        g = g * (h @ p["w_up"])
    return g @ p["w_down"], {}


def _block(h, p, cfg, cos, sin, *, impl, window=None, return_kv=False):
    attn_in = _norm(h, p, cfg, "attn_norm")
    if return_kv:
        a, kv = _attention(attn_in, p, cfg, cos, sin, impl=impl,
                           window=window, return_kv=True)
    else:
        a = _attention(attn_in, p, cfg, cos, sin, impl=impl, window=window)
        kv = None
    h = shard_act(h + a, "hidden")
    m, aux = _mlp(_norm(h, p, cfg, "mlp_norm"), p, cfg)
    h = shard_act(h + m, "hidden")
    return (h, kv, aux) if return_kv else (h, aux)


def _cross_block(h, p, cfg, vis, *, impl):
    """Gated cross-attention sub-block (llama-3.2-vision style)."""
    a = _attention(_norm(h, p, cfg, "attn_norm"), p, cfg, None, None,
                   impl=impl, causal=False, kv_override=vis)
    return shard_act(h + jnp.tanh(p["gate"]).astype(h.dtype) * a, "hidden")


# --- forward ----------------------------------------------------------------------
def forward(params, tokens, cfg: ArchConfig, *, vision_embeds=None,
            impl: str = "auto", return_cache: bool = False,
            cache_len: int | None = None, remat: bool = False,
            return_hidden: bool = False):
    """tokens (B, S) -> {"logits": (B, S, V), "aux": {...}[, "cache"]}."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.jdtype)
    h = shard_act(h, "hidden")
    rot = Rotary(cfg.hd, cfg.rope_theta)
    cos, sin = rot.freqs(jnp.arange(S))

    def body(carry, p_i):
        out = _block(carry, p_i, cfg, cos, sin, impl=impl,
                     window=cfg.attn_window, return_kv=return_cache)
        if return_cache:
            h2, kv, aux = out
            return h2, (kv, aux)
        h2, aux = out
        return h2, aux

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    interleaved = cfg.n_experts > 0 and cfg.moe_every > 1
    if cfg.cross_attn_every:
        G = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every
        blocks = jax.tree.map(
            lambda x: x.reshape((G, per) + x.shape[1:]), params["blocks"])
        vis = vision_embeds
        assert vis is not None, "vlm arch requires vision_embeds"

        def group(carry, xs):
            cross_p, self_p = xs
            carry = _cross_block(carry, cross_p, cfg, vis, impl=impl)
            carry, ys = jax.lax.scan(body, carry, self_p)
            return carry, ys

        h, ys = jax.lax.scan(group, h, (params["cross_blocks"], blocks))
        ys = jax.tree.map(lambda x: x.reshape((cfg.n_layers,) + x.shape[2:]),
                          ys)
        kvs, auxs = ys if return_cache else (None, ys)
    elif interleaved:
        # llama4-style: (moe_every - 1) dense layers, then one MoE layer.
        per = cfg.moe_every
        G = cfg.n_layers // per
        dense = jax.tree.map(
            lambda x: x.reshape((G, per - 1) + x.shape[1:]),
            params["blocks"])

        def group(carry, xs):
            moe_p, dense_g = xs
            carry, ys_d = jax.lax.scan(body, carry, dense_g)
            carry, ys_m = body(carry, moe_p)
            return carry, (ys_d, ys_m)

        h, (ys_d, ys_m) = jax.lax.scan(group, h,
                                       (params["moe_blocks"], dense))
        if return_cache:
            (kv_d, aux_d), (kv_m, aux_m) = ys_d, ys_m
            kvs = jax.tree.map(
                lambda d, m: jnp.concatenate(
                    [d, m[:, None]], axis=1).reshape(
                        (cfg.n_layers,) + d.shape[2:]), kv_d, kv_m)
            auxs = aux_m
        else:
            kvs, auxs = None, ys_m
    else:
        h, ys = jax.lax.scan(body, h, params["blocks"])
        kvs, auxs = ys if return_cache else (None, ys)
    h = _norm(h, params, cfg, "final_norm")
    if return_hidden:
        logits = None
    else:
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = shard_act(h @ head, "logits")

    aux = {}
    if cfg.n_experts and auxs:
        aux = {k: jnp.mean(v) for k, v in auxs.items() if v is not None}
    out = {"logits": logits, "aux": aux}
    if return_hidden:
        out["hidden"] = h
    if return_cache:
        k_stack, v_stack = kvs               # (L, B, KV, S, hd)
        CL = cache_len or S
        if cfg.attn_window:
            CL = min(CL, cfg.attn_window)
        if CL > S:                           # room to append during decode
            padw = ((0, 0),) * 3 + ((0, CL - S), (0, 0))
            k_stack = jnp.pad(k_stack, padw)
            v_stack = jnp.pad(v_stack, padw)
        elif CL < S:                         # rolling window: keep last CL
            # One shared ring-layout rule (kernels/decode_attention):
            # slot j holds the latest position p < S with p % CL == j —
            # the same conversion the Program prefill performs at a
            # runtime length (executor._write_prefill_cache).
            pos = ring_positions(S, CL, S)
            k_stack = k_stack[:, :, :, pos]
            v_stack = v_stack[:, :, :, pos]
        k_stack = k_stack.astype(cfg.kv_jdtype)
        v_stack = v_stack.astype(cfg.kv_jdtype)
        cache = {"k": k_stack, "v": v_stack,
                 "pos": jnp.full((B,), S, jnp.int32)}
        if cfg.cross_attn_every:
            cache["cross_k"], cache["cross_v"] = _cross_kv(params, cfg,
                                                           vision_embeds)
        out["cache"] = cache
    return out


def _cross_kv(params, cfg, vis):
    """Precompute cross-attention KV for all cross blocks (decode)."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    def one(p):
        return _heads(vis @ p["wk"], KV, hd), _heads(vis @ p["wv"], KV, hd)
    return jax.vmap(one)(params["cross_blocks"])   # (G, B, KV, Tv, hd)


# --- compile-to-Program lowering (dense family) -----------------------------------
def _require_dense(cfg: ArchConfig) -> None:
    """Gate what the *transformer-graph* lowering cannot express, with
    every blocker named (the serving engine's legacy-fallback warning
    and ``serve.py --program``'s exit-2 path print the full list).
    Dense and MoE decoder-only configs lower here; SSM / hybrid / audio
    families lower through their own modules' graph builders, so the
    remaining blockers are the vision-bridge features."""
    blockers = []
    if cfg.family not in ("dense", "moe"):
        blockers.append(f"family={cfg.family} (not a decoder-only "
                        f"transformer graph)")
    if cfg.cross_attn_every:
        blockers.append("gated cross-attention (vision bridge)")
    if cfg.n_vision_tokens:
        blockers.append("vision-encoder inputs")
    if cfg.n_encoder_layers:
        blockers.append("encoder-decoder")
    if cfg.shared_attn_every:
        blockers.append("shared attention blocks")
    if blockers:
        raise NotImplementedError(
            f"Program lowering covers the decoder-only transformer "
            f"families (windowed attention and MoE included); "
            f"{cfg.name} is blocked by: {', '.join(blockers)} — it "
            f"still runs the scan forward")


def kv_cache_len(cfg: ArchConfig, max_len: int) -> int:
    """Per-slot KV rows the §5.1 region plan reserves: the paper's
    "region sized at the largest output it holds" discipline applied to
    state — a sliding window means positions older than ``attn_window``
    are never attendable, so the persistent region holds
    ``min(max_len, attn_window)`` rows and eviction is the rolling
    overwrite at ``pos % cache_len``.  One rule shared by
    ``init_cache`` (legacy loop) and ``_kv_cache_specs`` (Programs)."""
    if cfg.attn_window:
        return min(max_len, cfg.attn_window)
    return max_len


def _block_path(cfg: ArchConfig, i: int) -> tuple[str, int, bool]:
    """(param group, index-within-group, is_moe) for global layer ``i``
    — the graph-side mirror of ``forward``'s interleaved llama4-style
    grouping ((moe_every - 1) dense layers then one MoE layer, params
    split across "blocks" / "moe_blocks") and of the all-MoE layout
    (moe_every <= 1: every layer's experts live stacked in "blocks")."""
    if cfg.n_experts > 0 and cfg.moe_every > 1:
        g, r = divmod(i, cfg.moe_every)
        if r == cfg.moe_every - 1:
            return "moe_blocks", g, True
        return "blocks", g * (cfg.moe_every - 1) + r, False
    return "blocks", i, cfg.n_experts > 0


def _build_lm_graph(cfg: ArchConfig, name: str, M: int, by: int,
                    add_attention) -> ModelGraph:
    """One block emitter for every dense-LM graph flavor (stateless,
    cache-writing prefill, per-token decode) — the flavors differ only
    in the token count M and the attention node, supplied by
    ``add_attention(g, i, qkv_inputs)``.  Keeping a single emitter is
    what guarantees the prefill and decode graphs of a serving pair can
    never structurally drift apart."""
    D, H, KV, hd, F = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff

    def norm_meta(param: str | None) -> dict:
        meta = {"norm": cfg.norm}
        if cfg.norm != "nonparametric" and param is not None:
            meta["param"] = param
            if cfg.norm == "layernorm":
                meta["param_b"] = (param + "_b" if ":" not in param else
                                   param.replace(":", "_b:", 1))
        return meta

    g = ModelGraph(name)
    g.add(embed_node("embed", M, cfg.vocab, D, dtype_bytes=by,
                     param="embed"))
    resid = "embed"
    for i in range(cfg.n_layers):
        grp, gi, is_moe = _block_path(cfg, i)

        def bp(k: str, grp=grp, gi=gi) -> str:
            return f"{grp}/{k}:{gi}"
        an = f"l{i}.attn_norm"
        g.add(norm_node(an, M * D, dtype_bytes=by, inputs=[resid],
                        **norm_meta(bp("attn_norm"))))
        g.add(matmul_node(f"l{i}.wq", M, D, H * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wq")))
        g.add(matmul_node(f"l{i}.wk", M, D, KV * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wk")))
        g.add(matmul_node(f"l{i}.wv", M, D, KV * hd, dtype_bytes=by,
                          inputs=[an], param=bp("wv")))
        add_attention(g, i, [f"l{i}.wq", f"l{i}.wk", f"l{i}.wv"])
        wo = f"l{i}.wo"
        g.add(matmul_node(wo, M, H * hd, D, dtype_bytes=by,
                          inputs=[f"l{i}.attn"], bypass_of=resid,
                          param=bp("wo")))
        mn = f"l{i}.mlp_norm"
        g.add(norm_node(mn, M * D, dtype_bytes=by, inputs=[wo],
                        **norm_meta(bp("mlp_norm"))))
        if is_moe:
            # One capacity-bucketed dispatch op replaces the dense MLP
            # chain; the whole block's stacked params ride the group
            # path ("moe_blocks:2" tree-slices every leaf at index 2)
            # and the routing config travels on the node for op_cfg.
            g.add(moe_node(f"l{i}.moe", tokens=M, d_model=D, d_ff=F,
                           experts=cfg.n_experts, top_k=cfg.top_k,
                           dtype_bytes=by, inputs=[mn], bypass_of=wo,
                           param=f"{grp}:{gi}",
                           capacity_factor=cfg.capacity_factor,
                           activation=cfg.activation,
                           gated=cfg.gated_mlp))
            resid = f"l{i}.moe"
            continue
        g.add(matmul_node(f"l{i}.w_gate", M, D, F, dtype_bytes=by,
                          inputs=[mn], fused_activation=cfg.activation,
                          param=bp("w_gate")))
        if cfg.gated_mlp:
            g.add(matmul_node(f"l{i}.w_up", M, D, F, dtype_bytes=by,
                              inputs=[mn], param=bp("w_up")))
            g.add(elementwise_node(f"l{i}.glu_mul", "mul", M * F,
                                   dtype_bytes=by,
                                   inputs=[f"l{i}.w_gate", f"l{i}.w_up"]))
            down_in = f"l{i}.glu_mul"
        else:
            down_in = f"l{i}.w_gate"
        g.add(matmul_node(f"l{i}.w_down", M, F, D, dtype_bytes=by,
                          inputs=[down_in], bypass_of=wo,
                          param=bp("w_down")))
        resid = f"l{i}.w_down"
    g.add(norm_node("final_norm", M * D, dtype_bytes=by, inputs=[resid],
                    **norm_meta("final_norm")))
    g.add(matmul_node("lm_head", M, D, cfg.vocab, dtype_bytes=by,
                      inputs=["final_norm"],
                      param="embed" if cfg.tie_embeddings else "lm_head",
                      transpose_w=cfg.tie_embeddings))
    return g


def _paged_cache_meta(i: int, page_size: int, kv_quant: str | None) -> dict:
    """Attention-node meta for the paged region plan: the cache names
    resolve to the §5.1 page *pools*, the shared table and (for int8
    pools) the per-page scale regions ride along, and ``page_size``
    reaches the schedule so the decode kv block is pinned to the page."""
    meta = {"k_cache": f"l{i}.k_pages", "v_cache": f"l{i}.v_pages",
            "page_table": PAGE_TABLE_REGION, "page_size": page_size}
    if kv_quant == "int8":
        meta["k_scale"] = f"l{i}.k_scale"
        meta["v_scale"] = f"l{i}.v_scale"
    return meta


def to_graph(cfg: ArchConfig, batch: int = 1, seq: int = 64,
             dtype_bytes: int | None = None,
             write_cache: bool = False,
             page_size: int | None = None,
             kv_quant: str | None = None) -> ModelGraph:
    """Lower a dense-transformer config to the compiler IR (§5.1
    steps 1-2), mirroring ``forward``'s op-for-op structure:

        embed -> N x [attn_norm, wq|wk|wv, flash_attention, wo(+resid),
                      mlp_norm, w_gate|w_up, mul, w_down(+resid)]
              -> final_norm -> lm_head

    Residual adds are not standalone ops: each block's two adds ride
    the o-projection / down-projection writeback (``bypass_of``, the
    paper's VMOV-on-writeback), which is what makes the residual stream
    a RESIDUAL_SOURCE the §5.1 allocator pins across the block.  Param
    paths point into the stacked parameter tree ("blocks/wq:3").

    ``write_cache=True`` emits the *prefill* flavor of the graph (the
    serving pair's first half): each attention node additionally names
    the persistent ``l{i}.k_cache`` / ``l{i}.v_cache`` regions it
    writes the computed (post-RoPE) K and raw V into at the admitted
    slot — a runtime operand carried by the executor's ProgramState.
    ``page_size`` switches those names to the paged plan's page pools
    (plus the shared page-table region, and per-page scale regions when
    ``kv_quant="int8"``) — see ``regions.paged_kv_specs``."""
    _require_dense(cfg)
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def add_attention(g, i, qkv):
        cache_meta = {}
        if write_cache:
            cache_meta = ({"k_cache": f"l{i}.k_cache",
                           "v_cache": f"l{i}.v_cache"} if page_size is None
                          else _paged_cache_meta(i, page_size, kv_quant))
        g.add(attention_node(
            f"l{i}.attn", seq_q=seq, seq_kv=seq, heads=H, kv_heads=KV,
            head_dim=hd, batch=batch, causal=True, dtype_bytes=by,
            inputs=qkv, window=cfg.attn_window, rope_theta=cfg.rope_theta,
            **cache_meta))

    return _build_lm_graph(cfg, cfg.name, batch * seq, by, add_attention)


def _tuned_context(cfg_name: str, batch: int, hw: HardwareModel,
                   generation: str):
    """(tuned_view, cost_model) from the active autotune cache, or
    (None, None).  Shared by the compile entry points below; the
    ``generation`` threaded through their memo keys is what makes
    re-tuning invalidate memoized Programs (the stale-Program bugfix)."""
    from ..core import autotune
    cache = autotune.active()
    if cache is None or generation == "empty":
        return None, None
    fp = autotune.hw_fingerprint(hw)
    return cache.view(cfg_name, fp, batch), cache.cost_model(fp)


def compile_program(cfg: ArchConfig, batch: int = 1, seq: int = 64,
                    hw: HardwareModel = TPU_V5E) -> Program:
    """graph -> schedule -> regions -> Program for a dense-transformer
    config, cached per (config, batch, seq, hw, tuned-cache
    generation).  Every tiling / attention-block / fusion decision in
    the returned Program comes from ``compile_model`` — the single
    source of truth, exactly as for the CNNs
    (models/cnn.py::compile_program)."""
    from ..core import autotune
    return _compile_program(cfg, batch, seq, hw,
                            autotune.active_generation())


@functools.lru_cache(maxsize=64)
def _compile_program(cfg: ArchConfig, batch: int, seq: int,
                     hw: HardwareModel, generation: str) -> Program:
    tuned, cost_model = _tuned_context(cfg.name, batch, hw, generation)
    graph = to_graph(cfg, batch=batch, seq=seq)
    schedule = compile_model(graph, hw, tuned=tuned, cost_model=cost_model)
    return lower_to_program(graph, schedule)


def to_decode_graph(cfg: ArchConfig, slots: int = 8, max_len: int = 256,
                    dtype_bytes: int | None = None,
                    page_size: int | None = None,
                    kv_quant: str | None = None) -> ModelGraph:
    """Lower the per-token decode step to the compiler IR: the same
    block structure as ``to_graph`` (one shared emitter) but with one
    token per slot (M = slots) and the attention replaced by
    ``decode_attention`` against the persistent per-block KV-cache
    regions — op-for-op the graph of ``decode_step``.

    Windowed attention lowers as a *region-plan decision*: the decode
    node's cache extent is ``kv_cache_len`` (= min(max_len,
    attn_window)), the node carries ``window`` so the schedule's
    decode-regime block chooser sizes ``block_kv`` against the window,
    and the executor's rolling write at ``pos % cache_len`` is the
    eviction — op-for-op the legacy ``_attention_decode`` ring rule."""
    _require_dense(cfg)
    by = (dtype_bytes if dtype_bytes is not None
          else jnp.dtype(cfg.jdtype).itemsize)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cache_len = kv_cache_len(cfg, max_len)

    def add_attention(g, i, qkv):
        if page_size is None:
            cache_meta = {"k_cache": f"l{i}.k_cache",
                          "v_cache": f"l{i}.v_cache"}
        else:
            cache_meta = _paged_cache_meta(i, page_size, kv_quant)
        g.add(decode_attention_node(
            f"l{i}.attn", cache_len=cache_len, heads=H, kv_heads=KV,
            head_dim=hd, slots=slots, dtype_bytes=by, inputs=qkv,
            window=cfg.attn_window, rope_theta=cfg.rope_theta,
            **cache_meta))

    return _build_lm_graph(cfg, cfg.name + ".decode", slots, by,
                           add_attention)


def _kv_cache_specs(cfg: ArchConfig, slots: int,
                    max_len: int) -> tuple[PersistentSpec, ...]:
    """One persistent (slots, kv_cache_len, kv_heads, head_dim) region
    per block and cache side, in the engine's KV dtype.  A sliding
    window shrinks the resident rows to the window (max_len/W fewer
    persistent KV bytes), the §5.1 sizing rule applied to state."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.kv_jdtype)
    shape = (slots, kv_cache_len(cfg, max_len), KV, hd)
    size = int(np.prod(shape)) * dt.itemsize
    specs = []
    for i in range(cfg.n_layers):
        specs.append(PersistentSpec(f"l{i}.k_cache", shape, dt.name, size))
        specs.append(PersistentSpec(f"l{i}.v_cache", shape, dt.name, size))
    return tuple(specs)


# Generic named-state hooks (regions.state_specs).  Dense KV state
# composes with every serving feature; MoE shares the KV-shaped state
# but chunked prefill is gated (expert capacity is a whole-sequence
# decision — a chunk boundary re-buckets routing) and so is
# speculation (rollback re-runs routing over rolled-back tokens).
register_state_family(
    "dense", lambda cfg, slots, max_len: (
        _kv_cache_specs(cfg, slots, max_len),
        StateCaps(paged=True, windowed=True, chunkable=True,
                  speculatable=True)))
register_state_family(
    "moe", lambda cfg, slots, max_len: (
        _kv_cache_specs(cfg, slots, max_len),
        StateCaps(paged=True, windowed=True, chunkable=False,
                  speculatable=False)))


def compile_program_pair(cfg: ArchConfig, slots: int = 8,
                         max_len: int = 256,
                         hw: HardwareModel = TPU_V5E, *,
                         paged: bool = False, page_size: int = 16,
                         page_pool: int | None = None,
                         kv_quant: str | None = None) -> ProgramPair:
    from ..core import autotune
    return _compile_program_pair(cfg, slots, max_len, hw,
                                 autotune.active_generation(),
                                 paged, page_size, page_pool, kv_quant)


@functools.lru_cache(maxsize=32)
def _compile_program_pair(cfg: ArchConfig, slots: int, max_len: int,
                          hw: HardwareModel, generation: str,
                          paged: bool = False, page_size: int = 16,
                          page_pool: int | None = None,
                          kv_quant: str | None = None) -> ProgramPair:
    """Compile the stateful serving pair: a batch-1 prefill Program
    (full causal forward + cache writes at the admitted slot) and a
    decode Program (one token per slot against the cache), sharing one
    persistent region table so a single runtime ``ProgramState``
    addresses both.  Cached per (config, slots, max_len, hw,
    tuned-cache generation); tuned decode entries are looked up at
    ``batch=slots`` (matching ``core/autotune.tune_lm_decode``) and
    prefill entries at ``batch=1``.

    For a windowed config the persistent regions hold
    ``kv_cache_len = min(max_len, attn_window)`` rows per slot; the
    prefill executor converts the full-``max_len`` K/V into the rolling
    (ring) layout at write time and decode overwrites at ``pos %
    cache_len`` — the full-cache and windowed plans differ *only* in
    region shape, never in instruction structure.

    ``paged=True`` selects the third region-plan scheme: the allocator
    mints page pools + a page table (``regions.paged_kv_specs``)
    instead of contiguous rows — ``page_pool`` caps the pool (the HBM
    budget knob, default worst-case) and ``kv_quant="int8"`` stores
    quantized pages with per-page scales.  Paged is mutually exclusive
    with a sliding window (the window is already a shrunk contiguous
    plan; paging it would page a ring, which buys nothing)."""
    if paged and cfg.attn_window:
        raise NotImplementedError(
            f"paged KV and attn_window are mutually exclusive "
            f"({cfg.name} has window={cfg.attn_window}); the window "
            f"plan already bounds resident rows")
    # Family dispatch: decoder-only transformers (dense / MoE) lower
    # right here; the recurrent and encoder-memory families through
    # their own modules' graph builders.  Importing the module is what
    # registers its named-state hook, so ``state_specs`` below resolves
    # for every dispatched family and raises the full blocker list for
    # the rest (vlm).
    fam = cfg.family
    if fam == "ssm":
        from . import rwkv as gmod
    elif fam == "hybrid":
        from . import zamba2 as gmod
    elif fam == "audio":
        from . import whisper as gmod
    else:
        gmod = None
        _require_dense(cfg)
    specs, caps = state_specs(cfg, slots, max_len)
    if paged and not caps.paged:
        raise NotImplementedError(
            f"{cfg.name} is blocked by: family {fam!r} state is not "
            f"pageable (paged plans assume KV-row granularity) — it "
            f"still runs the scan forward")
    pre_tuned, cost_model = _tuned_context(cfg.name, 1, hw, generation)
    dec_tuned, _ = _tuned_context(cfg.name, slots, hw, generation)
    pg = page_size if paged else None
    if gmod is None:
        pre_graph = to_graph(cfg, batch=1, seq=max_len, write_cache=True,
                             page_size=pg,
                             kv_quant=kv_quant if paged else None)
        dec_graph = to_decode_graph(cfg, slots=slots, max_len=max_len,
                                    page_size=pg,
                                    kv_quant=kv_quant if paged else None)
    else:
        pre_graph = gmod.to_graph(cfg, seq=max_len, write_cache=True)
        dec_graph = gmod.to_decode_graph(cfg, slots=slots, max_len=max_len)
    pre_graph.name = cfg.name + ".prefill"
    pre_sched = compile_model(pre_graph, hw, tuned=pre_tuned,
                              cost_model=cost_model)
    dec_sched = compile_model(dec_graph, hw, tuned=dec_tuned,
                              cost_model=cost_model)
    pre_plan = allocate_regions(pre_graph, pre_sched)
    dec_plan = allocate_regions(dec_graph, dec_sched)
    # One persistent table, one base: the minted state region ids
    # coincide across the pair (regions.py invariant), so prefill-
    # written state buffers are read by decode ops under the same ids.
    base = max(len(pre_plan.regions), len(dec_plan.regions))
    paged_plan = None
    if paged:
        specs, paged_plan = paged_kv_specs(
            n_layers=cfg.n_layers, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            slots=slots, max_len=max_len, page_size=page_size,
            n_pages=page_pool,
            kv_dtype=("int8" if kv_quant == "int8"
                      else jnp.dtype(cfg.kv_jdtype).name))
    pre_plan = extend_with_persistent(pre_plan, specs, base)
    dec_plan = extend_with_persistent(dec_plan, specs, base)
    return ProgramPair(
        prefill=lower_to_program(pre_graph, pre_sched, pre_plan),
        decode=lower_to_program(dec_graph, dec_sched, dec_plan),
        slots=slots, max_len=max_len, paged=paged_plan, caps=caps)


def compile_draft_pair(target_cfg: ArchConfig, draft_cfg: ArchConfig,
                       slots: int = 8, max_len: int = 256,
                       hw: HardwareModel = TPU_V5E) -> ProgramPair:
    """Compile the speculative-decode *draft* (prefill, decode) pair —
    ``compile_program_pair`` verbatim on the draft config, same
    (slots, max_len) geometry as the target — after validating the
    draft can propose for ``target_cfg``.

    The contract is token-level: the draft proposes ids the target
    verifies, so the vocabularies must be identical (anything else is a
    silent id-space mismatch, not an accuracy tradeoff).  Sliding
    windows are rejected on either side: accept/rollback truncates the
    per-slot length, which is only safe while every cache row below the
    truncated length is still resident — a ring that wrapped during the
    speculative burst would have overwritten history the rollback
    re-exposes."""
    if draft_cfg.vocab != target_cfg.vocab:
        raise ValueError(
            f"draft/target vocab mismatch ({draft_cfg.vocab} vs "
            f"{target_cfg.vocab}): speculative decode exchanges token "
            f"ids, the vocabularies must be identical")
    if target_cfg.attn_window or draft_cfg.attn_window:
        raise NotImplementedError(
            "speculative decode over windowed attention: rollback "
            "truncates lengths, but a wrapped ring has already "
            "overwritten the rows the truncation re-exposes")
    if target_cfg.family != "dense":
        raise NotImplementedError(
            f"speculative decode requires a speculatable target "
            f"(family state caps): {target_cfg.name} is "
            f"family={target_cfg.family}, whose state rollback is not "
            f"length-truncation")
    _require_dense(draft_cfg)
    return compile_program_pair(draft_cfg, slots=slots, max_len=max_len,
                                hw=hw)


def program_forward(params, tokens, cfg: ArchConfig, *,
                    impl: str = "auto", hw: HardwareModel = TPU_V5E,
                    interpret: bool | None = None):
    """tokens (B, S) -> logits (B, S, V) through the compiled Program.

    The serving fast path: compiles the config once (cached) and
    executes the instruction stream through runtime/executor.py — no
    per-call re-derivation of tilings or fusion.  Unlike ``forward``
    this returns the logits array directly (no aux dict; the dense
    family has none)."""
    from ..runtime.executor import jitted_runner
    program = compile_program(cfg, batch=tokens.shape[0],
                              seq=tokens.shape[1], hw=hw)
    runner = jitted_runner(program, impl=impl, interpret=interpret)
    return runner(params, tokens)


# --- decode -----------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               n_vision: int | None = None) -> dict:
    KV, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    dt = cfg.kv_jdtype
    cache_len = kv_cache_len(cfg, max_len)
    cache = {
        "k": jnp.zeros((L, batch, KV, cache_len, hd), dt),
        "v": jnp.zeros((L, batch, KV, cache_len, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.cross_attn_every:
        G = cfg.n_layers // cfg.cross_attn_every
        Tv = n_vision or cfg.n_vision_tokens
        cache["cross_k"] = jnp.zeros((G, batch, KV, Tv, hd), dt)
        cache["cross_v"] = jnp.zeros((G, batch, KV, Tv, hd), dt)
    return cache


def _write_cache(cache_k, cache_v, k_new, v_new, slot):
    """Insert (B, KV, hd) at per-sequence slot of (B, KV, S, hd)."""
    def upd(c, x, s):
        return jax.lax.dynamic_update_slice_in_dim(c, x[:, None], s, axis=1)
    k = jax.vmap(upd)(cache_k, k_new, slot)
    v = jax.vmap(upd)(cache_v, v_new, slot)
    return k, v


def _attention_decode(h1, p, cfg, ck, cv, pos, cos, sin, *, impl):
    """h1 (B, D); ck/cv (B, KV, S, hd); pos (B,).  Rolling window cache."""
    B, D = h1.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = ck.shape[2]
    q = (h1 @ p["wq"]).reshape(B, H, hd)
    k = (h1 @ p["wk"]).reshape(B, KV, hd)
    v = (h1 @ p["wv"]).reshape(B, KV, hd)
    if cos is not None:
        q = apply_rope(q, cos[:, None], sin[:, None])
        k = apply_rope(k, cos[:, None], sin[:, None])
    slot = pos % S                                   # rolling (window) cache
    ck, cv = _write_cache(ck, cv, k.astype(ck.dtype), v.astype(cv.dtype),
                          slot)
    out = decode_attention(q, ck, cv, kv_len=ring_kv_len(pos, S), impl=impl)
    return (out.reshape(B, H * hd) @ p["wo"]), ck, cv


def decode_step(params, cache, tokens, cfg: ArchConfig, *,
                impl: str = "auto"):
    """tokens (B,) -> (logits (B, V), new cache).  pos advances by 1."""
    B = tokens.shape[0]
    pos = cache["pos"]
    h = params["embed"][tokens].astype(cfg.jdtype)
    rot = Rotary(cfg.hd, cfg.rope_theta)
    cos, sin = rot.freqs(pos)                        # (B, hd/2)

    def body(carry, xs):
        p_i, ck, cv = xs
        a_in = _norm(carry, p_i, cfg, "attn_norm")
        a, ck, cv = _attention_decode(a_in, p_i, cfg, ck, cv, pos, cos, sin,
                                      impl=impl)
        carry = carry + a
        m, _ = _mlp(_norm(carry, p_i, cfg, "mlp_norm")[:, None], p_i, cfg)
        carry = carry + m[:, 0]
        return carry, (ck, cv)

    if cfg.cross_attn_every:
        per = cfg.cross_attn_every
        G = cfg.n_layers // per
        blocks = jax.tree.map(
            lambda x: x.reshape((G, per) + x.shape[1:]), params["blocks"])
        kc = cache["k"].reshape((G, per) + cache["k"].shape[1:])
        vc = cache["v"].reshape((G, per) + cache["v"].shape[1:])

        def group(carry, xs):
            cross_p, self_p, kc_g, vc_g, xk, xv = xs
            a_in = _norm(carry, cross_p, cfg, "attn_norm")
            H, hd = cfg.n_heads, cfg.hd
            q = (a_in @ cross_p["wq"]).reshape(B, H, hd)
            a = decode_attention(q, xk, xv, impl=impl)
            a = a.reshape(B, H * hd) @ cross_p["wo"]
            carry = carry + jnp.tanh(cross_p["gate"]).astype(carry.dtype) * a
            carry, ys = jax.lax.scan(body, carry, (self_p, kc_g, vc_g))
            return carry, ys

        h, (k_new, v_new) = jax.lax.scan(
            group, h, (params["cross_blocks"], blocks, kc, vc,
                       cache["cross_k"], cache["cross_v"]))
        k_new = k_new.reshape(cache["k"].shape)
        v_new = v_new.reshape(cache["v"].shape)
    elif cfg.n_experts > 0 and cfg.moe_every > 1:
        per = cfg.moe_every
        G = cfg.n_layers // per
        dense = jax.tree.map(
            lambda x: x.reshape((G, per - 1) + x.shape[1:]),
            params["blocks"])
        kc = cache["k"].reshape((G, per) + cache["k"].shape[1:])
        vc = cache["v"].reshape((G, per) + cache["v"].shape[1:])

        def group(carry, xs):
            moe_p, dense_p, kc_g, vc_g = xs
            carry, ys_d = jax.lax.scan(
                body, carry, (dense_p, kc_g[:per - 1], vc_g[:per - 1]))
            carry, ys_m = body(carry, (moe_p, kc_g[per - 1], vc_g[per - 1]))
            return carry, (ys_d, ys_m)

        h, ((kd, vd), (km, vm)) = jax.lax.scan(
            group, h, (params["moe_blocks"], dense, kc, vc))
        k_new = jnp.concatenate([kd, km[:, None]], axis=1).reshape(
            cache["k"].shape)
        v_new = jnp.concatenate([vd, vm[:, None]], axis=1).reshape(
            cache["v"].shape)
    else:
        h, (k_new, v_new) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"]))

    h = _norm(h, params, cfg, "final_norm")
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = h @ head
    new_cache = dict(cache)
    new_cache.update({"k": k_new, "v": v_new, "pos": pos + 1})
    return logits, new_cache

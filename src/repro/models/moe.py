"""Capacity-bounded top-k MoE dispatch with shard-local routing.

T4 made first-class: expert load is a load-balancing problem with the
paper's percent-imbalance metric; capacity bounds L_max exactly like the
paper's DMA chunking bounds the slowest load unit.

Distribution (§Perf H3): the data-dependent dispatch (scatter into the
(E, cap, D) buffer, gather back) runs under ``jax.shard_map`` *manual*
over the batch axes — each data shard routes only its own tokens, so
the scatter/gather are provably chip-local.  The expert matmuls keep
the "model" axis *auto*: D/F stay GSPMD-sharded inside the body
("moe_buf"/"moe_h" rules), and the only cross-shard traffic is the
(E, D, F) weight-gradient reduction inserted by shard_map's transpose —
the same all-reduce any dense layer pays.

Two earlier versions are logged in EXPERIMENTS.md §Perf H3: global
dispatch (GSPMD last-resort replication: 42.9 GB scatters) and
hierarchical-indices-under-jit (the partitioner cannot prove block
locality of dynamic indices: worse).

Returns the per-step imbalance statistic so the training loop can log
C_L and apply the auxiliary balancing loss.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.balance import moe_capacity
from ..parallel.act_sharding import _CTX, shard_act
from ..parallel.compat import shard_map
from ..kernels.common import apply_activation

__all__ = ["moe_mlp"]


def _moe_local(x, router_w, w_gate, w_up, w_down, *, top_k, cap_frac,
               activation, gated, valid_count=None, axes=(),
               model_axis=None):
    """Dispatch + expert FFN on the local token block.

    Fully manual under shard_map: w_gate/w_up arrive F-sharded and
    w_down F-sharded over ``model_axis``; the expert FFN computes its
    local F slice with one psum on the output partials."""
    T, D = x.shape
    E = router_w.shape[-1]
    # with_sharding_constraint is illegal inside a fully-manual body
    cons = shard_act if (model_axis is None and not axes) else \
        (lambda a, n: a)
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = moe_capacity(T, E, top_k, cap_frac).capacity_per_expert
    cap = min(cap, T)

    flat_e = top_e.reshape(-1)                              # (T*k,)
    eff_cap = cap
    if valid_count is not None:
        # Right-padded token block (Program prefill pins (1, max_len)):
        # pad rows route to a sentinel expert E so they never claim
        # capacity, and the effective bound is re-derived at the *true*
        # token count — the same `moe_capacity` arithmetic, traced — so
        # per-expert bucketing is identical to the un-padded legacy
        # call and parity holds bit-for-bit on the kept rows.
        mean = valid_count.astype(jnp.float32) * top_k / E
        dyn = jnp.maximum(jnp.ceil(mean * cap_frac / 8.0) * 8.0, 8.0)
        eff_cap = jnp.minimum(dyn.astype(jnp.int32),
                              valid_count.astype(jnp.int32))
        tok_valid = jnp.arange(T) < valid_count
        flat_e = jnp.where(jnp.repeat(tok_valid, top_k), flat_e, E)
    order = jnp.argsort(flat_e, stable=True)
    counts_full = jnp.bincount(flat_e, length=E + 1)
    offsets = jnp.cumsum(counts_full) - counts_full
    ranks_sorted = jnp.arange(T * top_k) - offsets[flat_e[order]]
    ranks = jnp.zeros(T * top_k, jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32))
    counts = counts_full[:E]
    keep = (ranks < eff_cap) & (flat_e < E)
    slot = jnp.where(keep, flat_e * cap + ranks, E * cap)

    x_rep = jnp.repeat(x, top_k, axis=0)                    # static pattern
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].set(x_rep)
    ebuf = cons(buf[:E * cap].reshape(E, cap, D), "moe_buf")

    h = jnp.einsum("ecd,edf->ecf", ebuf, w_gate,
                   preferred_element_type=jnp.float32)
    h = cons(h, "moe_h")
    h = apply_activation(h, activation)
    if gated:
        up = cons(jnp.einsum("ecd,edf->ecf", ebuf, w_up,
                             preferred_element_type=jnp.float32), "moe_h")
        h = h * up
    out_e = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), w_down,
                       preferred_element_type=jnp.float32)
    if model_axis is not None:
        # reduce-scatter the F-contraction partials onto D slices: half
        # the ring bytes of a psum, and the combine gather below then
        # reads a 1/model-sized buffer; the (Tl, D/model) result is
        # all-gathered at the end (§Perf H3 iter 4).
        out_e = jax.lax.psum_scatter(out_e, model_axis,
                                     scatter_dimension=2, tiled=True)
    out_e = cons(out_e, "moe_buf")

    Dl = out_e.shape[-1]
    flat_out = jnp.concatenate(
        [out_e.reshape(E * cap, Dl).astype(jnp.float32),
         jnp.zeros((1, Dl), jnp.float32)], axis=0)
    gathered = flat_out[slot] * top_p.reshape(-1)[:, None]
    out = gathered.reshape(T, top_k, Dl).sum(axis=1).astype(x.dtype)
    if model_axis is not None:
        out = jax.lax.all_gather(out, model_axis, axis=1, tiled=True)

    # T4 stats, reduced across the manual axes when present.
    g_counts = counts.astype(jnp.float32)
    frac_probs = probs.mean(axis=0)
    mean_load = jnp.maximum(g_counts.mean(), 1e-9)
    imbalance = (g_counts.max() / mean_load - 1.0) * 100.0
    frac_tokens = g_counts / jnp.maximum(g_counts.sum(), 1.0)
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    dropped = 1.0 - keep.mean()
    aux = {"lb_loss": lb_loss, "imbalance_pct": imbalance,
           "dropped_frac": dropped}
    if axes:   # 1-element leaves so shard_map out_specs can carry them
        aux = {k: v[None] for k, v in aux.items()}
    return out, aux


def moe_mlp(x: jax.Array, router_w: jax.Array, w_gate: jax.Array,
            w_up: jax.Array, w_down: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25, activation: str = "silu",
            gated: bool = True, valid_count=None):
    """x: (T, D); router_w: (D, E); w_gate/w_up: (E, D, F); w_down: (E, F, D).

    ``valid_count`` (traced scalar) marks x as right-padded: only the
    first ``valid_count`` rows are real tokens; pad rows neither claim
    expert capacity nor perturb the bucketing of real ones.

    Returns (out (T, D), aux).
    """
    rules = _CTX.get()
    mesh = rules.mesh if rules is not None else None
    fn = functools.partial(_moe_local, top_k=top_k,
                           cap_frac=capacity_factor,
                           activation=activation, gated=gated,
                           valid_count=valid_count)
    if mesh is None or valid_count is not None:
        return fn(x, router_w, w_gate, w_up, w_down)

    sizes = dict(mesh.shape)
    dp = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    S = 1
    for a in dp:
        S *= sizes[a]
    T = x.shape[0]
    F = w_gate.shape[-1]
    mdl = "model" if sizes.get("model", 1) > 1 and F % sizes["model"] == 0 \
        else None
    # The manual path pays a per-call weight transfer; for decode-sized
    # token counts (weights >> activations) the plain GSPMD path is
    # strictly cheaper (llama4 decode regressed 2.4x under shard_map —
    # §Perf H3 note).
    if not dp or T % S != 0 or T // S < max(top_k, 256):
        return fn(x, router_w, w_gate, w_up, w_down)

    # Fully manual shard_map (every mesh axis listed): the partial-auto
    # mode miscompiles on the CPU backend (all-reduce with a copy
    # combiner), and full-manual is explicit about the single psum the
    # expert FFN needs.
    axes = set(dp) | ({mdl} if mdl else set())
    body = functools.partial(fn, axes=dp, model_axis=mdl)
    f_spec = P(None, None, mdl)        # w_gate / w_up: F-sharded
    d_spec = P(None, mdl, None)        # w_down: F-sharded on dim 1
    wrapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None), P(None, None), f_spec, f_spec, d_spec),
        out_specs=(P(dp, None),
                   {"lb_loss": P(dp), "imbalance_pct": P(dp),
                    "dropped_frac": P(dp)}),
        axis_names=axes, check_vma=False)
    out, aux = wrapped(x, router_w, w_gate, w_up, w_down)
    aux = {k: jnp.mean(v) for k, v in aux.items()}
    return out, aux

"""RWKV6 WKV recurrence Pallas kernel.

The recurrence is elementwise-decay + rank-1 update — inherently
sequential in t with O(D^2) state.  The TPU-native version keeps the
(D, D) state resident in VMEM for a whole (batch, head) stream and
walks the sequence in chunks: the chunk's r/k/v/w tiles are loaded once
(T2), the time loop runs entirely out of VMEM/VREGs.  This is the
bandwidth-optimal layout — every HBM byte is touched exactly once —
which is what matters for an op with arithmetic intensity ~2 FLOP/byte.

(A chunked matmul reformulation that shifts work onto the MXU is the
§Perf extension; see EXPERIMENTS.md.)

Grid: (B*H, L/Q), sequential chunk axis, state in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params, default_interpret, vmem_scratch

__all__ = ["wkv6_pallas"]


def _body(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
          s_ref, *, Q):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                       # (D,)

    def step(t, S):
        rt = r_ref[0, t].astype(jnp.float32)               # (D,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                     # (D, D)
        y = jnp.einsum("i,ij->j", rt, S + u[:, None] * kv)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return wt[:, None] * S + kv

    S = jax.lax.fori_loop(0, Q, step, s_ref[...])
    s_ref[...] = S

    @pl.when(c == nc - 1)
    def _emit():
        sout_ref[0] = S.astype(sout_ref.dtype)


def wkv6_pallas(r, k, v, w, u, *, s0=None, chunk: int = 128,
                interpret: bool | None = None):
    """r,k,v,w: (B, L, H, D); u: (H, D).  Returns (y, final_state)."""
    if interpret is None:
        interpret = default_interpret()
    B, L, H, D = r.shape
    Q = min(chunk, L)
    assert L % Q == 0

    def fold(a):
        return jnp.moveaxis(a, 2, 1).reshape(B * H, L, D)

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    s0f = (s0.reshape(B * H, D, D) if s0 is not None
           else jnp.zeros((B * H, D, D), jnp.float32))

    grid = (B * H, L // Q)
    body = functools.partial(_body, Q=Q)
    params = compiler_params(("parallel", "arbitrary"), interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    seq_spec = pl.BlockSpec((1, Q, D), lambda bh, c: (bh, c, 0))
    y, s_fin = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, D), lambda bh, c: (bh % H, 0)),
                  pl.BlockSpec((1, D, D), lambda bh, c: (bh, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, D, D), lambda bh, c: (bh, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * H, L, D), r.dtype),
                   jax.ShapeDtypeStruct((B * H, D, D), jnp.float32)],
        scratch_shapes=[vmem_scratch((D, D), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(rf, kf, vf, wf, u, s0f)
    y = jnp.moveaxis(y.reshape(B, H, L, D), 1, 2)
    return y, s_fin.reshape(B, H, D, D)

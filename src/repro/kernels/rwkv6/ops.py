"""Public WKV6 wrapper + decode step."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import wkv6_pallas
from .ref import wkv6_chunked, wkv6_ref

__all__ = ["wkv6", "wkv6_decode_step"]


def wkv6(r, k, v, w, u, *, s0=None, return_state: bool = False,
         impl: str = "auto", chunk: int = 128,
         interpret: bool | None = None):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "sequential":
        return wkv6_ref(r, k, v, w, u, s0=s0, return_state=return_state)
    if impl == "reference":
        # block-parallel form (see §Perf H1); sequential oracle retained
        return wkv6_chunked(r, k, v, w, u, s0=s0,
                            return_state=return_state)
    L = r.shape[1]
    ch = min(chunk, L)
    while L % ch != 0:
        ch //= 2
    y, s_fin = wkv6_pallas(r, k, v, w, u, s0=s0, chunk=max(ch, 1),
                           interpret=interpret)
    if return_state:
        return y, s_fin
    return y


def wkv6_decode_step(S, r_t, k_t, v_t, w_t, u):
    """One step for serving.  S: (B, H, D, D); r/k/v/w_t: (B, H, D);
    u: (H, D).  Returns (y_t, S_new)."""
    Sf = S.astype(jnp.float32)
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r_t, k_t, v_t, w_t))
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj",
                   rf, Sf + u.astype(jnp.float32)[None, :, :, None] * kv)
    S_new = wf[..., :, None] * Sf + kv
    return y.astype(r_t.dtype), S_new

from .ops import wkv6, wkv6_decode_step
from .ref import wkv6_chunked, wkv6_ref
from .kernel import wkv6_pallas
__all__ = ["wkv6", "wkv6_decode_step", "wkv6_ref", "wkv6_chunked", "wkv6_pallas"]

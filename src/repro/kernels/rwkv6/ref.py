"""Oracle for the RWKV6 (Finch) WKV recurrence.

Per head with head dim D and state S (D_k x D_v):
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with data-dependent per-channel decay w_t in (0, 1) (the model computes
w_t = exp(-exp(w_raw_t))) and a per-channel bonus u for the current
token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv6_ref"]


def wkv6_ref(r, k, v, w, u, *, s0=None, return_state: bool = False):
    """r,k,v,w: (B, L, H, D); u: (H, D).  Returns y (B, L, H, D)
    [and final state (B, H, D, D)]."""
    B, L, H, D = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp              # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,D,D)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    S0 = (s0.astype(jnp.float32) if s0 is not None
          else jnp.zeros((B, H, D, D), jnp.float32))
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(r.dtype)
    if return_state:
        return y, S_fin
    return y


def wkv6_chunked(r, k, v, w, u, *, s0=None, return_state: bool = False,
                 chunk: int = 16):
    """Block-parallel WKV6 (model path off-TPU): L/Q chunk steps instead
    of L sequential state updates — the same T2 move as the Mamba2
    chunked form (§Perf H1).

    Within a chunk, pair weights exp(cum_{t-1} - cum_s) are factored as
    (r ∘ e^{cum_prev - m})(k ∘ e^{m - cum}) with the per-channel center
    m = cum at mid-chunk, which keeps both factors within e^{±Q/2·|log w|}
    — safe in f32 for Q <= 16 with realistic decay magnitudes.
    """
    B, L, H, D = r.shape
    Q = min(chunk, L)
    while L % Q != 0:
        Q //= 2
    nc = L // Q
    uf = u.astype(jnp.float32)

    def resh(a):
        return a.reshape(B, nc, Q, H, D)

    rr, kk, vv, ww = (resh(a) for a in (r, k, v, w))

    @jax.checkpoint
    def step(S, inp):
        rc, kc, vc, wc = (a.astype(jnp.float32) for a in inp)  # (B,Q,H,D)
        lw = jnp.log(jnp.maximum(wc, 1e-30))                   # <= 0
        cum = jnp.cumsum(lw, axis=1)                           # inclusive
        cum_prev = cum - lw                                    # exclusive
        m = cum[:, Q // 2][:, None]                            # center
        r_t = rc * jnp.exp(cum_prev - m)
        k_t = kc * jnp.exp(m - cum)
        A = jnp.einsum("bqhd,bshd->bqsh", r_t, k_t)            # (B,Q,S,H)
        t_i = jnp.arange(Q)
        mask = (t_i[:, None] > t_i[None, :])[None, :, :, None]
        diag = jnp.einsum("bqhd,bqhd->bqh", rc * uf[None, None], kc)
        y = jnp.einsum("bqsh,bshd->bqhd", jnp.where(mask, A, 0.0), vc)
        y = y + diag[..., None] * vc
        # inter-chunk: carried state read out with decayed r
        y = y + jnp.einsum("bqhi,bhij->bqhj", rc * jnp.exp(cum_prev), S)
        # state update
        total = cum[:, -1][:, None]                            # (B,1,H,D)
        k_s = kc * jnp.exp(total - cum)
        S = (S * jnp.exp(total[:, 0])[..., None]
             + jnp.einsum("bqhi,bqhj->bhij", k_s, vc))
        return S, y.astype(r.dtype)

    S0 = (s0.astype(jnp.float32) if s0 is not None
          else jnp.zeros((B, H, D, D), jnp.float32))
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rr, kk, vv, ww))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, H, D)
    if return_state:
        return y, S_fin
    return y

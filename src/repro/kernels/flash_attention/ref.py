"""Attention oracles.

* ``attention_ref``  — naive quadratic softmax attention (small tests).
* ``flash_ref``      — chunked, memory-safe flash attention in pure jnp
  with a custom VJP that recomputes per chunk (O(Sq*chunk) live bytes).
  This is the model-path implementation wherever Mosaic is unavailable
  (CPU dry-run) and the oracle for the Pallas kernel.

Layout: q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D) with Hq % Hkv == 0
(GQA handled by grouping, never by materializing repeated KV).
Supports causal masking and a causal sliding window of size W
(query i attends keys in (i-W, i]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "flash_ref"]

NEG_INF = -1e30


def _mask(sq0: int, sk0, bq: int, bk: int, causal: bool,
          window: int | None, kv_len: int | None):
    """(bq, bk) additive mask for a tile at (sq0, sk0) global offset."""
    qi = sq0 + jnp.arange(bq)[:, None]
    ki = sk0 + jnp.arange(bk)[None, :]
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    if kv_len is not None:
        ok &= ki < kv_len
    return jnp.where(ok, 0.0, NEG_INF)


def attention_ref(q, k, v, *, scale: float | None = None,
                  causal: bool = False, window: int | None = None,
                  kv_len=None):
    """Naive O(Sq*Skv) oracle."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    Skv = k.shape[2]
    m = _mask(0, 0, Sq, Skv, causal, window, kv_len)
    s = s + m[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


# --- chunked flash with custom VJP ------------------------------------------------
def _fwd_scan(q, k, v, scale, causal, window, kv_len, chunk):
    """Returns (out_unnormalized -> normalized out, lse)."""
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    n_chunks = Skv // chunk

    def step(carry, j):
        m, l, acc = carry
        sk0 = j * chunk
        kj = jax.lax.dynamic_slice_in_dim(k, sk0, chunk, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, sk0, chunk, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q, kj.astype(jnp.float32)) * scale
        s = s + _mask(0, sk0, Sq, chunk, causal, window, kv_len)[None, None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  jnp.arange(n_chunks))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 7))
def _flash(q, k, v, scale, causal, window, kv_len, chunk):
    out, _ = _flash_fwd(q, k, v, scale, causal, window, kv_len, chunk)[0], None
    return out


def _flash_fwd(q, k, v, scale, causal, window, kv_len, chunk):
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    out, lse = _fwd_scan(qg, k, v, scale, causal, window, kv_len, chunk)
    o = out.reshape(B, Hq, Sq, D).astype(q.dtype)
    return o, (q, k, v, o, lse, kv_len)


def _flash_bwd(scale, causal, window, chunk, res, do):
    q, k, v, o, lse, kv_len = res
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    Skv = k.shape[2]
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    dog = do.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    og = o.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    delta = jnp.sum(dog * og, axis=-1)                      # (B,Hkv,G,Sq)
    n_chunks = Skv // chunk

    def step(carry, j):
        dq, dk, dv = carry
        sk0 = j * chunk
        kj = jax.lax.dynamic_slice_in_dim(kf, sk0, chunk, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(vf, sk0, chunk, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj) * scale
        s = s + _mask(0, sk0, Sq, chunk, causal, window, kv_len)[None, None, None]
        p = jnp.exp(s - lse[..., None])                     # (B,Hkv,G,Sq,c)
        dvj = jnp.einsum("bhgqk,bhgqd->bhkd", p, dog)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dog, vj)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kj)
        dkj = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, dkj.astype(dk.dtype), sk0, axis=2)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, dvj.astype(dv.dtype), sk0, axis=2)
        return (dq, dk, dv), None

    dq0 = jnp.zeros_like(qg)
    dk0 = jnp.zeros((B, Hkv, Skv, D), jnp.float32)
    dv0 = jnp.zeros((B, Hkv, Skv, D), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0),
                                   jnp.arange(n_chunks))
    return (dq.reshape(B, Hq, Sq, D).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype), None)


_flash.defvjp(lambda q, k, v, scale, causal, window, kv_len, chunk:
              _flash_fwd(q, k, v, scale, causal, window, kv_len, chunk),
              _flash_bwd)


def flash_ref(q, k, v, *, scale: float | None = None, causal: bool = False,
              window: int | None = None, kv_len=None,
              chunk: int = 512) -> jax.Array:
    """Memory-safe chunked flash attention (pure jnp, differentiable)."""
    D = q.shape[-1]
    Skv = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    chunk = min(chunk, Skv)
    if Skv % chunk != 0:
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_len = kv_len if kv_len is not None else Skv
    return _flash(q, k, v, scale, causal, window, kv_len, chunk)

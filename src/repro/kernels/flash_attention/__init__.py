from .ops import flash_attention, attention_block_sizes
from .ref import attention_ref, flash_ref
from .kernel import flash_attention_pallas

__all__ = ["flash_attention", "attention_block_sizes", "attention_ref",
           "flash_ref", "flash_attention_pallas"]

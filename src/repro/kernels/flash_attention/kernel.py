"""Flash-attention forward Pallas kernel (GQA, causal, sliding window).

Grid: (B * Hq, Sq/bq, Skv/bkv) — kv innermost, running softmax state in
VMEM scratch carried across kv steps (TPU grid iterates sequentially, so
scratch persists).  The KV BlockSpec index map folds the GQA head
mapping (q head -> kv head = h // group), so repeated KV heads are never
materialized — the bandwidth saving the schedule compiler counts on.

Block sizes come from core/tiling.py via ops.py; the working set is
q(bq,D) + k(bkv,D) + v(bkv,D) (double-buffered) + acc(bq,D) f32.
Fully-masked kv blocks are skipped with pl.when (compute skip; the
prefetch still streams them — the grid-restriction optimization is
recorded as future work in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params, default_interpret, vmem_scratch

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _body(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
          scale, causal, window, bq, bkv, kv_len):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sq0 = qb * bq
    sk0 = kb * bkv

    # Full-block skip test (static per (qb, kb) only through program ids).
    run = jnp.bool_(True)
    if causal:
        run &= sk0 <= sq0 + bq - 1
    if window is not None:
        run &= sk0 + bkv - 1 > sq0 - window

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bkv, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = sq0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        ki = sk0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        ok = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            ok &= ki <= qi
        if window is not None:
            ok &= ki > qi - window
        if kv_len is not None:
            ok &= ki < kv_len
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]                              # (bq, 128)
        m_cur = jnp.max(s, axis=-1, keepdims=True)       # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])    # (bq, 1)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            p.sum(axis=-1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == nkv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[:, 0] + jnp.log(l[:, 0])).astype(jnp.float32)


def flash_attention_pallas(q, k, v, *, scale: float, causal: bool,
                           window: int | None, kv_len: int | None,
                           block_q: int = 512, block_kv: int = 512,
                           interpret: bool | None = None,
                           return_lse: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Sq % block_q == 0 and
    Skv % block_kv == 0 (ops.py pads).  ``return_lse`` additionally
    returns the per-row logsumexp (B, Hq, Sq) for the backward pass."""
    if interpret is None:
        interpret = default_interpret()
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)
    grid = (B * Hq, Sq // bq, Skv // bkv)

    def kv_head(h, qb, kb):
        return ((h // Hq) * Hkv + (h % Hq) // group, kb, 0)

    q_spec = pl.BlockSpec((1, bq, D), lambda h, qb, kb: (h, qb, 0))
    k_spec = pl.BlockSpec((1, bkv, D), kv_head)
    v_spec = pl.BlockSpec((1, bkv, D), kv_head)
    o_spec = pl.BlockSpec((1, bq, D), lambda h, qb, kb: (h, qb, 0))
    lse_spec = pl.BlockSpec((1, bq), lambda h, qb, kb: (h, qb))

    body = functools.partial(_body, scale=scale, causal=causal,
                             window=window, bq=bq, bkv=bkv, kv_len=kv_len)
    params = compiler_params(("parallel", "arbitrary", "arbitrary"),
                             interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    out, lse = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[q_spec, k_spec, v_spec],
        out_specs=[o_spec, lse_spec],
        out_shape=[jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
                   jax.ShapeDtypeStruct((B * Hq, Sq), jnp.float32)],
        scratch_shapes=[vmem_scratch((bq, 128), jnp.float32),
                        vmem_scratch((bq, 128), jnp.float32),
                        vmem_scratch((bq, D), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf)
    out = out.reshape(B, Hq, Sq, D)
    if return_lse:
        return out, lse.reshape(B, Hq, Sq)
    return out

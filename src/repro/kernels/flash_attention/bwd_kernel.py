"""Flash-attention backward Pallas kernels (two-pass).

* ``_dkv``: grid (B*Hkv, Skv/bkv, G*Sq/bq) — for each kv block,
  accumulate dK/dV in VMEM scratch while streaming every (group, q
  block) of its GQA group; the group sum falls out of the sequential
  inner axis.
* ``_dq``:  grid (B*Hq, Sq/bq, Skv/bkv) — accumulate dQ per q block
  while streaming kv blocks (KV indexed through the GQA head map, as in
  the forward kernel).

Both recompute p = exp(q k^T * scale - lse) from the forward's saved
logsumexp; ``delta = rowsum(dO * O)`` is precomputed in ops.py.
Masking (causal / window / kv_len) matches the forward kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params, default_interpret, vmem_scratch

__all__ = ["flash_attention_bwd_pallas"]

NEG_INF = -1e30


def _mask(s, sq0, sk0, bq, bkv, causal, window, kv_len):
    qi = sq0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    ki = sk0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    if kv_len is not None:
        ok &= ki < kv_len
    return jnp.where(ok, s, NEG_INF)


def _dkv_body(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
              dk_ref, dv_ref, dka_ref, dva_ref, *,
              scale, causal, window, kv_len, bq, bkv, nq):
    kb = pl.program_id(1)
    inner = pl.program_id(2)
    n_inner = pl.num_programs(2)
    qb = inner % nq

    @pl.when(inner == 0)
    def _init():
        dka_ref[...] = jnp.zeros_like(dka_ref)
        dva_ref[...] = jnp.zeros_like(dva_ref)

    sq0 = qb * bq
    sk0 = kb * bkv
    run = jnp.bool_(True)
    if causal:
        run &= sk0 <= sq0 + bq - 1
    if window is not None:
        run &= sk0 + bkv - 1 > sq0 - window

    @pl.when(run)
    def _acc():
        q = q_ref[0].astype(jnp.float32)                 # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bkv, D)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)               # (bq, D)
        lse = lse_ref[0].astype(jnp.float32)             # (bq,)
        delta = dl_ref[0].astype(jnp.float32)            # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = _mask(s, sq0, sk0, bq, bkv, causal, window, kv_len)
        p = jnp.exp(s - lse[:, None])                    # (bq, bkv)
        dva_ref[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # p^T dO
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dka_ref[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # ds^T q

    @pl.when(inner == n_inner - 1)
    def _emit():
        dk_ref[0] = dka_ref[...].astype(dk_ref.dtype)
        dv_ref[0] = dva_ref[...].astype(dv_ref.dtype)


def _dq_body(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref,
             dqa_ref, *, scale, causal, window, kv_len, bq, bkv):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        dqa_ref[...] = jnp.zeros_like(dqa_ref)

    sq0 = qb * bq
    sk0 = kb * bkv
    run = jnp.bool_(True)
    if causal:
        run &= sk0 <= sq0 + bq - 1
    if window is not None:
        run &= sk0 + bkv - 1 > sq0 - window

    @pl.when(run)
    def _acc():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0].astype(jnp.float32)
        delta = dl_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = _mask(s, sq0, sk0, bq, bkv, causal, window, kv_len)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dqa_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kb == nkv - 1)
    def _emit():
        dq_ref[0] = dqa_ref[...].astype(dq_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, out, lse, do, *, scale: float,
                               causal: bool, window: int | None,
                               kv_len: int | None, block_q: int = 512,
                               block_kv: int = 512,
                               interpret: bool | None = None):
    """Returns (dq, dk, dv).  Shapes as the forward kernel; Sq/Skv must
    be multiples of the block sizes (ops.py pads)."""
    if interpret is None:
        interpret = default_interpret()
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    nq = Sq // bq

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)
    dof = do.reshape(B * Hq, Sq, D)
    lsef = lse.reshape(B * Hq, Sq)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).reshape(B * Hq, Sq)

    params = compiler_params(("parallel", "arbitrary", "arbitrary"),
                             interpret)
    kwargs = {"compiler_params": params} if params is not None else {}

    # -- dk / dv: per kv head, inner axis sweeps (group, q block) -------------
    def qhead(h, kb, inner):
        return ((h // Hkv) * Hq + (h % Hkv) * G + inner // nq,
                inner % nq, 0)

    def qhead2(h, kb, inner):
        hq, qb, _ = qhead(h, kb, inner)
        return (hq, qb)

    body = functools.partial(_dkv_body, scale=scale, causal=causal,
                             window=window, kv_len=kv_len, bq=bq,
                             bkv=bkv, nq=nq)
    dk, dv = pl.pallas_call(
        body,
        grid=(B * Hkv, Skv // bkv, G * nq),
        in_specs=[
            pl.BlockSpec((1, bq, D), qhead),
            pl.BlockSpec((1, bkv, D), lambda h, kb, i: (h, kb, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, kb, i: (h, kb, 0)),
            pl.BlockSpec((1, bq, D), qhead),
            pl.BlockSpec((1, bq), qhead2),
            pl.BlockSpec((1, bq), qhead2),
        ],
        out_specs=[pl.BlockSpec((1, bkv, D), lambda h, kb, i: (h, kb, 0)),
                   pl.BlockSpec((1, bkv, D), lambda h, kb, i: (h, kb, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * Hkv, Skv, D), k.dtype),
                   jax.ShapeDtypeStruct((B * Hkv, Skv, D), v.dtype)],
        scratch_shapes=[vmem_scratch((bkv, D), jnp.float32),
                        vmem_scratch((bkv, D), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf, dof, lsef, delta)

    # -- dq: per q head, kv innermost ------------------------------------------
    def kvmap(h, qb, kb):
        return ((h // Hq) * Hkv + (h % Hq) // G, kb, 0)

    body = functools.partial(_dq_body, scale=scale, causal=causal,
                             window=window, kv_len=kv_len, bq=bq, bkv=bkv)
    dq = pl.pallas_call(
        body,
        grid=(B * Hq, nq, Skv // bkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qb, kb: (h, qb, 0)),
            pl.BlockSpec((1, bkv, D), kvmap),
            pl.BlockSpec((1, bkv, D), kvmap),
            pl.BlockSpec((1, bq, D), lambda h, qb, kb: (h, qb, 0)),
            pl.BlockSpec((1, bq), lambda h, qb, kb: (h, qb)),
            pl.BlockSpec((1, bq), lambda h, qb, kb: (h, qb)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qb, kb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[vmem_scratch((bq, D), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf, dof, lsef, delta)

    return (dq.reshape(B, Hq, Sq, D), dk.reshape(B, Hkv, Skv, D),
            dv.reshape(B, Hkv, Skv, D))

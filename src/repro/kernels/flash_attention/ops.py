"""Public flash-attention wrapper: schedule-driven blocks, padding,
pallas/reference dispatch, and two differentiable paths:

* ``impl="pallas"``           — Pallas forward (serving path);
* ``impl="pallas_trainable"`` — Pallas forward AND backward (the dq /
  dkv kernels in bwd_kernel.py) under a custom VJP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import default_interpret
from ...core.hw import TPU_V5E, HardwareModel
from .bwd_kernel import flash_attention_bwd_pallas
from .kernel import flash_attention_pallas
from .ref import flash_ref

__all__ = ["flash_attention", "attention_block_sizes"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_trainable(q, k, v, scale, causal, window, kv_len, block_q,
                     block_kv, interpret):
    out, _ = flash_attention_pallas(
        q, k, v, scale=scale, causal=causal, window=window, kv_len=kv_len,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
        return_lse=True)
    return out


def _ft_fwd(q, k, v, scale, causal, window, kv_len, block_q, block_kv,
            interpret):
    out, lse = flash_attention_pallas(
        q, k, v, scale=scale, causal=causal, window=window, kv_len=kv_len,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
        return_lse=True)
    return out, (q, k, v, out, lse)


def _ft_bwd(scale, causal, window, kv_len, block_q, block_kv, interpret,
            res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, out, lse, do, scale=scale, causal=causal, window=window,
        kv_len=kv_len, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
    return dq, dk, dv


_flash_trainable.defvjp(_ft_fwd, _ft_bwd)


def attention_block_sizes(Sq: int, Skv: int, D: int, dtype_bytes: int,
                          hw: HardwareModel = TPU_V5E, *,
                          window: int | None = None) -> tuple[int, int]:
    """Pick (block_q, block_kv) so the working set fits the VMEM budget
    (T2 applied to attention).  The decision lives in the compiler
    (core/tiling.py::select_attention_blocks) — one chooser shared by
    this wrapper and the LM Program lowering.  A sliding ``window``
    caps the kv tile (no tile outgrows the span a query can attend)."""
    from ...core.tiling import select_attention_blocks
    return select_attention_blocks(Sq, Skv, D, dtype_bytes, hw,
                                   window=window)


def flash_attention(q, k, v, *, scale: float | None = None,
                    causal: bool = False, window: int | None = None,
                    kv_len=None, impl: str = "auto",
                    block_q: int | None = None, block_kv: int | None = None,
                    hw: HardwareModel = TPU_V5E,
                    interpret: bool | None = None) -> jax.Array:
    """Softmax attention, q (B,Hq,Sq,D), kv (B,Hkv,Skv,D).

    impl:
      "reference" — chunked jnp flash (memory-safe, differentiable);
      "pallas"    — Pallas forward; gradients via the reference VJP
                    (forward-only use is the serving path);
      "auto"      — pallas on TPU else reference.
    """
    if impl == "auto":
        # trainable = fwd + bwd Pallas kernels; fwd is identical, so this
        # is safe for inference too
        impl = ("pallas_trainable" if jax.default_backend() == "tpu"
                else "reference")
    D = q.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    if impl == "reference":
        return flash_ref(q, k, v, scale=scale, causal=causal, window=window,
                         kv_len=kv_len)

    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    if block_q is None or block_kv is None:
        bq, bkv = attention_block_sizes(Sq, Skv, D, q.dtype.itemsize, hw,
                                        window=window)
        block_q = block_q or bq
        block_kv = block_kv or bkv
    block_q = min(block_q, Sq) if Sq % min(block_q, Sq) == 0 else 128
    # Pad sequences to block multiples; padded keys are masked via kv_len.
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    if pad_kv and kv_len is None:
        kv_len = Skv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0))) if pad_kv else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0))) if pad_kv else v
    if impl == "pallas_trainable":
        out = _flash_trainable(qp, kp, vp, scale, causal, window, kv_len,
                               block_q, block_kv, interpret)
    else:
        out = flash_attention_pallas(qp, kp, vp, scale=scale, causal=causal,
                                     window=window, kv_len=kv_len,
                                     block_q=block_q, block_kv=block_kv,
                                     interpret=interpret)
    return out[:, :, :Sq] if pad_q else out

"""Oracle for 2D convolution with fused epilogue (NHWC / HWIO)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import apply_activation

__all__ = ["conv2d_ref", "maxpool2d_ref", "avgpool2d_ref"]


def conv2d_ref(x, w, *, stride: int = 1, pad: int = 0,
               bias=None, activation: str | None = None,
               bypass=None, bypass_first: bool = False,
               out_dtype=None) -> jax.Array:
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout)."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if bypass is not None and bypass_first:
        out = out + bypass.astype(jnp.float32)
    out = apply_activation(out, activation)
    if bypass is not None and not bypass_first:
        out = out + bypass.astype(jnp.float32)
    return out.astype(out_dtype or x.dtype)


def maxpool2d_ref(x, *, window: int, stride: int, pad: int = 0) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype.type(-(2**15)),
        jax.lax.max, (1, window, window, 1), (1, stride, stride, 1),
        ((0, 0), (pad, pad), (pad, pad), (0, 0)))


def avgpool2d_ref(x, *, window: int, stride: int, pad: int = 0) -> jax.Array:
    s = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add,
        (1, window, window, 1), (1, stride, stride, 1),
        ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    return (s / (window * window)).astype(x.dtype)

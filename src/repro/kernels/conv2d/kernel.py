"""Row-strip implicit-GEMM conv2d Pallas kernel — the paper's own
workload, scheduled the paper's way.

Maps are tiled at *output-row-strip* granularity (T2): ops.py
materializes halo-augmented input strips in HBM (the paper stores
overlapped regions in DRAM for single-DMA loads), and the kernel
consumes one (in_rows, W, Cin) strip per grid row.  Kernels (weights)
are tiled at whole-kernel granularity, ``kpt`` output channels per tile.

The Mloop/Kloop choice (T3) is the grid order:
  * MAPS_RESIDENT  (Kloop): grid (strip, ktile) — the strip block index
    ignores ktile, so the strip stays resident while kernel tiles stream.
  * WEIGHTS_RESIDENT (Mloop): grid (ktile, strip) — the weight tile
    stays resident while strips stream.

The conv itself is implicit GEMM: for each (dy, dx) tap, a strided
patch of the strip is contracted with w[dy, dx] on the MXU and
accumulated in f32.  Epilogue fuses bias + ReLU + residual bypass (the
paper's VMOV-on-writeback for ResNet).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import apply_activation, compiler_params, default_interpret
from ...core.dataflow import Dataflow

__all__ = ["conv2d_strips_pallas"]


def _body(x_ref, w_ref, *rest, out_rows, OW, stride, kh, kw,
          activation, out_dtype, has_bias, has_bypass,
          bypass_first=False):
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    byp_ref = refs.pop(0) if has_bypass else None
    o_ref = refs.pop(0)

    x = x_ref[0]                                   # (in_rows, Wp, Cin)
    Cin = x.shape[-1]
    kpt = o_ref.shape[-1]
    acc = jnp.zeros((out_rows * OW, kpt), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            patch = jax.lax.slice(
                x, (dy, dx, 0),
                (dy + (out_rows - 1) * stride + 1,
                 dx + (OW - 1) * stride + 1, Cin),
                (stride, stride, 1))               # (out_rows, OW, Cin)
            acc += jax.lax.dot_general(
                patch.reshape(out_rows * OW, Cin).astype(jnp.float32),
                w_ref[dy, dx].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    acc = acc.reshape(out_rows, OW, kpt)
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(jnp.float32)
    if byp_ref is not None and bypass_first:   # ResNet: add, then ReLU
        acc = acc + byp_ref[0].astype(jnp.float32)
    acc = apply_activation(acc, activation)
    if byp_ref is not None and not bypass_first:
        acc = acc + byp_ref[0].astype(jnp.float32)
    o_ref[0] = acc.astype(out_dtype)


def conv2d_strips_pallas(strips, w, *, out_rows: int, OW: int, stride: int,
                         kpt: int, bias=None, activation: str | None = None,
                         bypass=None, bypass_first: bool = False,
                         out_dtype=None,
                         dataflow: Dataflow = Dataflow.MAPS_RESIDENT,
                         interpret: bool | None = None) -> jax.Array:
    """strips: (NS, in_rows, Wp, Cin) halo-augmented row strips;
    w: (kh, kw, Cin, Cout); bypass: (NS, out_rows, OW, Cout) or None.
    Returns (NS, out_rows, OW, Cout)."""
    if interpret is None:
        interpret = default_interpret()
    NS, in_rows, Wp, Cin = strips.shape
    kh, kw, _, Cout = w.shape
    assert Cout % kpt == 0, (Cout, kpt)
    NK = Cout // kpt
    out_dtype = out_dtype or strips.dtype
    has_bias = bias is not None
    has_bypass = bypass is not None

    if dataflow is Dataflow.WEIGHTS_RESIDENT:
        grid = (NK, NS)                      # weight tile resident (Mloop)
        s_idx = lambda kt, st: (st, 0, 0, 0)
        w_idx = lambda kt, st: (0, 0, 0, kt)
        o_idx = lambda kt, st: (st, 0, 0, kt)
        b_idx = lambda kt, st: (0, kt)
    else:                                    # maps resident (Kloop)
        grid = (NS, NK)
        s_idx = lambda st, kt: (st, 0, 0, 0)
        w_idx = lambda st, kt: (0, 0, 0, kt)
        o_idx = lambda st, kt: (st, 0, 0, kt)
        b_idx = lambda st, kt: (0, kt)

    in_specs = [
        pl.BlockSpec((1, in_rows, Wp, Cin), s_idx),
        pl.BlockSpec((kh, kw, Cin, kpt), w_idx),
    ]
    operands = [strips, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, kpt), b_idx))
        operands.append(bias.reshape(1, Cout))
    if has_bypass:
        in_specs.append(pl.BlockSpec((1, out_rows, OW, kpt), o_idx))
        operands.append(bypass)

    body = functools.partial(
        _body, out_rows=out_rows, OW=OW, stride=stride, kh=kh, kw=kw,
        activation=activation, out_dtype=out_dtype, has_bias=has_bias,
        has_bypass=has_bypass, bypass_first=bypass_first)
    params = compiler_params(("arbitrary", "arbitrary"), interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, out_rows, OW, kpt), o_idx),
        out_shape=jax.ShapeDtypeStruct((NS, out_rows, OW, Cout), out_dtype),
        interpret=interpret,
        **kwargs,
    )(*operands)

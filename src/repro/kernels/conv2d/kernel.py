"""Row-strip implicit-GEMM conv2d Pallas kernels — the paper's own
workload, scheduled the paper's way, with the overlap-storage decision
(duplicate vs re-fetch) lifted to a compiler choice.

Maps are tiled at *output-row-strip* granularity (T2).  Two kernels
realize the same schedule with different halo storage:

* ``conv2d_virtual_pallas`` — **zero-copy (default)**: the kernel
  receives the whole padded per-image maps as one VMEM-resident block
  (grid-blocked only on batch / output channels) and gathers each
  output-row strip *inside* the kernel body with a dynamic slice keyed
  off the strip program id.  Strip row offsets are affine
  (``s * out_rows * stride``); when a caller needs non-affine offsets
  (ragged strip tables) it passes ``row_starts`` and the offsets are
  scalar-prefetched via ``PrefetchScalarGridSpec`` so the DMA address
  is known before the body runs.  No halo byte is ever duplicated in
  HBM.  An optional fused maxpool epilogue (``pool=(window, stride,
  pad)``) pools the conv output before writeback — the strip computes
  the few extra conv rows each overlapping pool window needs, trading
  a sliver of recompute for the pool layer's entire HBM round trip.

* ``conv2d_strips_pallas`` — the paper-faithful baseline: ops.py
  materializes halo-augmented input strips in HBM (Snowflake stores
  overlapped regions in DRAM because its DMA engine needs contiguous
  single-burst loads) and the kernel consumes one ``(in_rows, W, Cin)``
  strip per grid row.  Kept for the strip-storage benchmark and for
  hardware whose DMA truly requires contiguous strips.

Kernels (weights) are tiled at whole-kernel granularity, ``kpt`` output
channels per tile.  The Mloop/Kloop choice (T3) is the grid order:

* MAPS_RESIDENT  (Kloop): strip/batch block index ignores the kernel
  tile, so the maps block stays resident while kernel tiles stream.
* WEIGHTS_RESIDENT (Mloop): the weight tile stays resident while
  strips stream.

Every grid dimension writes a disjoint output block and carries no
cross-iteration state, so all dimensions are declared ``"parallel"``
in ``compiler_params`` — Mosaic is free to double-buffer and reorder.

The conv itself is implicit GEMM: for each (dy, dx) tap, a strided
patch of the strip is contracted with w[dy, dx] on the MXU and
accumulated in f32.  Epilogue fuses bias + activation + residual
bypass (the paper's VMOV-on-writeback for ResNet), then the optional
maxpool.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import apply_activation, compiler_params, default_interpret, pltpu
from ...core.dataflow import Dataflow
from ...core.ir import pool_out

__all__ = ["conv2d_strips_pallas", "conv2d_virtual_pallas"]


def _implicit_gemm(x, w_ref, rows, OW, stride, kh, kw, kpt):
    """Accumulate the (dy, dx) taps of an implicit GEMM in f32.

    x: (in_rows, Wp, Cin) input window; returns (rows, OW, kpt)."""
    Cin = x.shape[-1]
    acc = jnp.zeros((rows * OW, kpt), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            patch = jax.lax.slice(
                x, (dy, dx, 0),
                (dy + (rows - 1) * stride + 1,
                 dx + (OW - 1) * stride + 1, Cin),
                (stride, stride, 1))               # (rows, OW, Cin)
            acc += jax.lax.dot_general(
                patch.reshape(rows * OW, Cin).astype(jnp.float32),
                w_ref[dy, dx].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    return acc.reshape(rows, OW, kpt)


def _epilogue(acc, bias_ref, byp, activation, bypass_first):
    """Bias + activation + residual bypass, fused on writeback."""
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(jnp.float32)
    if byp is not None and bypass_first:       # ResNet: add, then ReLU
        acc = acc + byp.astype(jnp.float32)
    acc = apply_activation(acc, activation)
    if byp is not None and not bypass_first:
        acc = acc + byp.astype(jnp.float32)
    return acc


# --- materialized strips (paper-faithful baseline) ---------------------------------
def _body(x_ref, w_ref, *rest, out_rows, OW, stride, kh, kw,
          activation, out_dtype, has_bias, has_bypass,
          bypass_first=False):
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    byp_ref = refs.pop(0) if has_bypass else None
    o_ref = refs.pop(0)

    acc = _implicit_gemm(x_ref[0], w_ref, out_rows, OW, stride, kh, kw,
                         o_ref.shape[-1])
    byp = byp_ref[0] if byp_ref is not None else None
    acc = _epilogue(acc, bias_ref, byp, activation, bypass_first)
    o_ref[0] = acc.astype(out_dtype)


def conv2d_strips_pallas(strips, w, *, out_rows: int, OW: int, stride: int,
                         kpt: int, bias=None, activation: str | None = None,
                         bypass=None, bypass_first: bool = False,
                         out_dtype=None,
                         dataflow: Dataflow = Dataflow.MAPS_RESIDENT,
                         interpret: bool | None = None) -> jax.Array:
    """strips: (NS, in_rows, Wp, Cin) halo-augmented row strips already
    materialized in HBM; w: (kh, kw, Cin, Cout); bypass:
    (NS, out_rows, OW, Cout) or None.  Returns (NS, out_rows, OW, Cout)."""
    if interpret is None:
        interpret = default_interpret()
    NS, in_rows, Wp, Cin = strips.shape
    kh, kw, _, Cout = w.shape
    assert Cout % kpt == 0, (Cout, kpt)
    NK = Cout // kpt
    out_dtype = out_dtype or strips.dtype
    has_bias = bias is not None
    has_bypass = bypass is not None

    if dataflow is Dataflow.WEIGHTS_RESIDENT:
        grid = (NK, NS)                      # weight tile resident (Mloop)
        s_idx = lambda kt, st: (st, 0, 0, 0)
        w_idx = lambda kt, st: (0, 0, 0, kt)
        o_idx = lambda kt, st: (st, 0, 0, kt)
        b_idx = lambda kt, st: (0, kt)
    else:                                    # maps resident (Kloop)
        grid = (NS, NK)
        s_idx = lambda st, kt: (st, 0, 0, 0)
        w_idx = lambda st, kt: (0, 0, 0, kt)
        o_idx = lambda st, kt: (st, 0, 0, kt)
        b_idx = lambda st, kt: (0, kt)

    in_specs = [
        pl.BlockSpec((1, in_rows, Wp, Cin), s_idx),
        pl.BlockSpec((kh, kw, Cin, kpt), w_idx),
    ]
    operands = [strips, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, kpt), b_idx))
        operands.append(bias.reshape(1, Cout))
    if has_bypass:
        in_specs.append(pl.BlockSpec((1, out_rows, OW, kpt), o_idx))
        operands.append(bypass)

    body = functools.partial(
        _body, out_rows=out_rows, OW=OW, stride=stride, kh=kh, kw=kw,
        activation=activation, out_dtype=out_dtype, has_bias=has_bias,
        has_bypass=has_bypass, bypass_first=bypass_first)
    # Output tiles are disjoint across both grid dims: parallel semantics
    # let Mosaic double-buffer the streamed operand.
    params = compiler_params(("parallel", "parallel"), interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, out_rows, OW, kpt), o_idx),
        out_shape=jax.ShapeDtypeStruct((NS, out_rows, OW, Cout), out_dtype),
        interpret=interpret,
        **kwargs,
    )(*operands)


# --- virtual strips (zero-copy) ----------------------------------------------------
def _virtual_body(*refs, n_prefetch, strip_axis, out_rows, OH, OW, stride,
                  kh, kw, rows_c, pool, OWo, activation, out_dtype,
                  has_bias, has_bypass, bypass_first):
    refs = list(refs)
    rs_ref = refs.pop(0) if n_prefetch else None
    x_ref = refs.pop(0)
    w_ref = refs.pop(0)
    bias_ref = refs.pop(0) if has_bias else None
    byp_ref = refs.pop(0) if has_bypass else None
    o_ref = refs.pop(0)

    s = pl.program_id(strip_axis)
    in_rows = (rows_c - 1) * stride + kh
    if rs_ref is not None:                     # scalar-prefetched offsets
        r0 = rs_ref[s]
    else:                                      # affine: s * out_rows * stride
        r0 = pl.multiple_of(s * (out_rows * stride), stride)
    # The zero-copy gather: slice this strip's input window out of the
    # VMEM-resident padded maps — no HBM duplication ever existed.
    x = x_ref[0, pl.ds(r0, in_rows), :, :]     # (in_rows, Wp, Cin)

    kpt = o_ref.shape[-1]
    acc = _implicit_gemm(x, w_ref, rows_c, OW, stride, kh, kw, kpt)
    byp = byp_ref[0] if byp_ref is not None else None
    acc = _epilogue(acc, bias_ref, byp, activation, bypass_first)

    if pool is None:
        o_ref[0] = acc.astype(out_dtype)
        return

    # Fused pool epilogue.  This strip owns pool rows
    # [s*SR, (s+1)*SR); pool row p needs conv rows [p*ps - pp,
    # p*ps - pp + pw), so local conv row l is global row
    # s*out_rows - pp + l.  Rows outside [0, OH) are the pool's
    # padding (or bottom fill) — mask them with the op's identity
    # before reducing: -inf for max, 0 for avg (avgpool2d_ref divides
    # by the fixed window^2, counting pad as zeros, so a zero identity
    # reproduces it exactly).
    pw, ps, pp, pop = pool
    SR = out_rows // ps
    ident = jnp.float32(0.0 if pop == "avg" else -jnp.inf)
    gr = (s * out_rows - pp
          + jax.lax.broadcasted_iota(jnp.int32, (rows_c, 1, 1), 0))
    acc = jnp.where((gr >= 0) & (gr < OH), acc, ident)
    wpad_r = max(0, (OWo - 1) * ps + pw - OW - pp)
    if pp or wpad_r:
        acc = jnp.pad(acc, ((0, 0), (pp, wpad_r), (0, 0)),
                      constant_values=ident)
    pooled = None
    for py in range(pw):
        for px in range(pw):
            tap = jax.lax.slice(
                acc, (py, px, 0),
                (py + (SR - 1) * ps + 1, px + (OWo - 1) * ps + 1, kpt),
                (ps, ps, 1))
            if pooled is None:
                pooled = tap
            elif pop == "avg":
                pooled = pooled + tap
            else:
                pooled = jnp.maximum(pooled, tap)
    if pop == "avg":
        pooled = pooled / jnp.float32(pw * pw)
    o_ref[0] = pooled.astype(out_dtype)


def conv2d_virtual_pallas(xp, w, *, out_rows: int, OH: int, OW: int,
                          stride: int, kpt: int, n_strips: int, bias=None,
                          activation: str | None = None, bypass=None,
                          bypass_first: bool = False, out_dtype=None,
                          dataflow: Dataflow = Dataflow.MAPS_RESIDENT,
                          pool: tuple[int, int, int] | None = None,
                          row_starts=None,
                          interpret: bool | None = None) -> jax.Array:
    """Zero-copy row-strip conv: xp is the whole padded maps
    (B, Hp, Wp, Cin) — no strip duplication; strips are gathered
    in-kernel.  bypass: (B, n_strips*out_rows, OW, Cout) or None (not
    combinable with ``pool``).  pool: (window, stride, pad, op) max or
    avg pool fused after the epilogue.  row_starts: optional (n_strips,) int32
    per-strip *input* row offsets, scalar-prefetched so the gather
    address is known before the body runs — for input-side offset
    tables an affine ``s * out_rows * stride`` cannot express (e.g.
    irregular row subsampling).  Output strips stay uniform: strip s
    always writes output rows [s*SR, (s+1)*SR), and the pool row mask
    is likewise derived from s, so a custom table must keep that
    output mapping valid.  Returns (B, n_strips*SR, OWo, Cout) where
    (SR, OWo) are the per-strip output rows / width after the
    optional pool."""
    if interpret is None:
        interpret = default_interpret()
    B, Hp, Wp, Cin = xp.shape
    kh, kw, _, Cout = w.shape
    assert Cout % kpt == 0, (Cout, kpt)
    NK = Cout // kpt
    NS = n_strips
    out_dtype = out_dtype or xp.dtype
    has_bias = bias is not None
    has_bypass = bypass is not None

    if pool is None:
        rows_c, SR, OWo = out_rows, out_rows, OW
    else:
        pw, ps, pp, _ = pool
        assert not has_bypass, "fused pool is not combinable with bypass"
        assert out_rows % ps == 0, (out_rows, ps)
        rows_c = out_rows + pw - ps            # extra rows: overlapping windows
        SR = out_rows // ps
        OWo = pool_out(OW, pw, ps, pp)
    in_rows = (rows_c - 1) * stride + kh
    assert (NS - 1) * out_rows * stride + in_rows <= Hp, \
        "padded maps too short for the strip table"

    if dataflow is Dataflow.WEIGHTS_RESIDENT:
        grid = (NK, B, NS)                   # weight tile resident (Mloop)
        strip_axis = 2
        x_idx = lambda kt, b, st: (b, 0, 0, 0)
        w_idx = lambda kt, b, st: (0, 0, 0, kt)
        o_idx = lambda kt, b, st: (b, st, 0, kt)
        b_idx = lambda kt, b, st: (0, kt)
    else:                                    # maps resident (Kloop)
        grid = (B, NS, NK)
        strip_axis = 1
        x_idx = lambda b, st, kt: (b, 0, 0, 0)
        w_idx = lambda b, st, kt: (0, 0, 0, kt)
        o_idx = lambda b, st, kt: (b, st, 0, kt)
        b_idx = lambda b, st, kt: (0, kt)

    n_prefetch = 0
    if row_starts is not None:
        if pltpu is None:
            raise RuntimeError("row_starts requires the Pallas TPU "
                               "backend (PrefetchScalarGridSpec); it is "
                               "unavailable in this jax install")
        n_prefetch = 1
        # Index maps receive the prefetch ref as a trailing arg.
        wrap = lambda f: (lambda *a: f(*a[:3]))
        x_idx, w_idx, o_idx, b_idx = (wrap(f) for f in
                                      (x_idx, w_idx, o_idx, b_idx))

    in_specs = [
        pl.BlockSpec((1, Hp, Wp, Cin), x_idx),
        pl.BlockSpec((kh, kw, Cin, kpt), w_idx),
    ]
    operands = [xp, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, kpt), b_idx))
        operands.append(bias.reshape(1, Cout))
    if has_bypass:
        in_specs.append(pl.BlockSpec((1, out_rows, OW, kpt), o_idx))
        operands.append(bypass)
    out_spec = pl.BlockSpec((1, SR, OWo, kpt), o_idx)
    out_shape = jax.ShapeDtypeStruct((B, NS * SR, OWo, Cout), out_dtype)

    body = functools.partial(
        _virtual_body, n_prefetch=n_prefetch, strip_axis=strip_axis,
        out_rows=out_rows, OH=OH, OW=OW, stride=stride, kh=kh, kw=kw,
        rows_c=rows_c, pool=pool, OWo=OWo, activation=activation,
        out_dtype=out_dtype, has_bias=has_bias, has_bypass=has_bypass,
        bypass_first=bypass_first)
    # All three grid dims write disjoint output blocks with no carried
    # state — parallel semantics everywhere (Mosaic double-buffers).
    params = compiler_params(("parallel",) * 3, interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    if n_prefetch:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=out_spec)
        return pl.pallas_call(body, grid_spec=grid_spec,
                              out_shape=out_shape, interpret=interpret,
                              **kwargs)(row_starts.astype(jnp.int32),
                                        *operands)
    return pl.pallas_call(body, grid=grid, in_specs=in_specs,
                          out_specs=out_spec, out_shape=out_shape,
                          interpret=interpret, **kwargs)(*operands)

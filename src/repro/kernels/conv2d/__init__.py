from .ops import conv2d
from .ref import conv2d_ref, maxpool2d_ref, avgpool2d_ref
from .kernel import conv2d_strips_pallas, conv2d_virtual_pallas
__all__ = ["conv2d", "conv2d_ref", "maxpool2d_ref", "avgpool2d_ref",
           "conv2d_strips_pallas", "conv2d_virtual_pallas"]

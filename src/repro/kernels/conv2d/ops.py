"""Public conv2d wrapper: schedule lookup, strip-storage decision,
dispatch, and shape restore.

The default path is **zero-copy**: the padded maps go to the kernel
whole (blocked only on batch / output channels) and each output-row
strip is gathered *inside* the kernel with a dynamic slice — the halo
rows are re-fetched from VMEM, never duplicated in HBM.  The paper's
scheme — materializing halo-augmented strips in DRAM so Snowflake's
DMA engine can issue contiguous single-burst loads — survives as the
``strip_storage="materialized"`` baseline; on hardware with random
VMEM access the overlap-duplication-vs-refetch tradeoff is a compiler
decision (``core/tiling.py``), not a constraint.

``fuse_pool=(window, stride[, pad[, op]])`` fuses a following max or
avg pool into the kernel epilogue (AlexNet / ResNet stem conv→pool,
GoogLeNet-style avg downsampling; stride-2 convs fuse the same way —
the strip geometry already carries the conv stride), eliminating the
pool layer's HBM round trip; on the materialized/reference paths it
degrades gracefully to a separate reference pool with identical
numerics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ...core.dataflow import Dataflow, choose_conv_dataflow
from ...core.hw import TPU_V5E, HardwareModel
from ...core.ir import pool_out
from ...core.tiling import ConvTiling, select_conv_row_strips
from .kernel import conv2d_strips_pallas, conv2d_virtual_pallas
from .ref import avgpool2d_ref, conv2d_ref, maxpool2d_ref

__all__ = ["conv2d"]


def _materialize_strips(xp, n_strips, out_rows, in_rows, stride):
    """Gather halo-augmented row strips into one HBM array:
    (B, Hp, Wp, C) -> (B*NS, in_rows, Wp, C).  This duplicates
    ``overlap_frac`` of the maps off-chip — the Snowflake baseline the
    zero-copy path exists to kill; kept for ``strip_storage=
    "materialized"`` and the strip-storage benchmark."""
    B, Hp, Wp, C = xp.shape
    starts = jnp.arange(n_strips) * out_rows * stride
    def one(start):
        return jax.lax.dynamic_slice(xp, (0, start, 0, 0),
                                     (B, in_rows, Wp, C))
    strips = jax.vmap(one)(starts)                   # (NS, B, in_rows, Wp, C)
    strips = jnp.moveaxis(strips, 1, 0)              # (B, NS, ...)
    return strips.reshape(B * n_strips, in_rows, Wp, C)


def _norm_pool(fuse_pool):
    """Normalize to (window, stride, pad, op): pad defaults to 0, op to
    "max" (matching core/ir.py's fused_pool meta)."""
    if fuse_pool is None:
        return None
    fp = tuple(fuse_pool)
    if len(fp) == 2:
        fp = fp + (0,)
    if len(fp) == 3:
        fp = fp + ("max",)
    if fp[3] not in ("max", "avg"):
        raise ValueError(f"fuse_pool op must be max|avg, got {fp[3]!r}")
    return fp


def _pool_ref(out, pool):
    """The separate-pool fallback (reference / materialized / bypass
    paths) — identical numerics to the fused epilogue."""
    pw, ps, pp, op = pool
    ref = avgpool2d_ref if op == "avg" else maxpool2d_ref
    return ref(out, window=pw, stride=ps, pad=pp)


def conv2d(x, w, *, stride: int = 1, pad: int = 0, bias=None,
           activation: str | None = None, bypass=None,
           bypass_first: bool = False, out_dtype=None,
           impl: str = "auto", dataflow: Dataflow | None = None,
           hw: HardwareModel = TPU_V5E,
           strip_storage: str = "auto",
           fuse_pool: tuple[int, ...] | None = None,
           strip_offsets: str = "affine",
           tiling: ConvTiling | None = None,
           interpret: bool | None = None) -> jax.Array:
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout); bypass broadcastable to
    the conv output (B, OH, OW, Cout).

    strip_storage: "auto" (tiler's VMEM-residency decision) |
    "virtual" (zero-copy in-kernel gather) | "materialized" (HBM halo
    duplication, paper-faithful).  fuse_pool: (window, stride[, pad[,
    op]]) max/avg pool fused into the epilogue (virtual path; other
    paths apply an equivalent reference pool).  strip_offsets: "affine" derives strip
    row offsets from the program id; "prefetch" routes them through a
    scalar-prefetched offset table instead.  tiling: a pre-resolved
    ``ConvTiling`` (the schedule's exact decision, as carried by a
    ``core/program.py`` op) — when given, no tiling is re-derived here.
    """
    if strip_storage not in ("auto", "virtual", "materialized"):
        raise ValueError(f"strip_storage must be auto|virtual|materialized, "
                         f"got {strip_storage!r}")
    if strip_offsets not in ("affine", "prefetch"):
        raise ValueError(f"strip_offsets must be affine|prefetch, "
                         f"got {strip_offsets!r}")
    pool = _norm_pool(fuse_pool)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "reference":
        out = conv2d_ref(x, w, stride=stride, pad=pad, bias=bias,
                         activation=activation, bypass=bypass,
                         bypass_first=bypass_first, out_dtype=out_dtype)
        if pool is not None:
            out = _pool_ref(out, pool)
        return out

    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    OH = (H + 2 * pad - kh) // stride + 1
    OW = (W + 2 * pad - kw) // stride + 1
    ct = tiling if tiling is not None else select_conv_row_strips(
        H, W, Cin, Cout, kh, kw, stride, pad, x.dtype.itemsize, hw, batch=B)
    storage = ct.strip_storage if strip_storage == "auto" else strip_storage
    out_rows, kpt = ct.out_rows, ct.kernels_per_tile
    while Cout % kpt != 0:
        kpt -= 1

    if storage != "virtual":
        # Paper-faithful fallback: conv via materialized strips, pool
        # (if requested) as a separate reference op.
        out = _conv2d_materialized(
            x, w, stride=stride, pad=pad, bias=bias, activation=activation,
            bypass=bypass, bypass_first=bypass_first, out_dtype=out_dtype,
            dataflow=dataflow, ct=ct, out_rows=out_rows, kpt=kpt,
            OH=OH, OW=OW, interpret=interpret)
        if pool is not None:
            out = _pool_ref(out, pool)
        return out

    if pool is not None and bypass is not None:
        # The fused-pool epilogue cannot also fold a residual add; do
        # the conv (with bypass) zero-copy and pool separately.
        out = conv2d(x, w, stride=stride, pad=pad, bias=bias,
                     activation=activation, bypass=bypass,
                     bypass_first=bypass_first, out_dtype=out_dtype,
                     impl=impl, dataflow=dataflow, hw=hw,
                     strip_storage="virtual", tiling=tiling,
                     strip_offsets=strip_offsets, interpret=interpret)
        return _pool_ref(out, pool)

    # --- zero-copy path ------------------------------------------------------
    top_pad = pad
    if pool is None:
        rows_c, SR, OHo, OWo = out_rows, out_rows, OH, OW
        n_strips = math.ceil(OH / out_rows)
    else:
        pw, ps, pp, _ = pool
        out_rows = max(ps, (out_rows // ps) * ps)   # strips own whole windows
        rows_c = out_rows + pw - ps
        SR = out_rows // ps
        OHo = pool_out(OH, pw, ps, pp)
        OWo = pool_out(OW, pw, ps, pp)
        if OHo < 1 or OWo < 1:
            raise ValueError(
                f"fuse_pool window {pw} (pad {pp}) does not fit the "
                f"{OH}x{OW} conv output")
        n_strips = math.ceil(OHo / SR)
        top_pad = pad + pp * stride      # phantom rows for the pool's top pad
    in_rows = (rows_c - 1) * stride + kh
    Hp_needed = (n_strips - 1) * out_rows * stride + in_rows
    xp = jnp.pad(x, ((0, 0),
                     (top_pad, max(0, Hp_needed - H - top_pad)),
                     (pad, pad), (0, 0)))

    if dataflow is None:
        by = x.dtype.itemsize
        out_bytes = B * OHo * OWo * Cout * by
        dataflow, _, _ = choose_conv_dataflow(
            B * H * W * Cin * by, Cin * kh * kw * Cout * by, out_bytes,
            n_map_tiles=B * n_strips, n_kernel_tiles=Cout // kpt,
            overlap_frac=ct.overlap_frac, strip_storage="virtual")

    byp = None
    if bypass is not None:
        byp = jnp.broadcast_to(bypass, (B, OH, OW, Cout))
        byp = jnp.pad(byp, ((0, 0), (0, n_strips * out_rows - OH),
                            (0, 0), (0, 0)))

    row_starts = None
    if strip_offsets == "prefetch":
        row_starts = jnp.arange(n_strips, dtype=jnp.int32) * (
            out_rows * stride)

    out = conv2d_virtual_pallas(
        xp, w, out_rows=out_rows, OH=OH, OW=OW, stride=stride, kpt=kpt,
        n_strips=n_strips, bias=bias, activation=activation, bypass=byp,
        bypass_first=bypass_first, out_dtype=out_dtype or x.dtype,
        dataflow=dataflow, pool=pool, row_starts=row_starts,
        interpret=interpret)
    return out[:, :OHo]


def _conv2d_materialized(x, w, *, stride, pad, bias, activation, bypass,
                         bypass_first, out_dtype, dataflow, ct, out_rows,
                         kpt, OH, OW, interpret):
    """The paper's scheme: halo-augmented strips duplicated in HBM."""
    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    in_rows = (out_rows - 1) * stride + kh   # full window (pad supplies halo)
    n_strips = math.ceil(OH / out_rows)

    if dataflow is None:
        by = x.dtype.itemsize
        dataflow, _, _ = choose_conv_dataflow(
            B * H * W * Cin * by, Cin * kh * kw * Cout * by,
            B * OH * OW * Cout * by,
            n_map_tiles=B * n_strips, n_kernel_tiles=Cout // kpt,
            overlap_frac=ct.overlap_frac, strip_storage="materialized")

    # Pad: spatial conv padding + bottom rows so every strip is full.
    Hp_needed = (n_strips - 1) * out_rows * stride + in_rows
    xp = jnp.pad(x, ((0, 0), (pad, max(pad, Hp_needed - H - pad)),
                     (pad, pad), (0, 0)))
    strips = _materialize_strips(xp, n_strips, out_rows, in_rows, stride)

    byp = None
    if bypass is not None:
        byp = jnp.broadcast_to(bypass, (B, OH, OW, Cout))
        pad_oh = n_strips * out_rows - OH
        byp = jnp.pad(byp, ((0, 0), (0, pad_oh), (0, 0), (0, 0)))
        byp = byp.reshape(B * n_strips, out_rows, OW, Cout)

    out = conv2d_strips_pallas(
        strips, w, out_rows=out_rows, OW=OW, stride=stride, kpt=kpt,
        bias=bias, activation=activation, bypass=byp,
        bypass_first=bypass_first, out_dtype=out_dtype or x.dtype,
        dataflow=dataflow, interpret=interpret)
    out = out.reshape(B, n_strips * out_rows, OW, Cout)
    return out[:, :OH]

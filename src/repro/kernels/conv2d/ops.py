"""Public conv2d wrapper: schedule lookup, halo-strip materialization
(the paper's augmented tiles in DRAM), dispatch, and shape restore."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ...core.dataflow import Dataflow
from ...core.hw import TPU_V5E, HardwareModel
from ...core.tiling import select_conv_row_strips
from .kernel import conv2d_strips_pallas
from .ref import conv2d_ref

__all__ = ["conv2d"]


def _make_strips(xp, n_strips, out_rows, in_rows, stride):
    """Gather halo-augmented row strips: (B, H, W, C) -> (B*NS, in_rows, W, C)."""
    B, Hp, Wp, C = xp.shape
    starts = jnp.arange(n_strips) * out_rows * stride
    def one(start):
        return jax.lax.dynamic_slice(xp, (0, start, 0, 0),
                                     (B, in_rows, Wp, C))
    strips = jax.vmap(one)(starts)                   # (NS, B, in_rows, Wp, C)
    strips = jnp.moveaxis(strips, 1, 0)              # (B, NS, ...)
    return strips.reshape(B * n_strips, in_rows, Wp, C)


def conv2d(x, w, *, stride: int = 1, pad: int = 0, bias=None,
           activation: str | None = None, bypass=None,
           bypass_first: bool = False, out_dtype=None,
           impl: str = "auto", dataflow: Dataflow | None = None,
           hw: HardwareModel = TPU_V5E,
           interpret: bool | None = None) -> jax.Array:
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout); bypass broadcastable to
    the output (B, OH, OW, Cout)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "reference":
        return conv2d_ref(x, w, stride=stride, pad=pad, bias=bias,
                          activation=activation, bypass=bypass,
                          bypass_first=bypass_first, out_dtype=out_dtype)

    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    OH = (H + 2 * pad - kh) // stride + 1
    OW = (W + 2 * pad - kw) // stride + 1
    ct = select_conv_row_strips(H, W, Cin, Cout, kh, kw, stride, pad,
                                x.dtype.itemsize, hw, batch=B)
    out_rows, kpt = ct.out_rows, ct.kernels_per_tile
    in_rows = (out_rows - 1) * stride + kh   # full window (pad supplies halo)
    while Cout % kpt != 0:
        kpt -= 1
    n_strips = math.ceil(OH / out_rows)

    if dataflow is None:
        # T3 on the strip grid (same formulas as core/schedule.py).
        maps_b = H * W * Cin
        ker_b = Cin * kh * kw * Cout
        kloop = maps_b + n_strips * ker_b
        mloop = (Cout // kpt) * maps_b + ker_b
        dataflow = (Dataflow.MAPS_RESIDENT if kloop <= mloop
                    else Dataflow.WEIGHTS_RESIDENT)

    # Pad: spatial conv padding + bottom rows so every strip is full.
    Hp_needed = (n_strips - 1) * out_rows * stride + in_rows
    xp = jnp.pad(x, ((0, 0), (pad, max(pad, Hp_needed - H - pad)),
                     (pad, pad), (0, 0)))
    strips = _make_strips(xp, n_strips, out_rows, in_rows, stride)

    byp = None
    if bypass is not None:
        byp = jnp.broadcast_to(bypass, (B, OH, OW, Cout))
        pad_oh = n_strips * out_rows - OH
        byp = jnp.pad(byp, ((0, 0), (0, pad_oh), (0, 0), (0, 0)))
        byp = byp.reshape(B * n_strips, out_rows, OW, Cout)

    out = conv2d_strips_pallas(
        strips, w, out_rows=out_rows, OW=OW, stride=stride, kpt=kpt,
        bias=bias, activation=activation, bypass=byp,
        bypass_first=bypass_first, out_dtype=out_dtype or x.dtype,
        dataflow=dataflow, interpret=interpret)
    out = out.reshape(B, n_strips * out_rows, OW, Cout)
    return out[:, :OH]

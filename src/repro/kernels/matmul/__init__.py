from .ops import matmul, scheduled_matmul
from .ref import matmul_ref
from .kernel import matmul_pallas

__all__ = ["matmul", "scheduled_matmul", "matmul_ref", "matmul_pallas"]

"""Schedule-driven tiled matmul Pallas kernel.

The schedule compiler (core/dataflow.py) picks one of three dataflows
per layer; each maps to a distinct grid/BlockSpec arrangement.  All
three share one kernel body with a fused epilogue (bias + activation +
residual bypass — the paper's VMOV-on-writeback, T1/T5):

* MAPS_RESIDENT (paper Kloop)     grid (m, n): the A-slab (bm x K) block
  index ignores n, so the Pallas pipeline keeps it resident across the
  inner n sweep; B streams once per m-tile.
* WEIGHTS_RESIDENT (paper Mloop)  grid (n, m): the B-slab (K x bn) index
  ignores m; A streams once per n-tile.
* OUTPUT_STATIONARY (beyond-paper) grid (m, n, k): both operands tiled;
  f32 accumulator in VMEM scratch, epilogue on the last k step.

Inputs must be pre-padded to block multiples (ops.py does this).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import (apply_activation, compiler_params, default_interpret,
                      vmem_scratch)
from ...core.dataflow import Dataflow

__all__ = ["matmul_pallas"]


def _epilogue(acc, bias_ref, bypass_ref, activation, out_dtype):
    if bias_ref is not None:
        acc = acc + bias_ref[...].astype(jnp.float32)
    acc = apply_activation(acc, activation)
    if bypass_ref is not None:
        acc = acc + bypass_ref[...].astype(jnp.float32)
    return acc.astype(out_dtype)


def _resident_body(a_ref, b_ref, *rest, activation, out_dtype,
                   has_bias, has_bypass):
    """Single-shot contraction: full K present in both refs."""
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    bypass_ref = refs.pop(0) if has_bypass else None
    o_ref = refs.pop(0)
    acc = jnp.dot(a_ref[...], b_ref[...],
                  preferred_element_type=jnp.float32)
    o_ref[...] = _epilogue(acc, bias_ref, bypass_ref, activation, out_dtype)


def _os_body(a_ref, b_ref, *rest, activation, out_dtype, has_bias,
             has_bypass, k_axis):
    """Output-stationary: accumulate over the k grid dim in scratch."""
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    bypass_ref = refs.pop(0) if has_bypass else None
    o_ref = refs.pop(0)
    acc_ref = refs.pop(0)
    k = pl.program_id(k_axis)
    nk = pl.num_programs(k_axis)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...], bias_ref, bypass_ref,
                               activation, out_dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *,
                  dataflow: Dataflow = Dataflow.OUTPUT_STATIONARY,
                  block: tuple[int, int, int],
                  bias: jax.Array | None = None,
                  activation: str | None = None,
                  bypass: jax.Array | None = None,
                  out_dtype=None,
                  interpret: bool | None = None) -> jax.Array:
    """2D matmul (M,K)x(K,N) with fused epilogue.  Shapes must already be
    padded to the block multiples implied by ``dataflow``/``block``."""
    if interpret is None:
        interpret = default_interpret()
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bk, bn = block
    out_dtype = out_dtype or a.dtype
    has_bias = bias is not None
    has_bypass = bypass is not None
    out_shape = jax.ShapeDtypeStruct((M, N), out_dtype)

    if dataflow is Dataflow.MAPS_RESIDENT:
        assert M % bm == 0 and N % bn == 0, (a.shape, b.shape, block)
        grid = (M // bm, N // bn)                      # m outer, n inner
        a_spec = pl.BlockSpec((bm, K), lambda m, n: (m, 0))   # resident
        b_spec = pl.BlockSpec((K, bn), lambda m, n: (0, n))   # streamed
        o_spec = pl.BlockSpec((bm, bn), lambda m, n: (m, n))
        extra_specs = []
        if has_bias:
            extra_specs.append(pl.BlockSpec((1, bn), lambda m, n: (0, n)))
        if has_bypass:
            extra_specs.append(pl.BlockSpec((bm, bn), lambda m, n: (m, n)))
        body = functools.partial(_resident_body, activation=activation,
                                 out_dtype=out_dtype, has_bias=has_bias,
                                 has_bypass=has_bypass)
        scratch = []
        semantics = ("arbitrary", "arbitrary")
    elif dataflow is Dataflow.WEIGHTS_RESIDENT:
        assert M % bm == 0 and N % bn == 0, (a.shape, b.shape, block)
        grid = (N // bn, M // bm)                      # n outer, m inner
        a_spec = pl.BlockSpec((bm, K), lambda n, m: (m, 0))   # streamed
        b_spec = pl.BlockSpec((K, bn), lambda n, m: (0, n))   # resident
        o_spec = pl.BlockSpec((bm, bn), lambda n, m: (m, n))
        extra_specs = []
        if has_bias:
            extra_specs.append(pl.BlockSpec((1, bn), lambda n, m: (0, n)))
        if has_bypass:
            extra_specs.append(pl.BlockSpec((bm, bn), lambda n, m: (m, n)))
        body = functools.partial(_resident_body, activation=activation,
                                 out_dtype=out_dtype, has_bias=has_bias,
                                 has_bypass=has_bypass)
        scratch = []
        semantics = ("arbitrary", "arbitrary")
    else:  # OUTPUT_STATIONARY
        assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
            (a.shape, b.shape, block)
        grid = (M // bm, N // bn, K // bk)             # k innermost
        a_spec = pl.BlockSpec((bm, bk), lambda m, n, k: (m, k))
        b_spec = pl.BlockSpec((bk, bn), lambda m, n, k: (k, n))
        o_spec = pl.BlockSpec((bm, bn), lambda m, n, k: (m, n))
        extra_specs = []
        if has_bias:
            extra_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))
        if has_bypass:
            extra_specs.append(
                pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)))
        body = functools.partial(_os_body, activation=activation,
                                 out_dtype=out_dtype, has_bias=has_bias,
                                 has_bypass=has_bypass, k_axis=2)
        scratch = [vmem_scratch((bm, bn), jnp.float32)]
        semantics = ("parallel", "parallel", "arbitrary")

    operands = [a, b]
    if has_bias:
        operands.append(bias.reshape(1, N))
    if has_bypass:
        operands.append(bypass)

    params = compiler_params(semantics, interpret)
    kwargs = {}
    if params is not None:
        kwargs["compiler_params"] = params
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[a_spec, b_spec] + extra_specs,
        out_specs=o_spec,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(*operands)

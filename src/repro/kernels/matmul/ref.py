"""Pure-jnp oracle for the scheduled matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import apply_activation

__all__ = ["matmul_ref"]


def matmul_ref(a: jax.Array, b: jax.Array, *,
               bias: jax.Array | None = None,
               activation: str | None = None,
               bypass: jax.Array | None = None,
               out_dtype=None) -> jax.Array:
    """C = epilogue(A @ B):  f32 accumulation, optional bias add,
    activation and residual-bypass add (the paper's fused writeback)."""
    acc = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = apply_activation(acc, activation)
    if bypass is not None:
        acc = acc + bypass.astype(jnp.float32)
    return acc.astype(out_dtype or a.dtype)

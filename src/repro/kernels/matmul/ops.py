"""Public jit'd wrapper for the scheduled matmul.

Handles schedule lookup (tiling + dataflow from core/), padding to block
multiples, leading-batch-dim folding, and the pallas/reference dispatch
(Pallas on TPU or under interpret=True; pure-jnp reference elsewhere,
e.g. inside the CPU dry-run where Mosaic is unavailable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import default_interpret, pad_to, unpad
from ...core.dataflow import Dataflow, choose_matmul_dataflow
from ...core.hw import TPU_V5E, HardwareModel
from .kernel import matmul_pallas
from .ref import matmul_ref

__all__ = ["matmul", "scheduled_matmul"]


def _fold(a: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = a.shape[:-1]
    return a.reshape(-1, a.shape[-1]), lead


def matmul(a: jax.Array, b: jax.Array, *,
           bias: jax.Array | None = None,
           activation: str | None = None,
           bypass: jax.Array | None = None,
           out_dtype=None,
           impl: str = "auto",
           dataflow: Dataflow | None = None,
           block: tuple[int, int, int] | None = None,
           hw: HardwareModel = TPU_V5E,
           interpret: bool | None = None) -> jax.Array:
    """``epilogue(a @ b)`` with schedule-driven tiling.

    a: (..., K); b: (K, N); bias: (N,); bypass: broadcastable to out.
    impl: "auto" | "pallas" | "reference".
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "reference":
        return matmul_ref(a, b, bias=bias, activation=activation,
                          bypass=bypass, out_dtype=out_dtype)

    a2, lead = _fold(a)
    M, K = a2.shape
    N = b.shape[-1]
    if dataflow is None or block is None:
        dec = choose_matmul_dataflow(M, K, N, a.dtype.itemsize, hw)
        dataflow = dataflow or dec.dataflow
        block = block or (dec.tiling.bm, dec.tiling.bk, dec.tiling.bn)
    bm, bk, bn = block
    bm, bn = min(bm, _ceil_mult(M, 128)), min(bn, _ceil_mult(N, 128))
    bk = min(bk, _ceil_mult(K, 128))
    block = (bm, bk, bn)

    kpad = bk if dataflow is Dataflow.OUTPUT_STATIONARY else 128
    a_p = pad_to(a2, (bm, kpad))
    b_p = pad_to(b, (kpad, bn))
    bypass_p = None
    if bypass is not None:
        bypass_p = pad_to(jnp.broadcast_to(bypass.reshape(M, N), (M, N)),
                          (bm, bn))
    bias_p = pad_to(bias, (bn,)) if bias is not None else None

    out = matmul_pallas(a_p, b_p, dataflow=dataflow, block=block,
                        bias=bias_p, activation=activation,
                        bypass=bypass_p, out_dtype=out_dtype or a.dtype,
                        interpret=interpret)
    out = unpad(out, (M, N))
    return out.reshape(*lead, N)


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def scheduled_matmul(schedule, a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """Run a matmul under a precomputed ``LayerSchedule``."""
    return matmul(a, b, dataflow=schedule.dataflow, block=schedule.block,
                  activation=schedule.fuse_activation, **kw)

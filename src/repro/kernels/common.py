"""Shared kernel utilities: padding, epilogue math, compiler params."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params: name moved across jax versions.
    from jax.experimental.pallas import tpu as pltpu
    _CompilerParams = getattr(pltpu, "CompilerParams",
                              getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

__all__ = ["pltpu", "compiler_params", "pad_to", "unpad", "apply_activation",
           "ACTIVATIONS", "vmem_scratch", "default_interpret"]


def default_interpret() -> bool:
    """Pallas runs in interpret mode off-TPU (this container is CPU)."""
    return jax.default_backend() != "tpu"


def compiler_params(dimension_semantics: tuple[str, ...],
                    interpret: bool):
    """Mosaic compiler params; omitted in interpret mode."""
    if interpret or _CompilerParams is None:
        return None
    return _CompilerParams(dimension_semantics=dimension_semantics)


def pad_to(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    """Zero-pad trailing dims of ``x`` up to the given multiples."""
    pads = []
    for dim, m in zip(x.shape[-len(multiples):], multiples):
        target = ((dim + m - 1) // m) * m
        pads.append((0, target - dim))
    full = [(0, 0)] * (x.ndim - len(multiples)) + pads
    if all(p == (0, 0) for p in full):
        return x
    return jnp.pad(x, full)


def unpad(x: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    if tuple(x.shape) == tuple(shape):
        return x
    return x[tuple(slice(0, s) for s in shape)]


def _silu(x):
    return x * jax.nn.sigmoid(x)


ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0),
    "silu": _silu,
    "swish": _silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "tanh": jnp.tanh,
}


def apply_activation(x: jax.Array, name: str | None) -> jax.Array:
    return ACTIVATIONS[name](x)


def vmem_scratch(shape, dtype):
    """VMEM scratch shape (works in interpret mode too)."""
    assert pltpu is not None, "pallas tpu backend required"
    return pltpu.VMEM(shape, dtype)

from .ops import decode_attention
from .ref import decode_attention_ref
from .kernel import decode_attention_pallas
__all__ = ["decode_attention", "decode_attention_ref", "decode_attention_pallas"]

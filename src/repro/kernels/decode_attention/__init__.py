from .ops import (decode_attention, gather_pages, paged_decode_attention,
                  ring_kv_len, ring_positions)
from .ref import decode_attention_ref
from .kernel import decode_attention_pallas, paged_decode_attention_pallas
__all__ = ["decode_attention", "decode_attention_ref",
           "decode_attention_pallas", "paged_decode_attention",
           "paged_decode_attention_pallas", "gather_pages",
           "ring_kv_len", "ring_positions"]

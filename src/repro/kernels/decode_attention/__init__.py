from .ops import decode_attention, ring_kv_len, ring_positions
from .ref import decode_attention_ref
from .kernel import decode_attention_pallas
__all__ = ["decode_attention", "decode_attention_ref",
           "decode_attention_pallas", "ring_kv_len", "ring_positions"]

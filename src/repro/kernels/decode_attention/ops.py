"""Public decode-attention wrapper with pallas/reference dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.hw import TPU_V5E, HardwareModel
from .kernel import decode_attention_pallas
from .ref import decode_attention_ref

__all__ = ["decode_attention"]


def decode_attention(q, k, v, *, kv_len=None, scale: float | None = None,
                     impl: str = "auto", block_kv: int | None = None,
                     hw: HardwareModel = TPU_V5E,
                     interpret: bool | None = None) -> jax.Array:
    """Single-token decode: q (B,Hq,D) vs cache (B,Hkv,S,D)."""
    B, Hq, D = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    if kv_len is None:
        kv_len = jnp.full((B,), S, jnp.int32)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "reference":
        return decode_attention_ref(q, k, v, kv_len=kv_len, scale=scale)
    if block_kv is None:
        # T2 decode regime: cache block sized to stream at full
        # bandwidth, k+v double buffered.  One chooser shared with the
        # compiler (core/tiling.py) — the decode-Program lowering pins
        # the same value into each decode_attention op, so this branch
        # only runs for direct (non-Program) kernel calls.
        from ...core.tiling import select_attention_blocks
        _, block_kv = select_attention_blocks(1, S, D, k.dtype.itemsize, hw)
    pad = (-S) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return decode_attention_pallas(q, k, v, kv_len, scale=scale,
                                   block_kv=block_kv, interpret=interpret)

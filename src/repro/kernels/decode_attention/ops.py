"""Public decode-attention wrapper with pallas/reference dispatch.

The cache operand is a **ring buffer**: callers that decode past the
cache length (a rolling full-length cache, or a sliding-window cache
sized ``W = min(max_len, attn_window)``) write the new token's K/V at
``pos % S`` and pass ``kv_len = ring_kv_len(pos, S)`` — the last
``min(pos + 1, S)`` rows are then valid and everything at ring slots
``>= kv_len`` (unwritten padding, or rows evicted by overwrite) is
masked out.  Row *order* inside the ring does not matter: RoPE bakes
each row's absolute position into its key, and softmax attention is
permutation-invariant over KV rows, so the wrapped layout attends
identically to the chronological one (the legacy
``transformer._attention_decode`` rule this kernel inherits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.hw import TPU_V5E, HardwareModel
from .kernel import decode_attention_pallas, paged_decode_attention_pallas
from .ref import decode_attention_ref

__all__ = ["decode_attention", "paged_decode_attention", "gather_pages",
           "ring_kv_len", "ring_positions"]


def ring_positions(length, cache_len: int, seq_len: int):
    """Source position for every ring slot of a rolling cache holding
    the last ``min(length, cache_len)`` of ``seq_len`` computed rows:
    slot ``j`` holds the latest position ``p < length`` with ``p %
    cache_len == j``.  Returns (cache_len,) int32 gather indices into
    the full (seq_len, ...) row stack; ``length`` may be a traced
    scalar (the runtime prompt length).

    Slots with no valid position (j >= length) fall out of range and
    are clipped — they end up *duplicating* an early row, not holding
    zeros.  That is safe because such slots sit at ring indices ``>=
    ring_kv_len(length - 1, cache_len)`` and decode overwrites slot
    ``pos % cache_len`` at the exact tick ``ring_kv_len`` first admits
    it, so a duplicate is never attended.

    This is THE ring-layout rule: the prefill executor
    (runtime/executor.py::_write_prefill_cache) gathers with it at a
    runtime length, and the legacy cache export (models/transformer.py
    ::forward ``return_cache``) uses it at ``length == seq_len`` — one
    shared rule, like ``ring_kv_len``, so the two layouts can never
    drift."""
    j = jnp.arange(cache_len)
    last = jnp.asarray(length, jnp.int32) - 1
    p = j + ((last - j) // cache_len) * cache_len
    return jnp.clip(p, 0, seq_len - 1)


def ring_kv_len(pos, cache_len: int):
    """Valid-row count of a rolling (ring) KV cache after the write at
    ``pos % cache_len`` has landed: the last ``min(pos + 1, cache_len)``
    tokens are attendable, older rows have been evicted by overwrite.
    One rule shared by the legacy decode loop
    (models/transformer.py::_attention_decode) and the decode-Program
    executor (runtime/executor.py::run_decode) so the two paths can
    never drift."""
    return jnp.minimum(pos + 1, cache_len)


def decode_attention(q, k, v, *, kv_len=None, scale: float | None = None,
                     impl: str = "auto", block_kv: int | None = None,
                     hw: HardwareModel = TPU_V5E,
                     interpret: bool | None = None) -> jax.Array:
    """Single-token decode: q (B,Hq,D) vs cache (B,Hkv,S,D)."""
    B, Hq, D = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    if kv_len is None:
        kv_len = jnp.full((B,), S, jnp.int32)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "reference":
        return decode_attention_ref(q, k, v, kv_len=kv_len, scale=scale)
    if block_kv is None:
        # T2 decode regime: cache block sized to stream at full
        # bandwidth, k+v double buffered.  One chooser shared with the
        # compiler (core/tiling.py) — the decode-Program lowering pins
        # the same value into each decode_attention op, so this branch
        # only runs for direct (non-Program) kernel calls.  A windowed
        # cache is already window-sized, so S is the right extent.
        from ...core.tiling import select_attention_blocks
        _, block_kv = select_attention_blocks(1, S, D, k.dtype.itemsize, hw)
    pad = (-S) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return decode_attention_pallas(q, k, v, kv_len, scale=scale,
                                   block_kv=block_kv, interpret=interpret)


def gather_pages(pages, table, scale=None):
    """Materialize the contiguous (B, Hkv, S, D) cache view of a page
    pool through a page table — THE table-indirection rule, shared by
    the reference paged-attention path below and any caller that needs
    the flat layout (tests, the engine's debug dumps).

    pages: (n_pages, page_size, Hkv, D) pool (any dtype; int8 pools are
    dequantized when ``scale`` — per-page (n_pages,) float32 — is
    given); table: (B, pages_per_slot) int32.  Row ``s`` of slot ``b``
    is pool row ``(table[b, s // page_size], s % page_size)``; the null
    page 0 supplies whatever masked writes left there, which is fine
    because every row it backs sits beyond the caller's ``kv_len`` or
    below its shared-prefix redirect."""
    gathered = pages[table]          # (B, pages_per_slot, page_size, Hkv, D)
    if scale is not None:
        gathered = gathered.astype(jnp.float32) * scale[table][
            :, :, None, None, None]
    B, P, G, Hkv, D = gathered.shape
    return gathered.reshape(B, P * G, Hkv, D).transpose(0, 2, 1, 3)


def paged_decode_attention(q, k_pages, v_pages, page_table, *, kv_len,
                           scale: float | None = None,
                           k_scale=None, v_scale=None,
                           impl: str = "auto",
                           interpret: bool | None = None) -> jax.Array:
    """Single-token decode against a **paged** KV cache (§5.1 paged
    region plan): q (B, Hq, D) vs pools (n_pages, page_size, Hkv, D)
    addressed through ``page_table`` (B, pages_per_slot) int32.

    The virtual row range of slot ``b`` is its table row flattened —
    ``cache_len = pages_per_slot * page_size`` — and the same ring
    rules apply *through the table*: callers pass ``kv_len =
    ring_kv_len(pos, cache_len)`` and write the new token's K/V at
    virtual row ``pos % cache_len`` (i.e. into page ``row //
    page_size``), so rolling overwrite past ``cache_len`` works
    unchanged.  int8 pools carry one float32 scale per page
    (``k_scale`` / ``v_scale``), applied in the gather.

    There is no block_kv knob: the kv block IS the page
    (core/tiling.py pins block_kv == page_size for paged decode ops)."""
    B, Hq, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    if kv_len is None:
        kv_len = jnp.full((B,), page_table.shape[1] * k_pages.shape[1],
                          jnp.int32)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "reference":
        k = gather_pages(k_pages, page_table, k_scale)
        v = gather_pages(v_pages, page_table, v_scale)
        return decode_attention_ref(q, k, v, kv_len=kv_len, scale=scale)
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, page_table, kv_len, scale=scale,
        k_scale=k_scale, v_scale=v_scale, interpret=interpret)

"""KV-cache decode attention Pallas kernel.

One new query token per sequence against a long KV cache — the
bandwidth-bound serving hot spot (every cache byte is read once per
step, arithmetic intensity ~= 1 MAC/byte).  The schedule compiler's job
here is purely T2/T4: size the kv block to VMEM and keep the streams
busy; there is no loop-order freedom (the cache is the only big
operand).

Grid: (B * Hq, S / bkv), kv innermost with running-softmax scratch.
GQA folded into the KV index map as in flash_attention.

Ring-cache semantics: the per-sequence ``kv_len`` (see
ops.py::ring_kv_len) bounds the valid rows of a rolling cache — blocks
whose start is past ``kv_len`` are skipped entirely (``sk0 < kv_len``
guard, so a window-sized cache streams only window bytes) and the tail
block masks per-row.  The kernel never reorders rows; the wrapped ring
layout is handled by softmax's permutation invariance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params, default_interpret, vmem_scratch

__all__ = ["decode_attention_pallas", "paged_decode_attention_pallas"]

NEG_INF = -1e30


def _body(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
          scale, bkv):
    kb = pl.program_id(1)
    nkv = pl.num_programs(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    sk0 = kb * bkv

    @pl.when(sk0 < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (1, D)
        k = k_ref[0].astype(jnp.float32)            # (bkv, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ki = sk0 + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        s = jnp.where(ki < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            p.sum(-1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == nkv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, kv_len, *, scale: float,
                            block_kv: int = 1024,
                            interpret: bool | None = None) -> jax.Array:
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: (B,) int32."""
    if interpret is None:
        interpret = default_interpret()
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    bkv = min(block_kv, S)
    assert S % bkv == 0

    qf = q.reshape(B * Hq, 1, D)
    kf = k.reshape(B * Hkv, S, D)
    vf = v.reshape(B * Hkv, S, D)
    lenf = kv_len.astype(jnp.int32)

    def kv_map(h, kb):
        return ((h // Hq) * Hkv + (h % Hq) // group, kb, 0)

    grid = (B * Hq, S // bkv)
    body = functools.partial(_body, scale=scale, bkv=bkv)
    params = compiler_params(("parallel", "arbitrary"), interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda h, kb: (h // Hq,)),
                  pl.BlockSpec((1, 1, D), lambda h, kb: (h, 0, 0)),
                  pl.BlockSpec((1, bkv, D), kv_map),
                  pl.BlockSpec((1, bkv, D), kv_map)],
        out_specs=pl.BlockSpec((1, 1, D), lambda h, kb: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, D), q.dtype),
        scratch_shapes=[vmem_scratch((1, 128), jnp.float32),
                        vmem_scratch((1, 128), jnp.float32),
                        vmem_scratch((1, D), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(lenf, qf, kf, vf)
    return out.reshape(B, Hq, D)


def _paged_body(len_ref, pt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                o_ref, m_ref, l_ref, acc_ref, *, scale, page_size, Hq, Hkv):
    """One (sequence*head, page) grid step of paged decode attention.

    The kv block IS the page: the page table block (1, 1) names which
    pool page this step reads, and the page's rows are loaded with a
    dynamic ``pl.ds`` gather from the whole-pool ref — the page id is a
    runtime value, so it cannot appear in a BlockSpec index map without
    TPU-only scalar prefetch; keeping the gather in the body keeps the
    kernel portable to interpret mode.  Quantized pools (ks/vs scale
    refs present) are dequantized per page right after the load."""
    h = pl.program_id(0)
    kb = pl.program_id(1)
    nkv = pl.num_programs(1)
    kvh = (h % Hq) // (Hq // Hkv)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    sk0 = kb * page_size

    @pl.when(sk0 < kv_len)
    def _compute():
        page = pt_ref[0, 0]
        idx = (pl.ds(page, 1), slice(None), pl.ds(kvh, 1), slice(None))
        k = pl.load(k_ref, idx)[0, :, 0, :].astype(jnp.float32)  # (pg, D)
        v = pl.load(v_ref, idx)[0, :, 0, :].astype(jnp.float32)
        if ks_ref is not None:
            k = k * pl.load(ks_ref, (pl.ds(page, 1),))[0]
            v = v * pl.load(vs_ref, (pl.ds(page, 1),))[0]
        q = q_ref[0].astype(jnp.float32)                         # (1, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ki = sk0 + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        s = jnp.where(ki < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            p.sum(-1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == nkv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pages, v_pages, page_table, kv_len, *,
                                  scale: float,
                                  k_scale=None, v_scale=None,
                                  interpret: bool | None = None) -> jax.Array:
    """q: (B, Hq, D); k_pages, v_pages: (n_pages, page_size, Hkv, D)
    pools; page_table: (B, pages_per_slot) int32; kv_len: (B,) int32
    ring extents.  k_scale / v_scale: (n_pages,) float32 per-page
    dequant scales for int8 pools, or None for float pools.

    Grid (B * Hq, pages_per_slot) — the page table is blocked (1, 1)
    per grid step and the pools ride along whole (their index map is
    constant) because the page id is runtime data.  block_kv ==
    page_size by construction (core/tiling.py pins it)."""
    if interpret is None:
        interpret = default_interpret()
    B, Hq, D = q.shape
    n_pages, page_size, Hkv, _ = k_pages.shape
    pages_per_slot = page_table.shape[1]
    quant = k_scale is not None

    qf = q.reshape(B * Hq, 1, D)
    lenf = kv_len.astype(jnp.int32)

    whole_pool = pl.BlockSpec((n_pages, page_size, Hkv, D),
                              lambda h, kb: (0, 0, 0, 0))
    in_specs = [pl.BlockSpec((1,), lambda h, kb: (h // Hq,)),
                pl.BlockSpec((1, 1), lambda h, kb: (h // Hq, kb)),
                pl.BlockSpec((1, 1, D), lambda h, kb: (h, 0, 0)),
                whole_pool, whole_pool]
    args = [lenf, page_table.astype(jnp.int32), qf, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((n_pages,), lambda h, kb: (0,))] * 2
        args += [k_scale, v_scale]

    def body(*refs):
        if quant:
            len_ref, pt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref = refs[:7]
            rest = refs[7:]
        else:
            len_ref, pt_ref, q_ref, k_ref, v_ref = refs[:5]
            ks_ref = vs_ref = None
            rest = refs[5:]
        _paged_body(len_ref, pt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                    *rest, scale=scale, page_size=page_size, Hq=Hq, Hkv=Hkv)

    params = compiler_params(("parallel", "arbitrary"), interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    out = pl.pallas_call(
        body,
        grid=(B * Hq, pages_per_slot),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, D), lambda h, kb: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, D), q.dtype),
        scratch_shapes=[vmem_scratch((1, 128), jnp.float32),
                        vmem_scratch((1, 128), jnp.float32),
                        vmem_scratch((1, D), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(*args)
    return out.reshape(B, Hq, D)

"""Oracle for single-token KV-cache decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref"]

NEG_INF = -1e30


def decode_attention_ref(q, k, v, *, kv_len=None,
                         scale: float | None = None) -> jax.Array:
    """q: (B, Hq, D) one new token; k, v: (B, Hkv, S, D) cache;
    kv_len: (B,) valid lengths (int) or None for full cache.

    Ring-cache contract (see ops.py): the cache may be a rolling buffer
    written at ``pos % S`` — rows at ring slots ``< kv_len`` are the
    last ``min(pos + 1, S)`` tokens (in wrapped order, which softmax
    attention cannot observe), rows at slots ``>= kv_len`` are padding
    or evicted history and are masked to -inf here."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32)) * scale
    if kv_len is not None:
        mask = jnp.arange(S)[None, :] < kv_len[:, None]      # (B, S)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)

"""Pallas hot-spot kernels, each a kernel.py + ops.py + ref.py triple.

Every op dispatches impl="auto" -> Pallas on TPU, pure-jnp reference on
CPU (the dry-run path); interpret=True validates the kernel bodies on
CPU in the test suite.
"""
from .matmul import matmul, scheduled_matmul, matmul_ref
from .conv2d import conv2d, conv2d_ref, maxpool2d_ref, avgpool2d_ref
from .flash_attention import flash_attention, attention_ref, flash_ref
from .decode_attention import (decode_attention, decode_attention_ref,
                               paged_decode_attention)
from .mamba2 import mamba2_scan, mamba2_decode_step, mamba2_scan_ref
from .rwkv6 import wkv6, wkv6_decode_step, wkv6_ref

__all__ = [
    "matmul", "scheduled_matmul", "matmul_ref",
    "conv2d", "conv2d_ref", "maxpool2d_ref", "avgpool2d_ref",
    "flash_attention", "attention_ref", "flash_ref",
    "decode_attention", "decode_attention_ref", "paged_decode_attention",
    "mamba2_scan", "mamba2_decode_step", "mamba2_scan_ref",
    "wkv6", "wkv6_decode_step", "wkv6_ref",
]

"""Chunked Mamba2 SSD scan Pallas kernel.

TPU adaptation of the SSD block decomposition: the sequence is split
into chunks of Q steps; within a chunk the recurrence is a masked-decay
matmul (MXU work), across chunks a small (N, P) state is carried in VMEM
scratch — the grid's chunk axis is sequential, so scratch persists.
This turns a length-L scan into L/Q matmul tiles, which is exactly the
paper's T2 move: batch enough contiguous MAC work ("traces") per tile to
hide the bookkeeping.

Numerically safe: A < 0 and dt >= 0 make every exponent non-positive.

Grid: (B*H, L/Q).  B/C are shared across heads (single group) and
indexed through bh -> batch maps, so they stream once per batch, not per
head — the Mloop/Kloop reasoning applied to the SSM operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import compiler_params, default_interpret, vmem_scratch

__all__ = ["mamba2_scan_pallas"]


def _body(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
          y_ref, hout_ref, h_ref, *, Q, H):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    A = a_ref[0].astype(jnp.float32)          # scalar
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    a = A * dt                                # (Q,) <= 0
    cum = jnp.cumsum(a)                       # inclusive
    total = cum[-1]

    # Intra-chunk: masked decay attention on the MXU.
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    dec = jnp.exp(cum[:, None] - cum[None, :])
    S = jnp.where(s_i <= t_i, CB * dec, 0.0) * dt[None, :]
    y = jax.lax.dot_general(S, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # Inter-chunk: contribution of the carried state.
    h_prev = h_ref[...]                       # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # State update.
    w = (jnp.exp(total - cum) * dt)[:, None]  # (Q, 1)
    h_ref[...] = h_prev * jnp.exp(total) + jax.lax.dot_general(
        Bm * w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def mamba2_scan_pallas(x, dt, A, B, C, *, h0=None, chunk: int = 256,
                       interpret: bool | None = None):
    """x: (Bt, L, H, P); dt: (Bt, L, H); A: (H,); B, C: (Bt, L, N).
    Returns (y (Bt, L, H, P), h_final (Bt, H, N, P))."""
    if interpret is None:
        interpret = default_interpret()
    Bt, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)

    xf = jnp.moveaxis(x, 2, 1).reshape(Bt * H, L, P)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(Bt * H, L)
    h0f = (h0.reshape(Bt * H, N, P) if h0 is not None
           else jnp.zeros((Bt * H, N, P), jnp.float32))

    grid = (Bt * H, L // Q)
    body = functools.partial(_body, Q=Q, H=H)
    params = compiler_params(("parallel", "arbitrary"), interpret)
    kwargs = {"compiler_params": params} if params is not None else {}
    y, h_fin = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q), lambda bh, c: (bh, c)),
            pl.BlockSpec((1,), lambda bh, c: (bh % H,)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh // H, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh // H, c, 0)),
            pl.BlockSpec((1, N, P), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, N, P), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt * H, L, P), x.dtype),
            jax.ShapeDtypeStruct((Bt * H, N, P), jnp.float32),
        ],
        scratch_shapes=[vmem_scratch((N, P), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(xf, dtf, A, B, C, h0f)
    y = jnp.moveaxis(y.reshape(Bt, H, L, P), 1, 2)
    return y, h_fin.reshape(Bt, H, N, P)

"""Oracle for the Mamba2 selective state-space scan (SSD).

Per head: state h (N, P); per step t
    h_t = exp(A * dt_t) * h_{t-1} + B_t^T (dt_t * x_t)     (outer product)
    y_t = C_t h_t + D_skip * x_t
A is a negative scalar per head; B_t, C_t are shared across heads
(single group); x (B, L, H, P); dt (B, L, H); B/C (B, L, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mamba2_scan_ref"]


def mamba2_scan_ref(x, dt, A, B, C, *, D_skip=None, h0=None,
                    return_state: bool = False):
    """x: (Bt, L, H, P); dt: (Bt, L, H); A: (H,); B, C: (Bt, L, N).
    Returns y (Bt, L, H, P) [and final state (Bt, H, N, P)]."""
    Bt, L, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt_, Ct_ = inp          # (Bt,H,P), (Bt,H), (Bt,N), (Bt,N)
        decay = jnp.exp(Af[None, :] * dtt)                  # (Bt, H)
        dBx = jnp.einsum("bn,bhp->bhnp", Bt_, xt * dtt[..., None])
        h = h * decay[..., None, None] + dBx                # (Bt,H,N,P)
        y = jnp.einsum("bn,bhnp->bhp", Ct_, h)
        return h, y

    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((Bt, H, N, P), jnp.float32))
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h_fin, ys = jax.lax.scan(step, h_init, xs)
    y = jnp.moveaxis(ys, 0, 1)                              # (Bt, L, H, P)
    if D_skip is not None:
        y = y + D_skip.astype(jnp.float32)[None, None, :, None] * xf
    y = y.astype(x.dtype)
    if return_state:
        return y, h_fin
    return y


def mamba2_scan_chunked(x, dt, A, B, C, *, D_skip=None, h0=None,
                        return_state: bool = False, chunk: int = 64):
    """Block-parallel SSD in pure jnp — the Pallas kernel's chunk
    decomposition without Mosaic, used as the model path off-TPU.

    Replaces the L-step sequential scan (state re-read every step, the
    dominant memory term in the baseline zamba2 roofline) with L/Q chunk
    steps of masked-decay matmuls; state traffic drops by Q (§Perf H1).
    All exponents are <= 0, so the form is numerically safe.
    """
    Bt, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    while L % Q != 0:
        Q //= 2
    nc = L // Q
    # Big activations stay in the input dtype (bf16 on the model path —
    # upcasting them doubled the dominant memory term, §Perf H1 iter 6);
    # only the small per-head cumsums / state run in f32.
    xr = x.reshape(Bt, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, Q, H)
    Br = B.reshape(Bt, nc, Q, N)
    Cr = C.reshape(Bt, nc, Q, N)
    Af = A.astype(jnp.float32)
    cdt = x.dtype

    def step(h, inp):
        xc, dtc, Bc, Cc = inp        # (Bt,Q,H,P) (Bt,Q,H) (Bt,Q,N) (Bt,Q,N)
        a = Af[None, None] * dtc                       # (Bt,Q,H) <= 0
        cum = jnp.cumsum(a, axis=1)
        total = cum[:, -1]                             # (Bt,H)
        CB = jnp.einsum("bqn,bsn->bqs", Cc, Bc,
                        preferred_element_type=jnp.float32)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (Bt,Q,S,H)
        t_i = jnp.arange(Q)
        mask = (t_i[:, None] >= t_i[None, :])[None, :, :, None]
        M = jnp.where(mask, CB[..., None] * dec, 0.0) \
            * dtc[:, None, :, :]                       # (Bt,Q,S,H) f32
        y = jnp.einsum("bqsh,bshp->bqhp", M.astype(cdt), xc,
                       preferred_element_type=jnp.float32)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bqn,bhnp->bqhp", Cc.astype(jnp.float32), h)
        w = (jnp.exp(total[:, None] - cum) * dtc)      # (Bt,Q,H) f32
        h = (h * jnp.exp(total)[..., None, None]
             + jnp.einsum("bsh,bsn,bshp->bhnp",
                          w, Bc.astype(jnp.float32),
                          xc.astype(jnp.float32)))
        return h, y.astype(cdt)

    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((Bt, H, N, P), jnp.float32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xr, dtf, Br, Cr))
    # Rematerialize the O(Q^2 H) decay tensor in the backward pass
    # instead of saving it per chunk — saving it was the dominant memory
    # term of the whole zamba2 train step (§Perf H1 iter 7).
    h_fin, ys = jax.lax.scan(jax.checkpoint(step), h_init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bt, L, H, P)
    if D_skip is not None:
        y = y + (D_skip.astype(cdt)[None, None, :, None] * x)
    if return_state:
        return y, h_fin
    return y

"""Public Mamba2 scan wrapper: dispatch, D-skip fusion, decode step."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import mamba2_scan_pallas
from .ref import mamba2_scan_chunked, mamba2_scan_ref

__all__ = ["mamba2_scan", "mamba2_decode_step"]


def mamba2_scan(x, dt, A, B, C, *, D_skip=None, h0=None,
                return_state: bool = False, impl: str = "auto",
                chunk: int = 256, interpret: bool | None = None):
    """Selective state-space scan.  Shapes as in ref.py."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "sequential":
        return mamba2_scan_ref(x, dt, A, B, C, D_skip=D_skip, h0=h0,
                               return_state=return_state)
    if impl == "reference":
        # block-parallel form: Q-times less state traffic than the
        # sequential scan (EXPERIMENTS.md §Perf H1)
        return mamba2_scan_chunked(x, dt, A, B, C, D_skip=D_skip, h0=h0,
                                   return_state=return_state,
                                   chunk=min(chunk, 256))
    L = x.shape[1]
    ch = min(chunk, L)
    while L % ch != 0:
        ch //= 2
    y, h_fin = mamba2_scan_pallas(x, dt, A, B, C, h0=h0, chunk=max(ch, 1),
                                  interpret=interpret)
    if D_skip is not None:
        y = y + (D_skip.astype(jnp.float32)[None, None, :, None]
                 * x.astype(jnp.float32)).astype(y.dtype)
    if return_state:
        return y, h_fin
    return y


def mamba2_decode_step(h, x_t, dt_t, A, B_t, C_t, *, D_skip=None):
    """One recurrence step for serving.  h: (Bt, H, N, P); x_t: (Bt, H, P);
    dt_t: (Bt, H); B_t, C_t: (Bt, N).  Returns (y_t, h_new)."""
    hf = h.astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(A.astype(jnp.float32)[None, :] * dtf)      # (Bt, H)
    dBx = jnp.einsum("bn,bhp->bhnp", B_t.astype(jnp.float32),
                     xf * dtf[..., None])
    h_new = hf * decay[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), h_new)
    if D_skip is not None:
        y = y + D_skip.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x_t.dtype), h_new

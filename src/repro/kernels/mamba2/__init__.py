from .ops import mamba2_scan, mamba2_decode_step
from .ref import mamba2_scan_chunked, mamba2_scan_ref
from .kernel import mamba2_scan_pallas
__all__ = ["mamba2_scan", "mamba2_decode_step", "mamba2_scan_ref", "mamba2_scan_chunked", "mamba2_scan_pallas"]

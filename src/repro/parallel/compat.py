"""jax API compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` (keyword
``check_rep``) to ``jax.shard_map`` (keywords ``axis_names`` /
``check_vma``).  Every manual-collective call site in this repo goes
through this wrapper so the repo runs on both sides of the migration.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "axis_size"]


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis inside a manual region.

    ``jax.lax.axis_size`` is the current API; older jax exposes the same
    number through ``jax.core.axis_frame`` (which returns either the
    size itself or a frame carrying it, depending on version).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` with the current signature, guarded for older
    jax.

    On jax with top-level ``jax.shard_map``, forwards ``axis_names`` and
    ``check_vma`` unchanged.  On older jax the experimental entry point
    is fully manual over *all* mesh axes and has no ``axis_names``; that
    is equivalent for our call sites (bodies never reference the
    unlisted axes and their operands are replicated across them), and
    ``check_vma`` maps onto the old ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)

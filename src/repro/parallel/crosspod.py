"""Cross-pod gradient compression with error feedback.

Within a pod, gradients reduce over fast ICI (GSPMD collectives).
Across pods the links are the scarce resource; this module implements
int8-compressed cross-pod all-reduce with error feedback (the residual
of quantization is carried to the next step, so compression introduces
no asymptotic bias) — 4x less cross-pod traffic than f32, ~2x less than
bf16.

Used via shard_map over the "pod" axis (examples/crosspod_sync.py) or
standalone on host arrays (the local-SGD / DiLoCo-style periodic sync in
runtime, where pods train independently for K steps and average
compressed deltas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "compressed_psum",
           "apply_error_feedback"]


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8: returns (q, scale)."""
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    shape=None) -> jax.Array:
    out = q.astype(jnp.float32) * scale
    if shape is not None:
        out = out.reshape(shape)
    return out


def apply_error_feedback(x: jax.Array, error: jax.Array
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (x + carried error); return (q, scale, new_error)."""
    corrected = x.astype(jnp.float32) + error
    q, scale = compress_int8(corrected)
    new_error = corrected - decompress_int8(q, scale)
    return q, scale, new_error


def compressed_psum(x: jax.Array, axis_name: str,
                    error: jax.Array | None = None):
    """int8-compressed psum over ``axis_name`` (inside shard_map).

    Quantizes the local contribution, psums the int8 payload upcast to
    int32 (exact), and rescales by the max scale — one all-reduce of
    ~1/4 the f32 bytes.  With ``error`` (same shape as x) applies error
    feedback and returns (result, new_error).
    """
    if error is not None:
        q, scale, new_error = apply_error_feedback(x, error)
    else:
        q, scale = compress_int8(x)
        new_error = None
    # Common scale across the axis keeps the sum exact in int32.
    smax = jax.lax.pmax(scale, axis_name)
    requant = jnp.clip(jnp.round(decompress_int8(q, scale) / smax),
                       -127, 127).astype(jnp.int32)
    total = jax.lax.psum(requant, axis_name)
    out = (total.astype(jnp.float32) * smax).astype(x.dtype)
    out = out.reshape(x.shape)
    if new_error is not None:
        return out, new_error
    return out

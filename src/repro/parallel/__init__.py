from .act_sharding import ActivationRules, activation_rules, shard_act
from .rules import STRATEGIES, ShardingPlan, make_plan
from .crosspod import (apply_error_feedback, compress_int8,
                       compressed_psum, decompress_int8)
from .overlap import all_gather_matmul, matmul_reduce_scatter
__all__ = ["ActivationRules", "activation_rules", "shard_act",
           "STRATEGIES", "ShardingPlan", "make_plan",
           "apply_error_feedback", "compress_int8", "compressed_psum",
           "decompress_int8", "all_gather_matmul", "matmul_reduce_scatter"]

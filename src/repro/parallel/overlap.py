"""Compute/communication overlap primitives (T4 on the interconnect).

The paper splits DMA transfers into chunks so loads hide under MAC
latency; the ICI analogue is the *collective matmul*: instead of one
blocking all-gather of the weight shards followed by one big matmul,
the ring is walked one shard at a time — each step's ``ppermute``
transfer overlaps the previous step's partial matmul (XLA schedules the
send/recv pair asynchronously on TPU).  ``core/dataflow.py``'s
``DistDecision.chunks`` picks the chunk count; this module provides the
shard_map-level implementations.

Used inside fully-manual shard_map bodies (see tests/test_overlap.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .compat import axis_size

__all__ = ["all_gather_matmul", "matmul_reduce_scatter"]


def _ring(axis_name):
    g = axis_size(axis_name)
    return [(i, (i + 1) % g) for i in range(g)]


def all_gather_matmul(x: jax.Array, w_shard: jax.Array,
                      axis_name: str) -> jax.Array:
    """x (M, K) replicated over ``axis_name``; w_shard (K, N/g) local.

    Computes ``x @ W_full`` (M, N) with the weight all-gather unrolled
    around the ring so every transfer overlaps a partial matmul — the
    weight-gathered (ICI-Kloop) execution with T4 chunking applied.
    """
    g = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    M = x.shape[0]
    Nl = w_shard.shape[1]
    buf = jnp.zeros((M, Nl * g), x.dtype)
    w = w_shard
    own = idx
    for _ in range(g):
        part = jnp.dot(x, w, preferred_element_type=jnp.float32)
        buf = jax.lax.dynamic_update_slice(
            buf, part.astype(x.dtype), (0, own * Nl))
        w = jax.lax.ppermute(w, axis_name, _ring(axis_name))
        own = (own - 1) % g
    return buf


def matmul_reduce_scatter(x: jax.Array, w_shard: jax.Array,
                          axis_name: str) -> jax.Array:
    """x (M, K/g local columns... i.e. x_shard (M, Kl)); w_shard (Kl, N).

    Computes the K-contracted ``X_full @ W_full`` reduce-scattered over
    N: returns this rank's (M, N/g) slice.  The ring accumulates partial
    products while they travel — each hop's transfer overlaps the next
    partial matmul (the activation-gathered / ICI-Mloop direction).
    """
    g = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    N = w_shard.shape[1]
    assert N % g == 0
    Nl = N // g
    acc = jnp.zeros((x.shape[0], Nl), jnp.float32)
    for step in range(g):
        # The accumulator visiting rank q at step t ends its journey at
        # rank (q - t - 1) + t+1 hops ... i.e. every visitor adds its
        # partial for the slice the FINAL holder owns: (idx - step - 1).
        target = (idx - step - 1) % g
        w_slice = jax.lax.dynamic_slice(
            w_shard, (0, target * Nl), (w_shard.shape[0], Nl))
        acc = acc + jnp.dot(x, w_slice,
                            preferred_element_type=jnp.float32)
        if step != g - 1:
            acc = jax.lax.ppermute(acc, axis_name, _ring(axis_name))
    return acc.astype(x.dtype)

"""Activation sharding hooks.

Models call ``shard_act(x, "hidden")`` at layer boundaries; a context
(installed by the launcher) maps logical activation names to
PartitionSpecs and applies ``with_sharding_constraint``.  Outside any
context (unit tests, single device) the hook is the identity, so model
code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["shard_act", "activation_rules", "ActivationRules"]

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_rules",
                                                      default=None)


class ActivationRules:
    """name -> PartitionSpec; unknown names pass through unsharded."""

    def __init__(self, specs: dict[str, P], mesh=None):
        self.specs = specs
        self.mesh = mesh

    def constrain(self, x: jax.Array, name: str) -> jax.Array:
        spec = self.specs.get(name)
        if spec is None:
            return x
        # Trim the spec to the array rank (specs are written for the
        # canonical rank; reduced ranks drop trailing axes) and drop
        # entries whose dimension the mesh axis does not divide.
        sizes = dict(self.mesh.shape) if self.mesh is not None else {}
        entries = list(spec)[:x.ndim]
        while len(entries) < x.ndim:
            entries.append(None)
        fixed = []
        for dim, e in zip(x.shape, entries):
            names = (e,) if isinstance(e, str) else tuple(e or ())
            total = 1
            for n in names:
                total *= sizes.get(n, 1)
            fixed.append(e if (total and dim % total == 0) else None)
        return jax.lax.with_sharding_constraint(x, P(*fixed))


@contextlib.contextmanager
def activation_rules(rules: ActivationRules | None):
    tok = _CTX.set(rules)
    try:
        yield
    finally:
        _CTX.reset(tok)


def shard_act(x: jax.Array, name: str) -> jax.Array:
    rules = _CTX.get()
    if rules is None:
        return x
    return rules.constrain(x, name)


def data_shards() -> int:
    """Product of the batch-carrying mesh axes in the active context
    (1 outside any mesh) — the block count for hierarchical dispatch."""
    rules = _CTX.get()
    if rules is None or rules.mesh is None:
        return 1
    sizes = dict(rules.mesh.shape)
    return sizes.get("pod", 1) * sizes.get("data", 1)

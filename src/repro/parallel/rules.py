"""Sharding-rule presets + the distributed Mloop/Kloop chooser.

Strategies per weight class (the ICI-level face of the paper's
loop-rearrangement decision, DESIGN.md T3):

* ``tp``    — activation-gathered (Megatron): weights sharded over
  "model"; activations all-gathered / partial sums reduce-scattered.
* ``fsdp``  — weight-gathered over the FLAT device axis (data x model
  [x pod]): batch is sharded over every axis, weights are ZeRO-3
  sharded over the same flat axis and all-gathered per layer.
* ``auto``  — two candidate layouts costed in bytes-moved per chip and
  the cheaper one chosen, exactly the paper's Mloop/Kloop logic lifted
  to ICI:
    layout A ("flat_dp"): pure weight-gathered; every axis carries
      batch.  ICI cost = 3 x frac x total weight bytes (fwd AG, bwd AG,
      grad RS).
    layout B ("mixed"): batch over data [x pod] only; per weight class
      the cheaper of weight-gathered-over-data / activation-gathered-
      over-model (choose_dist_strategy).
  Decode/prefill always use layout B (weights must stay sharded over
  "model"; batch is too small to cover the flat axis).

An earlier revision sharded FSDP weights over "data" only — the HLO
analyzer showed 3.9x replicated compute across the idle "model" axis;
layout A is the fix (EXPERIMENTS.md §Perf, iteration 0).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..core.dataflow import DistStrategy, choose_dist_strategy
from ..core.hw import TPU_V5E, HardwareModel, MeshDescriptor
from .act_sharding import ActivationRules

__all__ = ["ShardingPlan", "make_plan", "STRATEGIES"]

STRATEGIES = ("tp", "fsdp", "auto")

# Megatron-style: one "model" axis + FSDP over "data" on the other dim.
TP_RULES = {
    "vocab": "model", "embed": "data", "heads": "model",
    "kv_heads": "model", "ff": "model", "experts": "model",
    "layers": None,
}


def _flat_axes(mesh: MeshDescriptor) -> tuple:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axes)


def _fsdp_rules(mesh: MeshDescriptor) -> dict:
    flat = _flat_axes(mesh)
    return {k: flat for k in ("vocab", "embed", "heads", "kv_heads",
                              "ff", "experts")} | {"layers": None}


@dataclass
class ShardingPlan:
    strategy: str
    rules: dict                       # default logical->mesh rules
    overrides: dict = field(default_factory=dict)  # path-suffix -> rules
    act_specs: dict = field(default_factory=dict)
    batch_spec: P = P()
    decisions: dict = field(default_factory=dict)  # class -> chosen strategy

    def activation_rules(self, mesh=None) -> ActivationRules:
        return ActivationRules(self.act_specs, mesh)


def _dp(mesh: MeshDescriptor):
    if "pod" in mesh.axes:
        return ("pod", "data")
    return ("data",)


def _act_specs(mesh: MeshDescriptor, *, dp, tp_acts: bool) -> dict:
    return {
        "hidden": P(dp, None, None),
        "logits": P(dp, None, "model" if tp_acts else None),
        "attn_q": P(dp, "model" if tp_acts else None, None, None),
        # dispatch buffers shard on D/F so data-dependent scatter/gather
        # partition cleanly (§Perf H3)
        "moe_buf": P(None, None, "model"),
        "moe_h": P(None, None, "model"),
    }


def _weight_classes(cfg: ArchConfig) -> dict:
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": (D, H * hd), "wk": (D, KV * hd), "wv": (D, KV * hd),
        "wo": (H * hd, D),
        "w_gate": (D, F), "w_up": (D, F), "w_down": (F, D),
        "embed": (V, D), "lm_head": (D, V),
    }


def make_plan(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshDescriptor,
              strategy: str = "auto",
              hw: HardwareModel = TPU_V5E) -> ShardingPlan:
    dp = _dp(mesh)
    flat = _flat_axes(mesh)
    if strategy == "tp":
        return ShardingPlan("tp", TP_RULES, {},
                            _act_specs(mesh, dp=dp, tp_acts=True),
                            P(dp, None))
    if strategy == "fsdp":
        return ShardingPlan("fsdp", _fsdp_rules(mesh), {},
                            _act_specs(mesh, dp=flat, tp_acts=False),
                            P(flat, None))

    assert strategy == "auto", strategy
    classes = _weight_classes(cfg)
    n_layers = cfg.n_layers
    total_tokens = (shape.seq_len * shape.global_batch
                    if shape.kind != "decode" else shape.global_batch)

    # Sequence-parallel layout for prefill when TP would have to shard a
    # head count the model axis does not divide (e.g. smollm's 15 heads
    # on 16): GSPMD's padded-head resharding degenerates into
    # "last-resort replication" per layer (§Perf H2).  Sharding the
    # sequence instead keeps every chip busy on position-wise work and
    # only gathers the (tiny, GQA) per-layer K/V for attention.
    if (shape.kind == "prefill" and mesh.model > 1
            and (cfg.n_heads % mesh.model or cfg.n_kv_heads % mesh.model)
            and cfg.family in ("dense", "moe", "vlm")
            and shape.seq_len % mesh.model == 0):
        act = {
            "hidden": P(dp, "model", None),
            "logits": P(dp, "model", None),
            "attn_q": P(dp, None, None, None),
            "attn_kv": P(dp, None, None, None),  # replicate small GQA KV
            "moe_buf": P(None, None, "model"),
            "moe_h": P(None, None, "model"),
        }
        rules = {k: "data" for k in ("vocab", "embed", "heads",
                                     "kv_heads", "ff", "experts")}
        rules["layers"] = None
        return ShardingPlan("auto", rules, {}, act, P(dp, None),
                            {"layout": "sequence_parallel"})

    # --- layout B: mixed TP/FSDP, batch over data [x pod] ---------------------
    tokens_local_b = max(total_tokens // max(mesh.data, 1), 1)
    decisions = {}
    overrides = {}
    cost_b = 0.0
    n_act_gathered = 0
    train_mult_wg = 3.0 if shape.kind == "train" else 1.0
    train_mult_ag = 2.0 if shape.kind == "train" else 1.0
    g_model = mesh.model
    frac_m = (g_model - 1) / g_model if g_model > 1 else 0.0
    for name, (Kd, Nd) in classes.items():
        per_layer = (n_layers if name not in ("embed", "lm_head") else 1)
        dec = choose_dist_strategy(tokens_local_b, Kd, Nd, 2, mesh, hw)
        decisions[name] = dec.strategy.value
        if dec.strategy is DistStrategy.ACTIVATION_GATHERED:
            overrides[name] = TP_RULES
            n_act_gathered += 1
            cost_b += train_mult_ag * dec.ici_bytes_per_chip * per_layer
        else:
            overrides[name] = {k: "data" for k in
                               ("vocab", "embed", "heads", "kv_heads",
                                "ff", "experts")} | {"layers": None}
            cost_b += train_mult_wg * dec.ici_bytes_per_chip * per_layer

    # --- layout A: flat DP + full ZeRO-3 (train only) --------------------------
    n_flat = mesh.n_chips
    frac_f = (n_flat - 1) / n_flat
    w_total = sum(Kd * Nd * 2 * (n_layers if n not in ("embed", "lm_head")
                                 else 1)
                  for n, (Kd, Nd) in classes.items())
    cost_a = 3.0 * frac_f * w_total
    feasible_a = (shape.kind == "train" and not cfg.n_experts
                  and shape.global_batch % n_flat == 0)

    # Step-time objective: bytes alone cannot see an idle mesh axis.
    # Compute parallelism: layout A uses every chip; layout B uses the
    # model axis only for activation-gathered (TP) classes.
    link_bw = hw.ici_bandwidth * max(hw.ici_links_per_axis, 1)
    model_flops = 6.0 * cfg.n_active_params() * total_tokens \
        if shape.kind == "train" else 2.0 * cfg.n_active_params() * total_tokens
    ffn_tp = any(decisions.get(c) == "activation_gathered"
                 for c in ("w_gate", "w_up", "w_down", "wq"))
    chips_b = mesh.data * (mesh.model if ffn_tp else 1)
    t_b = max(model_flops / (chips_b * hw.peak_flops), cost_b / link_bw)
    t_a = max(model_flops / (n_flat * hw.peak_flops), cost_a / link_bw) \
        if feasible_a else float("inf")

    if t_a < t_b:
        return ShardingPlan(
            "auto", _fsdp_rules(mesh), {},
            _act_specs(mesh, dp=flat, tp_acts=False), P(flat, None),
            {"layout": "flat_dp", "ici_bytes_per_chip": cost_a,
             "alternative_ici": cost_b, "t_a": t_a, "t_b": t_b})

    # Degenerate layout B (no class uses the model axis): force the big
    # classes to TP so compute parallelism covers the whole mesh.
    if not ffn_tp and mesh.model > 1:
        for c in ("w_gate", "w_up", "w_down", "wq", "wk", "wv", "wo"):
            overrides[c] = TP_RULES
            decisions[c] = "activation_gathered(forced: idle model axis)"
        n_act_gathered = len(classes)

    # MoE experts: shard the expert matmuls on their contraction dims
    # ("embed"/"ff" over model) to pair with the D-sharded dispatch
    # buffers; experts-dim sharding forced scatter replication (§Perf H3).
    if cfg.n_experts:
        MOE_W_RULES = {"experts": None, "embed": "model", "ff": "model",
                       "vocab": None, "heads": None, "kv_heads": None,
                       "layers": None}
        overrides["router"] = {k: "data" for k in TP_RULES} | {"layers": None}
        for w in ("w_gate", "w_up", "w_down"):
            overrides[f"moe_blocks/{w}"] = MOE_W_RULES
        decisions["experts"] = "expert_tp_on_d"
        if cfg.moe_every == 1:
            for w in ("w_gate", "w_up", "w_down"):
                overrides[w] = MOE_W_RULES
    # Vocab-TP head when divisible: zero extra comm (activations are
    # model-replicated there) and 1/model-size per-chunk logits.
    if cfg.vocab % mesh.model == 0:
        overrides["embed"] = TP_RULES
        overrides["lm_head"] = TP_RULES
        decisions["embed"] = decisions["lm_head"] = "vocab_tp"
    tp_acts = n_act_gathered >= len(classes) // 2
    decisions["layout"] = "mixed"
    decisions["ici_bytes_per_chip"] = cost_b
    base_rules = {k: "data" for k in ("vocab", "embed", "heads",
                                      "kv_heads", "ff", "experts")}
    base_rules["layers"] = None
    return ShardingPlan("auto", base_rules, overrides,
                        _act_specs(mesh, dp=dp, tp_acts=tp_acts),
                        P(dp, None), decisions)

"""The 10 assigned architectures — exact public configurations.

Provenance tags follow the assignment sheet; each CONFIG is re-exported
by its own module (``configs/<id with _>.py``) so ``--arch <id>`` maps
to one file per architecture.
"""
from __future__ import annotations

from .base import ArchConfig

ZAMBA2_7B = ArchConfig(
    # [arXiv:2411.15242; unverified] — Mamba2 backbone + shared attn blocks.
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, head_dim=112,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6,
    attn_window=4096,        # TPU adaptation: windowed shared attention
    sub_quadratic=True,      # Mamba2 backbone -> long_500k runs
    source="arXiv:2411.15242")

MAMBA2 = ArchConfig(
    # [arXiv:2405.21060; unverified] — pure SSD backbone, no attention:
    # shared_attn_every=0 drops the hybrid family's shared block, so
    # every layer is one selective-scan mixer with O(1) decode state.
    name="mamba2", family="hybrid",
    n_layers=64, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=10240,
    vocab=50288, head_dim=128,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=0,
    sub_quadratic=True, source="arXiv:2405.21060")

DEEPSEEK_7B = ArchConfig(
    # [arXiv:2401.02954; hf] — llama-arch dense.
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab=102400, source="arXiv:2401.02954")

OLMO_1B = ArchConfig(
    # [arXiv:2402.00838; hf] — non-parametric LayerNorm.
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, norm="nonparametric", source="arXiv:2402.00838")

SMOLLM_360M = ArchConfig(
    # [hf:HuggingFaceTB/SmolLM-360M; hf] — small llama-arch, GQA 15/5.
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, head_dim=64, source="hf:HuggingFaceTB/SmolLM-360M")

LLAMA3_8B = ArchConfig(
    # [arXiv:2407.21783; unverified] — GQA, 128k vocab.
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, rope_theta=500000.0, source="arXiv:2407.21783")

RWKV6_7B = ArchConfig(
    # [arXiv:2404.05892; hf] — Finch, attention-free, data-dependent decay.
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65536, head_dim=64, norm="layernorm",
    sub_quadratic=True, source="arXiv:2404.05892")

WHISPER_BASE = ArchConfig(
    # [arXiv:2212.04356; unverified] — enc-dec; conv frontend is a stub.
    name="whisper-base", family="audio",
    n_layers=6, n_encoder_layers=6, encoder_seq=1500,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
    norm="layernorm", gated_mlp=False, activation="gelu",
    tie_embeddings=True, max_pos=32768, source="arXiv:2212.04356")

GRANITE_MOE_1B = ArchConfig(
    # [hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — 32 experts top-8.
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49155, n_experts=32, top_k=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base")

LLAMA4_MAVERICK = ArchConfig(
    # [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — MoE 128e top-1.
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, n_experts=128, top_k=1, moe_every=2,
    rope_theta=500000.0, source="hf:meta-llama/Llama-4-Scout-17B-16E")

LLAMA32_VISION_11B = ArchConfig(
    # [hf:meta-llama/Llama-3.2-11B-Vision; unverified] — cross-attn image
    # layers every 5th layer; vision tower is a stub.
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, cross_attn_every=5, n_vision_tokens=1601,
    rope_theta=500000.0, source="hf:meta-llama/Llama-3.2-11B-Vision")

ALL_ARCHS = (ZAMBA2_7B, MAMBA2, DEEPSEEK_7B, OLMO_1B, SMOLLM_360M,
             LLAMA3_8B, RWKV6_7B, WHISPER_BASE, GRANITE_MOE_1B,
             LLAMA4_MAVERICK, LLAMA32_VISION_11B)

"""The paper's own CNN workloads: AlexNetOWT, ResNet18, ResNet50.

Layer tables match the paper's Table 1 conv parameters exactly
(AlexNetOWT conv2..5: 27x27,5x5,64,192 / 13x13,3x3,192,384 /
13x13,3x3,384,256 / 13x13,3x3,256,256) and torchvision's
ResNet18/ResNet50 shapes (the paper benchmarks fb.resnet.torch
pretrained ResNet18).
"""
from __future__ import annotations

from .base import CNNConfig, CNNLayer

C = CNNLayer


def _alexnet_owt() -> CNNConfig:
    return CNNConfig(
        name="alexnet-owt", input_hw=224, input_ch=3,
        layers=(
            C("conv", 64, 11, 4, 2),            # -> 55x55x64
            C("maxpool", k=3, stride=2),        # -> 27x27
            C("conv", 192, 5, 1, 2),            # Table1 row 1
            C("maxpool", k=3, stride=2),        # -> 13x13
            C("conv", 384, 3, 1, 1),            # Table1 row 2
            C("conv", 256, 3, 1, 1),            # Table1 row 3
            C("conv", 256, 3, 1, 1),            # Table1 row 4
            C("maxpool", k=3, stride=2),        # -> 6x6
            C("fc", 4096), C("fc", 4096), C("fc", 1000, activation=None),
        ))


def _basic_block(layers, c, stride, project):
    """ResNet18 basic block: main path conv-conv, optional projection
    shortcut on a parallel path, add fused into the last conv."""
    idx0 = len(layers) - 1                      # the block's input layer
    if project:
        layers.append(C("conv", c, 1, stride, 0, activation=None,
                        input_of=idx0))
        short = len(layers) - 1
    else:
        short = idx0
    layers.append(C("conv", c, 3, stride, 1, input_of=idx0))
    layers.append(C("conv", c, 3, 1, 1, activation="relu",
                    bypass_of=short))
    return layers


def _resnet18() -> CNNConfig:
    layers = [
        C("conv", 64, 7, 2, 3),                 # -> 112
        C("maxpool", k=3, stride=2, pad=1),     # -> 56
    ]
    for c, blocks, stride in ((64, 2, 1), (128, 2, 2),
                              (256, 2, 2), (512, 2, 2)):
        for b in range(blocks):
            s = stride if b == 0 else 1
            _basic_block(layers, c, s, b == 0 and stride != 1)
    layers.append(C("avgpool", k=7, stride=7))
    layers.append(C("fc", 1000, activation=None))
    return CNNConfig(name="resnet18", input_hw=224, input_ch=3,
                     layers=tuple(layers))


def _bottleneck(layers, c, stride, project):
    idx0 = len(layers) - 1
    if project:
        layers.append(C("conv", 4 * c, 1, stride, 0, activation=None,
                        input_of=idx0))
        short = len(layers) - 1
    else:
        short = idx0
    layers.append(C("conv", c, 1, 1, 0, input_of=idx0))
    layers.append(C("conv", c, 3, stride, 1))
    layers.append(C("conv", 4 * c, 1, 1, 0, activation="relu",
                    bypass_of=short))
    return layers


def _resnet50() -> CNNConfig:
    layers = [
        C("conv", 64, 7, 2, 3),
        C("maxpool", k=3, stride=2, pad=1),
    ]
    for c, blocks, stride in ((64, 3, 1), (128, 4, 2),
                              (256, 6, 2), (512, 3, 2)):
        for b in range(blocks):
            _bottleneck(layers, c, stride if b == 0 else 1, b == 0)
    layers.append(C("avgpool", k=7, stride=7))
    layers.append(C("fc", 1000, activation=None))
    return CNNConfig(name="resnet50", input_hw=224, input_ch=3,
                     layers=tuple(layers))


ALEXNET_OWT = _alexnet_owt()
RESNET18 = _resnet18()
RESNET50 = _resnet50()
ALL_CNNS = (ALEXNET_OWT, RESNET18, RESNET50)

"""Architecture + shape configuration system.

One ``ArchConfig`` per assigned architecture (exact public numbers) plus
the paper's own CNNs.  ``smoke()`` derives the reduced same-family
config used by CPU smoke tests; the full config is only ever lowered
abstractly (dry-run).  ``ShapeSpec`` carries the assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeSpec", "LM_SHAPES", "CNNLayer", "CNNConfig"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    # MoE.
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1        # a MoE layer every N layers (llama4: 2)
    # SSM (Mamba2).
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    # Hybrid (zamba2): shared attention block applied every N layers.
    shared_attn_every: int = 0
    attn_window: int | None = None       # sliding window for the attn block
    # Encoder-decoder (whisper): n_layers is the decoder depth.
    n_encoder_layers: int = 0
    encoder_seq: int = 0                 # stub frame count
    # VLM: a cross-attention sub-block every N layers.
    cross_attn_every: int = 0
    n_vision_tokens: int = 0
    # Norm / misc.
    norm: str = "rmsnorm"                # rmsnorm | layernorm | nonparametric
    gated_mlp: bool = True
    activation: str = "silu"
    rope_theta: float = 10000.0
    max_pos: int = 0                     # >0: learned absolute positions
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    kv_dtype: str = ""                   # "" -> same as dtype; "float8"
                                         # halves decode-cache HBM (serving)
    # Which shape set applies; long-context support flag.
    sub_quadratic: bool = False          # True -> long_500k runnable
    source: str = ""                     # provenance note

    # ---- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def kv_jdtype(self):
        if not self.kv_dtype:
            return self.jdtype
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "float8": jnp.float8_e4m3fn}[self.kv_dtype]

    def n_params(self) -> float:
        """Analytic parameter count (embeddings included once)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        qkv = D * (self.n_heads + 2 * self.n_kv_heads) * self.hd
        o = self.n_heads * self.hd * D
        glu = 3 if self.gated_mlp else 2
        if self.family == "ssm":     # rwkv6-style
            block = 6 * D * D + 2 * D * F   # r,k,v,g,out,cr + channel-mix
        elif self.family == "hybrid":   # mamba2 backbone
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            block = D * (2 * di + 2 * N + H) + di * D
        else:
            dense_mlp = glu * D * F
            if self.n_experts:
                n_moe = L // self.moe_every
                mlp_total = (n_moe * (dense_mlp * self.n_experts
                                      + D * self.n_experts)
                             + (L - n_moe) * dense_mlp)
                block = qkv + o + mlp_total / L
            else:
                block = qkv + o + dense_mlp
        total = L * block + V * D * (1 if self.tie_embeddings else 2)
        if self.shared_attn_every:
            total += qkv + o + 3 * D * F           # one shared block
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (qkv + o + 2 * D * F)
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (qkv + o)
        return float(total)

    def n_active_params(self) -> float:
        if not self.n_experts:
            return self.n_params()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        glu = 3 if self.gated_mlp else 2
        n_moe = L // self.moe_every
        dense = dataclasses.replace(self, n_experts=0, top_k=0)
        act = (dense.n_params()
               - n_moe * glu * D * F                       # swap moe layers'
               + n_moe * glu * D * F * self.top_k)         # dense mlp for top-k
        return float(act)

    def shapes(self) -> tuple[ShapeSpec, ...]:
        out = []
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.sub_quadratic:
                continue
            out.append(s)
        return tuple(out)

    def skipped_shapes(self) -> tuple[str, ...]:
        if not self.sub_quadratic:
            return ("long_500k",)
        return ()

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        def shrink(v, lo, hi):
            return max(lo, min(v, hi))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=shrink(self.n_layers, 2, 4),
            d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2)
            if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq=16 if self.n_encoder_layers else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            attn_window=min(self.attn_window, 64) if self.attn_window else None,
            dtype="float32",
        )


# --- CNN configs (the paper's own models) --------------------------------------
@dataclass(frozen=True)
class CNNLayer:
    kind: str            # conv | maxpool | avgpool | fc
    c_out: int = 0
    k: int = 1
    stride: int = 1
    pad: int = 0
    activation: str | None = "relu"
    bypass_of: int | None = None   # layer index whose output is added
    bypass_first: bool = True      # ResNet order: add bypass, then ReLU
    input_of: int | None = None    # take input from this layer (default:
                                   # the previous one); enables parallel
                                   # paths like projection shortcuts


@dataclass(frozen=True)
class CNNConfig:
    name: str
    input_hw: int
    input_ch: int
    layers: tuple[CNNLayer, ...]
    n_classes: int = 1000
    dtype: str = "float32"

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

"""--arch config module; canonical definition in archs.py."""
from .archs import SMOLLM_360M as CONFIG

SMOKE = CONFIG.smoke()

"""--arch config module; canonical definition in archs.py."""
from .archs import MAMBA2 as CONFIG

SMOKE = CONFIG.smoke()

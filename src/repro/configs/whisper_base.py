"""--arch config module; canonical definition in archs.py."""
from .archs import WHISPER_BASE as CONFIG

SMOKE = CONFIG.smoke()

"""Paper-native CNN workload config."""
from .cnns import ALEXNET_OWT as CONFIG

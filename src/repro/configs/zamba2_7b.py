"""--arch config module; canonical definition in archs.py."""
from .archs import ZAMBA2_7B as CONFIG

SMOKE = CONFIG.smoke()

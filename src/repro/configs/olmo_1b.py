"""--arch config module; canonical definition in archs.py."""
from .archs import OLMO_1B as CONFIG

SMOKE = CONFIG.smoke()

"""--arch config module; canonical definition in archs.py."""
from .archs import DEEPSEEK_7B as CONFIG

SMOKE = CONFIG.smoke()

"""Paper-native CNN workload config."""
from .cnns import RESNET18 as CONFIG

"""--arch config module; canonical definition in archs.py."""
from .archs import RWKV6_7B as CONFIG

SMOKE = CONFIG.smoke()

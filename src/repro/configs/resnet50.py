"""Paper-native CNN workload config."""
from .cnns import RESNET50 as CONFIG

"""--arch config module; canonical definition in archs.py."""
from .archs import GRANITE_MOE_1B as CONFIG

SMOKE = CONFIG.smoke()

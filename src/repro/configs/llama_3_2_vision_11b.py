"""--arch config module; canonical definition in archs.py."""
from .archs import LLAMA32_VISION_11B as CONFIG

SMOKE = CONFIG.smoke()

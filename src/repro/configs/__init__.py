"""Config registry: --arch <id> -> ArchConfig / CNNConfig."""
from .base import ArchConfig, CNNConfig, CNNLayer, LM_SHAPES, ShapeSpec
from .archs import (ALL_ARCHS, DEEPSEEK_7B, GRANITE_MOE_1B, LLAMA3_8B,
                    LLAMA32_VISION_11B, LLAMA4_MAVERICK, MAMBA2, OLMO_1B,
                    RWKV6_7B, SMOLLM_360M, WHISPER_BASE, ZAMBA2_7B)
from .cnns import ALEXNET_OWT, ALL_CNNS, RESNET18, RESNET50

REGISTRY = {c.name: c for c in ALL_ARCHS}
CNN_REGISTRY = {c.name: c for c in ALL_CNNS}


def get_config(name: str):
    if name in REGISTRY:
        return REGISTRY[name]
    if name in CNN_REGISTRY:
        return CNN_REGISTRY[name]
    if name.endswith("-smoke"):
        return REGISTRY[name[: -len("-smoke")]].smoke()
    raise KeyError(f"unknown arch {name!r}; known: "
                   f"{sorted(REGISTRY) + sorted(CNN_REGISTRY)}")


__all__ = ["ArchConfig", "CNNConfig", "CNNLayer", "LM_SHAPES", "ShapeSpec",
           "REGISTRY", "CNN_REGISTRY", "get_config", "ALL_ARCHS", "ALL_CNNS"]

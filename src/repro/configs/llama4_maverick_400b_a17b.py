"""--arch config module; canonical definition in archs.py."""
from .archs import LLAMA4_MAVERICK as CONFIG

SMOKE = CONFIG.smoke()

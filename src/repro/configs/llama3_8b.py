"""--arch config module; canonical definition in archs.py."""
from .archs import LLAMA3_8B as CONFIG

SMOKE = CONFIG.smoke()

"""Communication load balancing (paper §6.3, T4).

Snowflake has 4 load/store units; the paper shows (Table 3) that
splitting large DMA transfers into chunks spread evenly across units —
minimizing the percent-imbalance metric C_L = (L_max / mu_L - 1) * 100 —
recovers up to 1.66x, saturating once transfers fully overlap compute.

On TPU the "units" generalize to (a) DMA streams the Pallas pipeline can
keep in flight, (b) ICI links per mesh axis, and (c) experts in a MoE
layer (token routing is a load-balancing problem with the same metric).
This module provides the metric, a greedy LPT balancer, the transfer
splitter, and MoE capacity planning.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

__all__ = [
    "percent_imbalance",
    "assign_lpt",
    "split_transfer",
    "balance_transfers",
    "speedup_model",
    "moe_capacity",
]


def percent_imbalance(loads: Sequence[float]) -> float:
    """C_L = (L_max / mu_L - 1) * 100  (paper eq. 1)."""
    loads = list(loads)
    if not loads:
        return 0.0
    mu = sum(loads) / len(loads)
    if mu == 0:
        return 0.0
    return (max(loads) / mu - 1.0) * 100.0


def assign_lpt(items: Sequence[float], n_units: int) -> list[list[int]]:
    """Longest-processing-time-first greedy partition of item indices
    onto ``n_units`` units.  Classic 4/3-approximation; what the paper's
    compiler does when spreading kernel+maps loads over load units."""
    units: list[list[int]] = [[] for _ in range(n_units)]
    totals = [0.0] * n_units
    for idx in sorted(range(len(items)), key=lambda i: -items[i]):
        u = min(range(n_units), key=lambda j: totals[j])
        units[u].append(idx)
        totals[u] += items[idx]
    return units


def split_transfer(total_bytes: int, n_chunks: int,
                   granule: int = 512) -> list[int]:
    """Split one large transfer into ``n_chunks`` granule-aligned chunks
    (paper: 'better to break a single large load transaction into
    multiple smaller loads')."""
    if n_chunks <= 1 or total_bytes <= granule:
        return [total_bytes]
    per = round_to_granule(total_bytes / n_chunks, granule)
    chunks = [per] * (n_chunks - 1)
    last = total_bytes - per * (n_chunks - 1)
    if last <= 0:   # over-split; shrink chunk count
        return split_transfer(total_bytes, n_chunks - 1, granule)
    chunks.append(last)
    return chunks


def round_to_granule(x: float, granule: int) -> int:
    return max(granule, int(math.ceil(x / granule)) * granule)


@dataclass(frozen=True)
class BalanceResult:
    assignments: list[list[int]]   # unit -> chunk indices
    chunk_bytes: list[int]
    imbalance_before: float
    imbalance_after: float


def balance_transfers(transfers: Sequence[int], n_units: int,
                      granule: int = 512,
                      max_chunks_per_transfer: int = 8) -> BalanceResult:
    """Chunk + LPT-balance a set of transfers across units.

    The un-balanced baseline assigns whole transfers round-robin (the
    paper's 'single map load to a unit while distributing kernels').
    """
    before = [0.0] * n_units
    for i, t in enumerate(transfers):
        before[i % n_units] += t
    imb_before = percent_imbalance(before)

    total = sum(transfers)
    target = total / n_units if n_units else 0
    chunks: list[int] = []
    for t in transfers:
        n = 1
        if target > 0 and t > target:
            n = min(max_chunks_per_transfer, max(1, round(t / target)))
        chunks.extend(split_transfer(t, n, granule))
    assign = assign_lpt(chunks, n_units)
    after = [sum(chunks[i] for i in unit) for unit in assign]
    imb_after = percent_imbalance(after)
    if imb_after > imb_before:
        # LPT is a 4/3-approximation; keep the round-robin baseline when
        # it happens to be better (never regress — the paper's Table 3
        # compares against the unbalanced baseline).
        assign = [[i for i in range(len(transfers)) if i % n_units == u]
                  for u in range(n_units)]
        return BalanceResult(assign, list(transfers), imb_before,
                             imb_before)
    return BalanceResult(assign, chunks, imb_before, imb_after)


def speedup_model(imbalance_pct: float, compute_time: float,
                  balanced_load_time: float) -> float:
    """Execution-time model behind the paper's Table 3.

    Per-unit transfer time scales with (1 + C_L/100); transfers overlap
    compute (double buffering), so step time = max(compute, slowest
    unit).  Speedup is measured against the worst recorded imbalance —
    the saturation shape of Table 3 falls out of the max()."""
    load_time = balanced_load_time * (1.0 + imbalance_pct / 100.0)
    return max(compute_time, load_time)


# --- MoE capacity planning (T4 applied to expert parallelism) --------------------
@dataclass(frozen=True)
class MoECapacity:
    capacity_per_expert: int
    capacity_factor: float
    expected_imbalance_pct: float


def moe_capacity(tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25,
                 granule: int = 8) -> MoECapacity:
    """Capacity-bounded dispatch sizing.  Routing concentrates load; the
    capacity factor bounds the worst-unit load exactly like the paper's
    chunk splitting bounds L_max."""
    mean = tokens * top_k / n_experts
    cap = int(math.ceil(mean * capacity_factor / granule)) * granule
    cap = max(granule, cap)
    exp_imb = (cap / max(mean, 1e-9) - 1.0) * 100.0
    return MoECapacity(cap, capacity_factor, exp_imb)

"""Fixed-point simulation + int8 quantization (paper §5.3, T6).

The paper validates hardware results layer-by-layer against a Q8.8
software oracle and reports Q8.8 / Q5.11 ImageNet accuracy.  Q(m).(f) is
a 16-bit signed fixed-point format with ``f`` fractional bits.  We keep
that oracle (bit-accurate int arithmetic in JAX) for validation, and add
a per-channel int8 path as the deployable TPU quantization.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = [
    "QFormat",
    "Q8_8",
    "Q5_11",
    "quantize",
    "dequantize",
    "qmatmul",
    "validate_layerwise",
    "int8_quantize_per_channel",
    "int8_matmul",
    "int8_quantize_pages",
    "int8_dequantize_pages",
    "int8_requantize_page",
]


@dataclass(frozen=True)
class QFormat:
    """Signed fixed point with ``int_bits`` integer and ``frac_bits``
    fractional bits (total = 1 sign + int + frac = 16 for the paper)."""

    int_bits: int
    frac_bits: int

    @property
    def total_bits(self) -> int:
        return 1 + self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return float(1 << self.frac_bits)

    @property
    def qmin(self) -> int:
        return -(1 << (self.total_bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.total_bits - 1)) - 1


Q8_8 = QFormat(int_bits=7, frac_bits=8)     # the paper's "Q8.8"
Q5_11 = QFormat(int_bits=4, frac_bits=11)   # the paper's "Q5.11"


def quantize(x: jax.Array, fmt: QFormat = Q8_8) -> jax.Array:
    """float -> int16 fixed point with saturation (round-to-nearest)."""
    q = jnp.round(x * fmt.scale)
    q = jnp.clip(q, fmt.qmin, fmt.qmax)
    return q.astype(jnp.int16 if fmt.total_bits <= 16 else jnp.int32)


def dequantize(q: jax.Array, fmt: QFormat = Q8_8) -> jax.Array:
    return q.astype(jnp.float32) / fmt.scale


def qmatmul(a_q: jax.Array, b_q: jax.Array, fmt: QFormat = Q8_8,
            bias_q: jax.Array | None = None,
            relu: bool = False) -> jax.Array:
    """Bit-accurate fixed-point matmul as Snowflake's MACs execute it:
    int16 x int16 -> int32 accumulate, then a single arithmetic right
    shift by ``frac_bits`` with saturation back to int16.

    This is the 'software implementation ... using Q8.8 to simulate
    Snowflake's compute operations' the paper uses for result checking.
    """
    acc = jnp.matmul(a_q.astype(jnp.int32), b_q.astype(jnp.int32))
    if bias_q is not None:
        acc = acc + (bias_q.astype(jnp.int32) << fmt.frac_bits)
    out = acc >> fmt.frac_bits          # arithmetic shift (floor)
    if relu:
        out = jnp.maximum(out, 0)
    out = jnp.clip(out, fmt.qmin, fmt.qmax)
    return out.astype(jnp.int16)


def validate_layerwise(float_outs: list[jax.Array],
                       quant_outs: list[jax.Array],
                       fmt: QFormat = Q8_8) -> list[dict]:
    """Layer-by-layer result checking (paper §5.3): compare the float
    reference against the dequantized fixed-point path; report max-abs
    and RMS error per layer in units of one LSB."""
    report = []
    lsb = 1.0 / fmt.scale
    for i, (f, q) in enumerate(zip(float_outs, quant_outs)):
        deq = dequantize(q, fmt) if jnp.issubdtype(q.dtype, jnp.integer) else q
        err = jnp.abs(f.astype(jnp.float32) - deq)
        report.append({
            "layer": i,
            "max_abs_err_lsb": float(jnp.max(err) / lsb),
            "rms_err_lsb": float(jnp.sqrt(jnp.mean(err ** 2)) / lsb),
        })
    return report


# --- int8 (deployable TPU quantization) ------------------------------------------
def int8_quantize_per_channel(w: jax.Array, axis: int = 0
                              ) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel int8 weight quantization."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_matmul(x: jax.Array, w_q: jax.Array, w_scale: jax.Array
                ) -> jax.Array:
    """bf16 activations x int8 weights, dequantized on the fly — the
    bandwidth-saving inference path (halves the Mloop/Kloop weight-bytes
    term, which the dataflow cost model sees through dtype_bytes=1)."""
    acc = jnp.matmul(x.astype(jnp.float32),
                     w_q.astype(jnp.float32) * w_scale)
    return acc.astype(x.dtype)


# --- int8 KV pages (paged region plan, §5.1) --------------------------------------
def int8_quantize_pages(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-page int8 quantization of a page-shaped array.

    ``x`` is (n_pages, ...) — every axis after the first belongs to one
    page (rows, kv_heads, head_dim for a KV pool).  One float32 scale
    per page: scale = amax(page)/127, with empty/zero pages mapped to
    scale 1.0 so dequantization is always well-defined.  Returns
    (q int8 of x.shape, scales (n_pages,) float32)."""
    axes = tuple(range(1, x.ndim))
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    sh = scale.reshape((-1,) + (1,) * (x.ndim - 1))
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / sh), -127, 127)
    return q.astype(jnp.int8), scale


def int8_dequantize_pages(q: jax.Array, scales: jax.Array) -> jax.Array:
    """Inverse of :func:`int8_quantize_pages` — broadcast each page's
    scale back over its rows."""
    sh = scales.reshape((-1,) + (1,) * (q.ndim - 1))
    return q.astype(jnp.float32) * sh


def int8_requantize_page(q: jax.Array, old_scale: jax.Array,
                         new_scale: jax.Array) -> jax.Array:
    """Re-express an int8 page under a larger scale: q * old/new,
    rounded.  Exact (a round of integers) when the scale is unchanged —
    the common decode case, where a new row's magnitude fits the page's
    existing scale and only that row is rewritten."""
    ratio = jnp.asarray(old_scale / new_scale)
    if ratio.ndim == 1 and q.ndim > 1:        # (n_pages,) over page axes
        ratio = ratio.reshape((-1,) + (1,) * (q.ndim - 1))
    return jnp.clip(jnp.round(q.astype(jnp.float32) * ratio),
                    -127, 127).astype(jnp.int8)

"""Hardware models.

The paper's compiler reasons about one accelerator (Snowflake on a Zynq
XC7Z045).  This framework generalizes the same decision inputs — peak
compute, off-chip bandwidth, on-chip buffer capacity, number of load
streams — into a ``HardwareModel`` consumed by the tiling engine
(core/tiling.py), the loop-order cost model (core/dataflow.py), the load
balancer (core/balance.py) and the roofline calculator (core/roofline.py).

Two concrete models ship:

* ``TPU_V5E`` — the deployment target for this repo (kernels, dry-run,
  roofline).  Constants follow the assignment spec: 197 TFLOP/s bf16,
  819 GB/s HBM, ~50 GB/s/link ICI.
* ``SNOWFLAKE`` — the paper's FPGA accelerator, used by the benchmark
  suite to reproduce the paper's Tables 1-3 and Figure 4 analytically.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

__all__ = [
    "HardwareModel",
    "MeshDescriptor",
    "TPU_V5E",
    "SNOWFLAKE",
    "SINGLE_POD",
    "MULTI_POD",
]


@dataclass(frozen=True)
class HardwareModel:
    """Per-chip hardware constants used by every compiler decision."""

    name: str
    # Compute.
    peak_flops: float            # FLOP/s at the native compute dtype
    compute_dtype_bytes: int     # bytes of the MAC operand dtype
    # Off-chip memory.
    hbm_bandwidth: float         # bytes/s
    hbm_bytes: int               # capacity
    # On-chip memory (VMEM on TPU; MBuf/WBuf on Snowflake).
    vmem_bytes: int              # usable scratch capacity per core
    vmem_budget_frac: float      # fraction the tiler may claim (double
                                 # buffering is accounted separately)
    # Compute-unit geometry (MXU on TPU; vMAC on Snowflake).
    mxu_dim: int                 # preferred contraction/output multiple
    sublane: int                 # second-minor tiling multiple (f32)
    lane: int                    # minor tiling multiple
    # Interconnect (ICI on TPU; the AXI ports on the Zynq).
    ici_bandwidth: float         # bytes/s per link
    ici_links_per_axis: int      # usable links per mesh axis (torus: 2)
    # Split on-chip buffers (Snowflake's MBuf/WBuf are separate; 0 means
    # a unified scratch, as on TPU where VMEM is one pool).
    maps_buffer_bytes: int = 0
    weights_buffer_bytes: int = 0
    # Load/store streams (the paper's 4 load units; informs chunking).
    load_units: int = 4
    # Whether the memory system supports random (strided, in-buffer)
    # access to a resident maps block.  Snowflake's DMA engine issues
    # contiguous single-burst loads only, so halo overlap must be
    # duplicated in DRAM (materialized strips); TPUs can gather
    # virtual strips out of VMEM for free.
    random_buffer_access: bool = True
    # Vector-instruction latency model (paper §5.2: bookkeeping must hide
    # under MAC latency).  Expressed as FLOPs one "instruction slot" of
    # epilogue work costs relative to the main loop.
    epilogue_slot_flops: float = 0.0

    # ---- derived quantities -------------------------------------------------
    @property
    def machine_balance(self) -> float:
        """FLOP per HBM byte needed to be compute bound."""
        return self.peak_flops / self.hbm_bandwidth

    def compute_time(self, flops: float) -> float:
        return flops / self.peak_flops

    def memory_time(self, bytes_moved: float) -> float:
        return bytes_moved / self.hbm_bandwidth

    def exec_time(self, flops: float, bytes_moved: float) -> float:
        """Overlapped execution model: DMA hides under compute (paper §3,
        double-buffer strategy), so a layer costs the max of the two."""
        return max(self.compute_time(flops), self.memory_time(bytes_moved))

    def vmem_budget(self) -> int:
        return int(self.vmem_bytes * self.vmem_budget_frac)

    def replace(self, **kw) -> "HardwareModel":
        return dataclasses.replace(self, **kw)


# --- TPU v5e: the deployment target ------------------------------------------
TPU_V5E = HardwareModel(
    name="tpu_v5e",
    peak_flops=197e12,             # bf16 MXU peak (assignment constant)
    compute_dtype_bytes=2,
    hbm_bandwidth=819e9,           # assignment constant
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    vmem_budget_frac=0.75,         # leave room for the pipeline emitter
    mxu_dim=128,
    sublane=8,
    lane=128,
    ici_bandwidth=50e9,            # assignment constant, per link
    ici_links_per_axis=2,          # 2D torus: two directions per axis
    load_units=4,                  # DMA streams we chunk against
    epilogue_slot_flops=8.0,
)

# --- Snowflake (paper hardware), for the benchmark reproductions -------------
# 4 CUs x 4 vMACs x 16 MACs = 256 MACs; 2 FLOP/MAC/cycle @ 250 MHz = 128 GOP/s.
# ZC706 AXI bandwidth 4.2 GB/s bi-directional (paper §6.2).
# MBuf: 64 KB per maps bank (double banked per CU); WBuf: 8 KB per vMAC.
SNOWFLAKE = HardwareModel(
    name="snowflake",
    peak_flops=256 * 2 * 250e6,    # 128 GOP/s (16-bit MACs)
    compute_dtype_bytes=2,         # Q8.8
    hbm_bandwidth=4.2e9,
    hbm_bytes=1 * 2**30,           # ZC706 DDR visible via CMA
    vmem_bytes=4 * (2 * 64 + 4 * 8) * 1024,   # 4 CUs x (2 maps banks + 4 WBufs)
    vmem_budget_frac=1.0,
    mxu_dim=16,                    # vMAC width
    sublane=1,
    lane=16,
    ici_bandwidth=0.0,
    ici_links_per_axis=0,
    # Per-tile capacities are PER CU (a maps tile lives in one CU's
    # double-banked 64 KB MBuf; its 4 vMACs hold the kernel tile in
    # 4 x 8 KB WBufs).  The x2 double-buffer accounting in the tiler
    # consumes the second bank / half the WBuf.
    maps_buffer_bytes=2 * 64 * 1024,
    weights_buffer_bytes=4 * 8 * 1024,
    load_units=4,                  # the paper's 4 load/store units
    epilogue_slot_flops=2.0,
    random_buffer_access=False,    # contiguous single-burst DMA only:
                                   # halo strips must be materialized
)


# --- Mesh descriptors ---------------------------------------------------------
@dataclass(frozen=True)
class MeshDescriptor:
    """Static description of a device mesh (no jax device state touched).

    Axis meaning follows launch/mesh.py: ``data`` carries batch (DP/FSDP),
    ``model`` carries tensor/expert parallelism, ``pod`` is the inter-pod
    axis (pipeline or extra data parallelism).
    """

    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_chips(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        if name not in self.axes:
            return 1
        return self.shape[self.axes.index(name)]

    @property
    def data(self) -> int:
        return self.axis_size("data") * self.axis_size("pod")

    @property
    def model(self) -> int:
        return self.axis_size("model")


SINGLE_POD = MeshDescriptor(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshDescriptor(shape=(2, 16, 16), axes=("pod", "data", "model"))

"""Loop rearrangement — the Mloop/Kloop decision (paper §6.2, T3).

The paper's central bandwidth optimization: when neither the maps nor
the kernels of a layer fit on-chip, one of them must be streamed
repeatedly.  ``Kloop`` keeps a maps tile resident and re-streams every
kernel tile past it (kernels loaded once per maps tile); ``Mloop`` keeps
a kernel tile resident and re-streams the maps.  The compiler picks the
order whose *total bytes moved* is lower, per layer.

This module implements that decision at two levels:

1. **Kernel level** (VMEM vs HBM): exact traffic formulas for the three
   Pallas-realizable dataflows of a tiled matmul —

   * ``MAPS_RESIDENT``  (paper Kloop): an A-slab (bm x K) stays in VMEM,
     B streams once per m-tile.     traffic = A + ceil(M/bm) * B + C
   * ``WEIGHTS_RESIDENT`` (paper Mloop): a B-slab (K x bn) stays, A
     streams once per n-tile.       traffic = ceil(N/bn) * A + B + C
   * ``OUTPUT_STATIONARY`` (beyond-paper generalization): both operands
     tiled, k innermost.  traffic = ceil(N/bn)*A + ceil(M/bm)*B + C

2. **Distributed level** (HBM vs ICI — beyond-paper): for a sharded
   matmul, choose between *weight-gathered* execution (weights
   all-gathered to the data shards; the Kloop analogue across ICI) and
   *activation-gathered* execution (activations gathered / partial sums
   reduce-scattered; the Mloop analogue), by the same bytes-moved logic.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass

from .hw import HardwareModel, MeshDescriptor
from .tiling import (MatmulTiling, enumerate_matmul_tilings,
                     matmul_vmem_bytes, pow2_candidates, round_up,
                     select_matmul_tiles)

__all__ = [
    "Dataflow",
    "enumerate_matmul_candidates",
    "matmul_traffic",
    "materialization_roundtrip",
    "conv_strip_traffic",
    "choose_conv_dataflow",
    "DataflowDecision",
    "choose_matmul_dataflow",
    "DistStrategy",
    "DistDecision",
    "choose_dist_strategy",
]


class Dataflow(enum.Enum):
    MAPS_RESIDENT = "kloop"        # paper's Kloop: kernels re-streamed
    WEIGHTS_RESIDENT = "mloop"     # paper's Mloop: maps re-streamed
    OUTPUT_STATIONARY = "output_stationary"


def matmul_traffic(M: int, K: int, N: int, dtype_bytes: int,
                   dataflow: Dataflow, bm: int, bk: int, bn: int,
                   out_bytes_per_el: int | None = None) -> float:
    """Total HBM bytes moved for one matmul under the given dataflow.

    Mirrors the paper's Fig. 4 accounting: resident operand loaded once,
    streamed operand loaded once per resident tile, output written once.
    """
    ob = out_bytes_per_el if out_bytes_per_el is not None else dtype_bytes
    a = M * K * dtype_bytes
    b = K * N * dtype_bytes
    c = M * N * ob
    if dataflow is Dataflow.MAPS_RESIDENT:
        return a + math.ceil(M / bm) * b + c
    if dataflow is Dataflow.WEIGHTS_RESIDENT:
        return math.ceil(N / bn) * a + b + c
    return math.ceil(N / bn) * a + math.ceil(M / bm) * b + c


def materialization_roundtrip(maps_bytes: float,
                              overlap_frac: float) -> float:
    """Bytes to build the halo-augmented strip copy in DRAM: read the
    maps once + write the ``(1 + overlap)`` augmented layout.  Zero when
    strips don't overlap — the producer's natural output already *is*
    the strip layout then.  The single definition shared by
    ``conv_strip_traffic``, the schedule notes, and the strip-storage
    benchmark."""
    if overlap_frac <= 0.0:
        return 0.0
    return (2.0 + overlap_frac) * maps_bytes


def conv_strip_traffic(maps_bytes: float, weights_bytes: float,
                       out_bytes: float, *, n_map_tiles: int,
                       n_kernel_tiles: int, overlap_frac: float,
                       strip_storage: str = "materialized",
                       charge_materialization: bool = True
                       ) -> tuple[float, float]:
    """(kloop, mloop) HBM bytes for a row-strip conv under T3.

    The single source of truth for the strip-grid loop-order formulas —
    both the schedule compiler (core/schedule.py) and the kernel wrapper
    (kernels/conv2d/ops.py) call this; they must never drift apart.

    ``strip_storage`` is the compiler's overlap decision (paper vs TPU):

    * ``"materialized"`` — Snowflake's scheme: halo-augmented strips are
      duplicated in DRAM so the DMA engine issues single-burst loads.
      Every maps pass re-reads the ``(1 + overlap_frac)`` copy, and —
      because the augmented layout is *not* what the producing layer
      wrote — building it costs a round trip first: read the maps once
      and write the ``(1 + overlap_frac)`` augmented copy.  That round
      trip, ``(2 + overlap_frac) * maps_bytes``, is charged whenever the
      strips actually overlap; ``charge_materialization=False`` opts out
      and reproduces the conv-loop-only accounting (the paper's Fig. 4
      frame, which measures the conv's own streams).  Zero-overlap
      strips need no augmentation (the producer's layout already *is*
      the strip layout), so they are never charged.
    * ``"virtual"`` — zero-copy: the kernel gathers each strip from the
      un-duplicated maps with an in-kernel dynamic slice, so maps move
      exactly once per pass, and there is no materialization round trip
      at all.
    """
    dup = 1.0 + (overlap_frac if strip_storage == "materialized" else 0.0)
    roundtrip = 0.0
    if strip_storage == "materialized" and charge_materialization:
        roundtrip = materialization_roundtrip(maps_bytes, overlap_frac)
    kloop = roundtrip + maps_bytes * dup + n_map_tiles * weights_bytes \
        + out_bytes
    mloop = roundtrip + n_kernel_tiles * maps_bytes * dup + weights_bytes \
        + out_bytes
    return kloop, mloop


def choose_conv_dataflow(maps_bytes: float, weights_bytes: float,
                         out_bytes: float, *, n_map_tiles: int,
                         n_kernel_tiles: int, overlap_frac: float,
                         strip_storage: str = "materialized",
                         charge_materialization: bool = True
                         ) -> tuple[Dataflow, float, dict[str, float]]:
    """Pick the cheaper strip-grid loop order; returns
    (dataflow, traffic_bytes, {"kloop": ..., "mloop": ...})."""
    kloop, mloop = conv_strip_traffic(
        maps_bytes, weights_bytes, out_bytes, n_map_tiles=n_map_tiles,
        n_kernel_tiles=n_kernel_tiles, overlap_frac=overlap_frac,
        strip_storage=strip_storage,
        charge_materialization=charge_materialization)
    alts = {"kloop": kloop, "mloop": mloop}
    if kloop <= mloop:
        return Dataflow.MAPS_RESIDENT, kloop, alts
    return Dataflow.WEIGHTS_RESIDENT, mloop, alts


@dataclass(frozen=True)
class DataflowDecision:
    dataflow: Dataflow
    tiling: MatmulTiling
    traffic_bytes: float
    alternatives: dict   # dataflow name -> traffic (for logging / Fig 4)

    @property
    def arithmetic_intensity(self) -> float:
        return 1.0  # overwritten by callers when FLOPs known


def _resident_tiling(M: int, K: int, N: int, dtype_bytes: int,
                     hw: HardwareModel,
                     dataflow: Dataflow) -> MatmulTiling | None:
    """Largest feasible resident-slab tiling, or None if the slab can
    never fit (K too large for the VMEM budget)."""
    base = hw.mxu_dim
    budget = hw.vmem_budget()
    mcap = hw.maps_buffer_bytes or budget
    wcap = hw.weights_buffer_bytes or budget
    Kp = round_up(K, base)
    if dataflow is Dataflow.MAPS_RESIDENT:
        # A slab (bm x K) resident; B (K x bn) streamed; C (bm x bn).
        best = None
        for bm in pow2_candidates(min(round_up(M, base), 4096), base):
            for bn in pow2_candidates(min(round_up(N, base), 1024), base):
                vmem = matmul_vmem_bytes(bm, Kp, bn, dtype_bytes,
                                         stream_a=False)
                if (bm * Kp * dtype_bytes > mcap
                        or 2 * Kp * bn * dtype_bytes > wcap):
                    continue
                if vmem <= budget:
                    g = (math.ceil(M / bm), math.ceil(N / bn), 1)
                    t = MatmulTiling(bm, Kp, bn, vmem, g)
                    # bigger bm means fewer B re-streams -> strictly better
                    if best is None or (t.bm, t.bn) > (best.bm, best.bn):
                        best = t
        return best
    # WEIGHTS_RESIDENT: B slab (K x bn) resident; A streamed.
    best = None
    for bn in pow2_candidates(min(round_up(N, base), 4096), base):
        for bm in pow2_candidates(min(round_up(M, base), 1024), base):
            vmem = matmul_vmem_bytes(bm, Kp, bn, dtype_bytes, stream_b=False)
            if (Kp * bn * dtype_bytes > wcap
                    or 2 * bm * Kp * dtype_bytes > mcap):
                continue
            if vmem <= budget:
                g = (math.ceil(M / bm), math.ceil(N / bn), 1)
                t = MatmulTiling(bm, Kp, bn, vmem, g)
                if best is None or (t.bn, t.bm) > (best.bn, best.bm):
                    best = t
    return best


def choose_matmul_dataflow(M: int, K: int, N: int, dtype_bytes: int,
                           hw: HardwareModel, *,
                           allow_output_stationary: bool = True,
                           out_bytes_per_el: int | None = None
                           ) -> DataflowDecision:
    """Per-layer loop-order choice (the paper's §5.1 step-3 decision).

    Evaluates the bytes-moved of every feasible dataflow and returns the
    cheapest.  ``allow_output_stationary=False`` restricts the choice to
    the paper's two modes (used by the paper-faithful benchmarks)."""
    options: list[tuple[float, Dataflow, MatmulTiling]] = []
    alts: dict[str, float] = {}

    for df in (Dataflow.MAPS_RESIDENT, Dataflow.WEIGHTS_RESIDENT):
        t = _resident_tiling(M, K, N, dtype_bytes, hw, df)
        if t is not None:
            tr = matmul_traffic(M, K, N, dtype_bytes, df, t.bm, t.bk, t.bn,
                                out_bytes_per_el)
            options.append((tr, df, t))
            alts[df.value] = tr

    if allow_output_stationary or not options:
        t = select_matmul_tiles(M, K, N, dtype_bytes, hw)
        tr = matmul_traffic(M, K, N, dtype_bytes, Dataflow.OUTPUT_STATIONARY,
                            t.bm, t.bk, t.bn, out_bytes_per_el)
        options.append((tr, Dataflow.OUTPUT_STATIONARY, t))
        alts[Dataflow.OUTPUT_STATIONARY.value] = tr

    options.sort(key=lambda o: o[0])
    tr, df, t = options[0]
    return DataflowDecision(dataflow=df, tiling=t, traffic_bytes=tr,
                            alternatives=alts)


def enumerate_matmul_candidates(M: int, K: int, N: int, dtype_bytes: int,
                                hw: HardwareModel, *,
                                allow_output_stationary: bool = True,
                                out_bytes_per_el: int | None = None
                                ) -> list[tuple[Dataflow, MatmulTiling,
                                                float]]:
    """The autotuner's matmul search space: every feasible
    (dataflow, tiling) pair with its modeled traffic — the resident-slab
    flavors from ``_resident_tiling``'s own loops plus the full
    output-stationary (bm, bk, bn) grid.  Superset of what
    ``choose_matmul_dataflow`` picks from."""
    base = hw.mxu_dim
    budget = hw.vmem_budget()
    mcap = hw.maps_buffer_bytes or budget
    wcap = hw.weights_buffer_bytes or budget
    Kp = round_up(K, base)
    out: list[tuple[Dataflow, MatmulTiling, float]] = []

    for bm in pow2_candidates(min(round_up(M, base), 4096), base):
        for bn in pow2_candidates(min(round_up(N, base), 1024), base):
            vmem = matmul_vmem_bytes(bm, Kp, bn, dtype_bytes, stream_a=False)
            if (bm * Kp * dtype_bytes > mcap
                    or 2 * Kp * bn * dtype_bytes > wcap or vmem > budget):
                continue
            g = (math.ceil(M / bm), math.ceil(N / bn), 1)
            t = MatmulTiling(bm, Kp, bn, vmem, g)
            tr = matmul_traffic(M, K, N, dtype_bytes, Dataflow.MAPS_RESIDENT,
                                bm, Kp, bn, out_bytes_per_el)
            out.append((Dataflow.MAPS_RESIDENT, t, tr))
    for bn in pow2_candidates(min(round_up(N, base), 4096), base):
        for bm in pow2_candidates(min(round_up(M, base), 1024), base):
            vmem = matmul_vmem_bytes(bm, Kp, bn, dtype_bytes, stream_b=False)
            if (Kp * bn * dtype_bytes > wcap
                    or 2 * bm * Kp * dtype_bytes > mcap or vmem > budget):
                continue
            g = (math.ceil(M / bm), math.ceil(N / bn), 1)
            t = MatmulTiling(bm, Kp, bn, vmem, g)
            tr = matmul_traffic(M, K, N, dtype_bytes,
                                Dataflow.WEIGHTS_RESIDENT, bm, Kp, bn,
                                out_bytes_per_el)
            out.append((Dataflow.WEIGHTS_RESIDENT, t, tr))
    if allow_output_stationary:
        for t in enumerate_matmul_tilings(M, K, N, dtype_bytes, hw):
            tr = matmul_traffic(M, K, N, dtype_bytes,
                                Dataflow.OUTPUT_STATIONARY, t.bm, t.bk, t.bn,
                                out_bytes_per_el)
            out.append((Dataflow.OUTPUT_STATIONARY, t, tr))
    return out


# --- distributed level (beyond-paper) -------------------------------------------
class DistStrategy(enum.Enum):
    WEIGHT_GATHERED = "weight_gathered"       # FSDP-style: AG weights (Kloop/ICI)
    ACTIVATION_GATHERED = "activation_gathered"  # TP-style: AG acts / RS partials
    LOCAL = "local"                            # operands already local


@dataclass(frozen=True)
class DistDecision:
    strategy: DistStrategy
    ici_bytes_per_chip: float
    alternatives: dict
    chunks: int = 1            # collective split factor for overlap (T4)


def choose_dist_strategy(M_local: int, K: int, N: int, dtype_bytes: int,
                         mesh: MeshDescriptor, hw: HardwareModel, *,
                         axis: str = "model",
                         overlappable_flops: float | None = None
                         ) -> DistDecision:
    """Pick weight- vs activation-gathered execution for one sharded
    matmul, per-chip ICI bytes as the cost (the paper's bytes-moved logic
    lifted to the interconnect).

    ``M_local`` is the per-chip token count; weights are sharded over
    ``axis`` (size g).  Weight-gathered moves the missing (g-1)/g of the
    weight matrix; activation-gathered moves activations in + partial
    sums out (all-gather + reduce-scatter = 2 * (g-1)/g * act bytes).
    """
    g = mesh.axis_size(axis)
    if g <= 1:
        return DistDecision(DistStrategy.LOCAL, 0.0, {"local": 0.0})
    frac = (g - 1) / g
    w_bytes = frac * K * N * dtype_bytes              # AG of weights
    a_bytes = 2 * frac * M_local * K * dtype_bytes    # AG acts + RS partials
    alts = {"weight_gathered": w_bytes, "activation_gathered": a_bytes}
    if w_bytes <= a_bytes:
        strat, cost = DistStrategy.WEIGHT_GATHERED, w_bytes
    else:
        strat, cost = DistStrategy.ACTIVATION_GATHERED, a_bytes

    # T4: chunk the collective so it overlaps with compute.  Target chunk
    # transfer time ~= chunk compute time; clamp to the load-unit count.
    chunks = 1
    if overlappable_flops and cost > 0:
        link_bw = hw.ici_bandwidth * max(hw.ici_links_per_axis, 1)
        t_coll = cost / link_bw
        t_comp = overlappable_flops / hw.peak_flops
        if t_coll < t_comp:
            chunks = max(1, min(hw.load_units * 2,
                                int(round(t_comp / max(t_coll, 1e-12)))))
            chunks = min(chunks, 8)
        else:
            chunks = hw.load_units
    return DistDecision(strat, cost, alts, chunks=chunks)

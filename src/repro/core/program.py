"""Executable Program — the instruction-stream analogue (paper §5.2).

``compile_model`` stops at a ``ModelSchedule``: per-layer decisions
(tiling, loop order, strip storage, fusion flags) with modeled cost.
The paper's compiler keeps going — it allocates memory regions from the
dependency labels and emits the instruction stream Snowflake executes.
This module is that last lowering step for us: a ``Program`` is an
ordered list of ``ProgramOp``s, each carrying

* the kernel id to dispatch (conv2d / matmul / maxpool / avgpool for
  the CNN families; embed / norm / flash_attention / mul for the LM
  families),
* the *resolved* schedule for that op — ``ConvTiling``, matmul block,
  or attention (block_q, block_kv) — so the kernels recompute nothing,
* the fusion epilogue (bias, activation, residual bypass, fused pool),
  exactly the paper's VMOV-on-writeback flags,
* input / output / bypass *memory-region* ids from the §5.1 region
  plan (core/regions.py).

``runtime/executor.py`` executes a Program against parameters; the
models compile once (cached) and run it, so every scheduler improvement
is automatically an execution improvement, never just a report.

Invariants (relied on by the executor, the tests and the docs):

* **Ops never re-derive tilings.**  Every schedule-shaped field on a
  ``ProgramOp`` (conv_tiling, block, strip_storage, dataflow, attention
  blocks) is resolved here, from the ``ModelSchedule``, at lowering
  time.  The executor passes them through verbatim; a kernel falling
  back to its own heuristics is a lowering bug, not a feature.
* **Region ids are allocator-owned.**  ``in_region`` / ``out_region``
  / ``bypass_region`` / ``k_region`` / ``v_region`` / ``in2_region``
  come exclusively from the §5.1 ``RegionPlan`` — and the persistent
  ``k_cache_region`` / ``v_cache_region`` ids from its persistent
  table; this module only maps producer/state names to the allocator's
  ids and never invents one.
* **``listing()`` is stable.**  For a fixed (graph, hw, batch) the
  listing is a deterministic function of the schedule — docs and CI
  reproduce it verbatim via ``examples/inspect_schedule.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

from .dataflow import Dataflow
from .ir import LayerKind, ModelGraph
from .regions import (PAGE_TABLE_REGION, PagedPlan, RegionPlan, StateCaps,
                      allocate_regions)
from .schedule import LayerSchedule, ModelSchedule
from .tiling import ConvTiling

__all__ = ["AttentionSpec", "ProgramOp", "Program", "ProgramPair",
           "lower_to_program"]


@dataclass(frozen=True)
class AttentionSpec:
    """Resolved geometry + schedule of one ``flash_attention`` op.

    Fields:

    * ``heads`` / ``kv_heads`` / ``head_dim`` — the projection layout;
      the executor reshapes the flat (B, S, heads*head_dim) q region
      (and the KV analogues) into per-head layout with these, so the
      kernel never consults the model config.
    * ``causal`` — decoder-LM causal masking (fixed at lowering).
    * ``window`` — causal sliding-window size, or None for full.  On a
      ``decode_attention`` op a window additionally means the §5.1 plan
      sized the persistent cache regions at ``min(max_len, window)``
      rows (rolling eviction-by-overwrite); the executor derives the
      ring extent from the region shape, so the field is the *record*
      of the decision, never re-derived.
    * ``rope_theta`` — rotary base; the executor applies RoPE to q/k
      before the kernel when set, 0.0 disables it (e.g. learned
      absolute positions).
    * ``block_q`` / ``block_kv`` — the compiler's T2 score-loop tiles
      (core/tiling.py::select_attention_blocks), pinned so the kernel
      wrapper re-derives nothing at run time.
    * ``page_size`` — rows per KV page when the §5.1 plan paged the
      persistent cache (``regions.paged_kv_specs``), else None.  On a
      paged decode op the kv block IS the page (``block_kv ==
      page_size``, pinned by the tiling chooser) and the history is
      gathered through the op's page-table region.
    """

    heads: int
    kv_heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None
    rope_theta: float = 0.0
    block_q: int = 128
    block_kv: int = 128
    page_size: int | None = None


@dataclass(frozen=True)
class ProgramOp:
    index: int                       # position in the instruction stream
    name: str                        # source layer name
    # "conv2d" | "matmul" | "maxpool" | "avgpool"
    #   | "embed" | "norm" | "flash_attention" | "decode_attention" | "mul"
    kernel: str
    in_region: int
    out_region: int
    param_key: str | None = None     # params path ("layer_03", "blocks/wq:3")
    param_key_b: str | None = None   # secondary param (layernorm bias)
    bypass_region: int | None = None
    k_region: int | None = None      # attention: K producer's region
    v_region: int | None = None      # attention: V producer's region
    in2_region: int | None = None    # mul: second operand's region
    # Persistent KV-cache regions (§5.1 extension).  On a
    # flash_attention op they mean "also write the computed K/V into
    # the cache at the runtime slot" (the prefill side of the pair); on
    # a decode_attention op they are where the history is read from and
    # the new token's K/V written at the per-slot position.  The slot /
    # position itself is a runtime operand (executor ProgramState),
    # never baked into the stream.
    k_cache_region: int | None = None
    v_cache_region: int | None = None
    # Paged KV (§5.1 paged plan).  When the allocator paged the cache,
    # k_cache_region / v_cache_region point at the page *pools* and
    # page_table_region at the shared (slots, pages_per_slot) int32
    # table that maps logical cache rows to pool pages; k/v_scale
    # regions hold per-page dequant scales when the plan quantized the
    # pool to int8.  All four resolve by name through the plan's
    # persistent table, like the caches themselves.
    page_table_region: int | None = None
    k_scale_region: int | None = None
    v_scale_region: int | None = None
    # Generic named state (§5.1 generalisation).  Family ops whose
    # persistent state is not KV-shaped — ssm_scan (recurrent state +
    # conv taps), wkv (wkv matrix + token-shift rows) — carry the
    # resolved persistent region ids here, in the family's documented
    # order.  Resolved by *name* through the plan's persistent table,
    # exactly like the KV cache fields above; the executor scatters
    # updates in place at the runtime slot.
    state_regions: tuple = ()
    # Static per-op config for family kernels (sorted (key, value)
    # pairs, hashable).  moe_dispatch carries top_k / capacity_factor /
    # activation / gated here so the executor never consults the model
    # config; plain dense ops leave it empty.
    op_cfg: tuple = ()
    # geometry
    stride: int = 1
    pad: int = 0
    window: int = 0                  # standalone pool window
    # fusion epilogue (the paper's writeback VMOVs)
    fuse_bias: bool = False
    fuse_activation: str | None = None
    fuse_bypass: bool = False
    bypass_first: bool = True
    fuse_pool: tuple[int, int, int, str] | None = None  # (window,stride,pad,op)
    # resolved schedule
    strip_storage: str | None = None
    dataflow: Dataflow | None = None
    conv_tiling: ConvTiling | None = None
    block: tuple[int, int, int] | None = None
    attn: AttentionSpec | None = None               # flash_attention only
    # op-shape details
    norm_kind: str | None = None     # "rmsnorm" | "layernorm" | "nonparametric"
    flatten_input: bool = False      # CNN fc: (B,H,W,C) -> (B, H*W*C)
    transpose_w: bool = False        # tied lm_head: use embed table W^T
    # modeled cost, carried for the listing / benchmarks / trace records
    flops: float = 0.0
    traffic_bytes: float = 0.0
    exec_time_s: float = 0.0         # schedule's (possibly calibrated) price

    def trace(self) -> str:
        """One paper-style instruction-trace line."""
        io = f"r{self.in_region}->r{self.out_region}"
        if self.kernel in ("flash_attention", "decode_attention"):
            io = (f"r{self.in_region},r{self.k_region},r{self.v_region}"
                  f"->r{self.out_region}")
        elif self.kernel in ("mul", "add"):
            sym = "*" if self.kernel == "mul" else "+"
            io = (f"r{self.in_region}{sym}r{self.in2_region}"
                  f"->r{self.out_region}")
        if self.bypass_region is not None:
            io += f"+r{self.bypass_region}"
        sched = ""
        if self.kernel == "conv2d" and self.conv_tiling is not None:
            ct = self.conv_tiling
            order = self.dataflow.value if self.dataflow else "?"
            sched = (f"{order} strips={ct.n_map_tiles}x{ct.n_kernel_tiles} "
                     f"rows={ct.out_rows} kpt={ct.kernels_per_tile} "
                     f"{self.strip_storage or 'auto'}")
        elif self.kernel == "matmul" and self.block is not None:
            order = self.dataflow.value if self.dataflow else "?"
            sched = f"{order} block={'x'.join(map(str, self.block))}"
            if self.transpose_w:
                sched += " W^T"
        elif self.kernel in ("maxpool", "avgpool"):
            sched = f"win={self.window} stride={self.stride}"
        elif self.kernel == "flash_attention" and self.attn is not None:
            a = self.attn
            sched = (f"h={a.heads}/{a.kv_heads}x{a.head_dim} "
                     f"bq={a.block_q} bkv={a.block_kv}"
                     f"{' causal' if a.causal else ''}"
                     f"{f' win={a.window}' if a.window else ''}"
                     f"{' rope' if a.rope_theta else ''}")
            if self.k_cache_region is not None:
                sched += (f" cache>r{self.k_cache_region},"
                          f"r{self.v_cache_region}@slot")
                if self.page_table_region is not None:
                    sched += (f" pt=r{self.page_table_region}"
                              f" pg={self.attn.page_size}")
        elif self.kernel == "decode_attention" and self.attn is not None:
            a = self.attn
            sched = (f"h={a.heads}/{a.kv_heads}x{a.head_dim} "
                     f"bkv={a.block_kv}"
                     f"{f' win={a.window}' if a.window else ''}"
                     f"{' rope' if a.rope_theta else ''}"
                     f" cache=r{self.k_cache_region},"
                     f"r{self.v_cache_region}@pos")
            if self.page_table_region is not None:
                sched += f" pt=r{self.page_table_region} pg={a.page_size}"
                if self.k_scale_region is not None:
                    sched += " int8"
        elif self.kernel == "norm":
            sched = self.norm_kind or ""
        elif self.kernel == "moe_dispatch":
            cfg = dict(self.op_cfg)
            sched = (f"experts={cfg.get('experts', '?')} "
                     f"top{cfg.get('top_k', '?')} "
                     f"cap={cfg.get('capacity_factor', '?')}")
        elif self.kernel in ("ssm_scan", "wkv"):
            sched = ("state=" + ",".join(f"r{r}" for r in self.state_regions)
                     + "@slot") if self.state_regions else ""
        elif self.kernel == "cross_attention" and self.attn is not None:
            a = self.attn
            sched = (f"h={a.heads}/{a.kv_heads}x{a.head_dim} "
                     f"mem=r{self.k_cache_region},"
                     f"r{self.v_cache_region}@slot")
        epi = "".join(
            [" +bias" if self.fuse_bias else "",
             f" +{self.fuse_activation}" if self.fuse_activation else "",
             " +bypass" if self.fuse_bypass else "",
             (f" +{'avg' if self.fuse_pool[3] == 'avg' else ''}pool"
              f"{self.fuse_pool[0]}s{self.fuse_pool[1]}"
              if self.fuse_pool else "")])
        return (f"%{self.index:02d} {self.kernel:8s} {self.name:14s} "
                f"{io:10s} {sched}{epi}")


@dataclass(frozen=True)
class Program:
    name: str
    hw_name: str
    ops: tuple[ProgramOp, ...]
    plan: RegionPlan

    @property
    def input_region(self) -> int:
        return self.plan.input_region

    @property
    def output_region(self) -> int:
        return self.plan.output_region

    @property
    def total_flops(self) -> float:
        return sum(op.flops for op in self.ops)

    @property
    def total_traffic_bytes(self) -> float:
        return sum(op.traffic_bytes for op in self.ops)

    def op(self, name: str) -> ProgramOp:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    def listing(self) -> str:
        plan = self.plan
        persist = ""
        if plan.n_persistent:
            persist = (f"+{plan.n_persistent} persistent "
                       f"({plan.persistent_bytes / 1e6:.2f} MB KV) ")
        head = (f"program {self.name} on {self.hw_name}: {len(self.ops)} ops, "
                f"{plan.n_pingpong}+{plan.n_pinned} regions "
                f"({plan.total_bytes / 1e6:.2f} MB) {persist}".rstrip() + ", "
                f"{self.total_flops / 1e9:.2f} GFLOP, "
                f"{self.total_traffic_bytes / 1e6:.1f} MB moved")
        return "\n".join([head] + [op.trace() for op in self.ops])


@dataclass(frozen=True)
class ProgramPair:
    """A prefill Program and a decode Program sharing one persistent
    region table (§5.1 extension) — the compiled form of stateful LM
    serving.  The prefill Program runs the full causal forward *and*
    writes each block's K/V into the persistent cache regions at an
    admitted slot; the decode Program advances every live slot by one
    token through ``decode_attention`` ops reading/writing the same
    regions.  Both plans embed identical persistent ids
    (``regions.extend_with_persistent`` with a shared base), so one
    runtime ``ProgramState`` serves both instruction streams.

    ``slots`` / ``max_len`` record the serving geometry the pair was
    compiled for.  The persistent-region shapes alone cannot recover
    ``max_len`` once a sliding window collapses the row count to
    ``min(max_len, attn_window)``, yet the prefill stream is still
    pinned to (1, max_len) token batches — so the engine validates a
    caller-supplied pair against these fields, not just the shapes.

    ``paged`` records the §5.1 paged-plan decision
    (``regions.PagedPlan``) when the persistent cache is a page pool +
    page table instead of contiguous (slots, cache_len) rows; None
    means contiguous.  The executor's host-side page allocator and the
    engine's COW admission both read their geometry from it."""

    prefill: Program
    decode: Program
    slots: int | None = None
    max_len: int | None = None
    paged: PagedPlan | None = None
    # Per-family state capabilities (regions.StateCaps) minted by the
    # family's ``state_specs`` hook alongside the specs themselves.
    # None means the pair predates the hook (treated as dense-KV: all
    # capabilities on) — the engine's paged/COW/chunk/speculation gates
    # consult this instead of assuming every family is KV-shaped.
    caps: StateCaps | None = None

    @property
    def page_table_region(self) -> int | None:
        """Region id of the shared page table, None when contiguous."""
        if self.paged is None:
            return None
        return self.decode.plan.persistent[PAGE_TABLE_REGION]

    @property
    def chunk_blocker(self) -> str | None:
        """Why this pair cannot serve *chunked* prefill (None = it
        can).  int8 paged pools quantize whole pages — the page scale
        is a function of every row in the page — while a chunk boundary
        inside a page writes rows under the scale of the rows seen so
        far, silently re-basing the ones a later chunk adds.  The
        engine checks this at construction, not mid-serve."""
        if self.paged is not None and self.paged.quantized:
            return ("int8 paged KV: page scales are whole-page "
                    "decisions, chunk writes are row-granular")
        if self.caps is not None and not self.caps.chunkable:
            return ("family state is not chunkable: recurrent state "
                    "after a chunk depends on every row before it, so "
                    "a chunk boundary cannot be resumed from the "
                    "persistent regions alone")
        return None

    @property
    def persistent(self) -> dict:
        return self.decode.plan.persistent

    @property
    def persistent_bytes(self) -> int:
        return self.decode.plan.persistent_bytes

    def listing(self) -> str:
        return (f"program pair {self.decode.name.removesuffix('.decode')}: "
                f"prefill {len(self.prefill.ops)} ops + decode "
                f"{len(self.decode.ops)} ops, "
                f"{len(self.persistent)} persistent KV regions "
                f"({self.persistent_bytes / 1e6:.2f} MB)\n"
                + self.prefill.listing() + "\n" + self.decode.listing())


def _pool_kernel(node) -> str:
    return "avgpool" if node.meta.get("op") == "avg" else "maxpool"


def _norm_pool(fp: dict) -> tuple[int, int, int, str]:
    return (fp["window"], fp["stride"], fp.get("pad", 0), fp.get("op", "max"))


def lower_to_program(graph: ModelGraph, schedule: ModelSchedule,
                     plan: RegionPlan | None = None) -> Program:
    """Lower a scheduled graph to the executable instruction stream.

    The schedule is the single source of truth: a pool is emitted as a
    standalone op exactly when the scheduler did *not* fuse it into its
    producer (``fused_pool`` in the conv's notes requires the zero-copy
    strip path), and every conv/matmul op carries the schedule's exact
    tiling, loop order and epilogue flags.
    """
    if plan is None:
        plan = allocate_regions(graph, schedule)
    nodes = list(graph)
    prev: str | None = None
    ops: list[ProgramOp] = []
    for node in nodes:
        ls: LayerSchedule = schedule.layer(node.name)
        src_name = node.inputs[0] if node.inputs else prev
        in_region = (plan.out_region[src_name] if src_name is not None
                     else plan.input_region)
        out_region = plan.out_region[node.name]
        prev = node.name
        fused_into = node.meta.get("fused_into")
        if fused_into is not None and "fused_into" in ls.notes:
            continue                      # runs inside its producer's epilogue
        common = dict(
            index=len(ops), name=node.name, in_region=in_region,
            out_region=out_region, param_key=node.meta.get("param"),
            flops=ls.flops, traffic_bytes=ls.traffic_bytes,
            exec_time_s=ls.exec_time_s)
        if node.kind is LayerKind.CONV2D:
            d = node.dims
            fp = ls.notes.get("fused_pool")
            ops.append(ProgramOp(
                kernel="conv2d", stride=d["stride"], pad=d["pad"],
                fuse_bias=ls.fuse_bias, fuse_activation=ls.fuse_activation,
                fuse_bypass=ls.fuse_bypass,
                bypass_region=(plan.out_region[node.bypass_of]
                               if node.bypass_of else None),
                bypass_first=node.meta.get("bypass_first", True),
                fuse_pool=_norm_pool(fp) if fp else None,
                strip_storage=ls.notes.get("strip_storage"),
                dataflow=ls.dataflow, conv_tiling=ls.conv_tiling,
                **common))
        elif node.kind is LayerKind.MATMUL:
            ops.append(ProgramOp(
                kernel="matmul", fuse_bias=ls.fuse_bias,
                fuse_activation=ls.fuse_activation,
                fuse_bypass=ls.fuse_bypass,
                bypass_region=(plan.out_region[node.bypass_of]
                               if node.bypass_of else None),
                flatten_input=node.meta.get("flatten_input", False),
                transpose_w=node.meta.get("transpose_w", False),
                dataflow=ls.dataflow, block=ls.block, **common))
        elif node.kind is LayerKind.POOL:
            m = node.meta
            ops.append(ProgramOp(
                kernel=_pool_kernel(node), window=m.get("window", 1),
                stride=m.get("stride", 1), pad=m.get("pad", 0), **common))
        elif node.kind is LayerKind.EMBED:
            # param_key_b names a learned absolute position table the
            # executor adds after the gather (prefill: rows [0, T);
            # decode: the per-slot position row).
            ops.append(ProgramOp(
                kernel="embed", param_key_b=node.meta.get("param_b"),
                **common))
        elif node.kind is LayerKind.NORM:
            ops.append(ProgramOp(
                kernel="norm", norm_kind=node.meta.get("norm", "rmsnorm"),
                param_key_b=node.meta.get("param_b"), **common))
        elif node.kind is LayerKind.ATTENTION and node.meta.get("cross"):
            # Cross-attention reads per-slot *read-only* encoder memory
            # from persistent regions — there is no K/V producer in the
            # transient graph and nothing is ever written back, so the
            # op takes [q] alone and resolves both memory regions by
            # name through the persistent table.
            d = node.dims
            ops.append(ProgramOp(
                kernel="cross_attention",
                k_cache_region=plan.persistent[node.meta["k_cache"]],
                v_cache_region=plan.persistent[node.meta["v_cache"]],
                attn=AttentionSpec(
                    heads=d["heads"], kv_heads=d["kv_heads"],
                    head_dim=d["head_dim"], causal=False,
                    rope_theta=node.meta.get("rope_theta", 0.0),
                    block_q=ls.notes.get("block_q", 128),
                    block_kv=ls.notes.get("block_kv", 128)),
                **common))
        elif node.kind is LayerKind.ATTENTION:
            d = node.dims
            # Persistent cache regions resolve by *name* through the
            # plan's allocator-owned persistent table (shared across a
            # prefill/decode pair).
            k_cache = v_cache = None
            page_table = k_scale = v_scale = None
            if node.meta.get("k_cache") is not None:
                k_cache = plan.persistent[node.meta["k_cache"]]
                v_cache = plan.persistent[node.meta["v_cache"]]
                # Paged plan: the cache names resolve to page pools and
                # the op additionally carries the shared table (and the
                # per-page scale regions when the pool is int8).
                if node.meta.get("page_table") is not None:
                    page_table = plan.persistent[node.meta["page_table"]]
                    if node.meta.get("k_scale") is not None:
                        k_scale = plan.persistent[node.meta["k_scale"]]
                        v_scale = plan.persistent[node.meta["v_scale"]]
            ops.append(ProgramOp(
                kernel=("decode_attention" if node.meta.get("decode")
                        else "flash_attention"),
                k_region=plan.out_region[node.inputs[1]],
                v_region=plan.out_region[node.inputs[2]],
                k_cache_region=k_cache, v_cache_region=v_cache,
                page_table_region=page_table,
                k_scale_region=k_scale, v_scale_region=v_scale,
                attn=AttentionSpec(
                    heads=d["heads"], kv_heads=d["kv_heads"],
                    head_dim=d["head_dim"],
                    causal=ls.notes.get("causal", True),
                    window=ls.notes.get("window"),
                    rope_theta=node.meta.get("rope_theta", 0.0),
                    block_q=ls.notes.get("block_q", 128),
                    block_kv=ls.notes.get("block_kv", 128),
                    page_size=ls.notes.get("page_size")),
                **common))
        elif node.kind is LayerKind.MOE:
            # Capacity-bucketed expert dispatch (§6 load balancing):
            # one op covers route → bucket → per-expert matmuls →
            # un-permute.  The static routing config rides op_cfg so
            # the executor never consults the model config.
            d = node.dims
            ops.append(ProgramOp(
                kernel="moe_dispatch",
                fuse_bypass=ls.fuse_bypass,
                bypass_region=(plan.out_region[node.bypass_of]
                               if node.bypass_of else None),
                op_cfg=tuple(sorted({
                    "experts": d["experts"], "top_k": d["top_k"],
                    "capacity_factor": node.meta.get(
                        "capacity_factor", 1.25),
                    "activation": node.meta.get("activation", "silu"),
                    "gated": node.meta.get("gated", True),
                }.items())),
                **common))
        elif node.kind in (LayerKind.SSM_SCAN, LayerKind.WKV):
            # Coarse recurrent block op: the whole mixing block runs as
            # one kernel against generic named state (SSM recurrent +
            # conv taps, or wkv matrix + token-shift rows), scattered
            # in place at the runtime slot.  State region ids resolve
            # by name, in the family's documented order.
            ops.append(ProgramOp(
                kernel=("ssm_scan" if node.kind is LayerKind.SSM_SCAN
                        else "wkv"),
                state_regions=tuple(plan.persistent[s]
                                    for s in node.meta.get("states", ())),
                fuse_bypass=ls.fuse_bypass,
                bypass_region=(plan.out_region[node.bypass_of]
                               if node.bypass_of else None),
                op_cfg=tuple(sorted(node.meta.get("op_cfg", {}).items())),
                **common))
        elif (node.kind is LayerKind.ELEMENTWISE
              and node.meta.get("op") in ("mul", "add")):
            ops.append(ProgramOp(
                kernel=node.meta["op"],
                in2_region=plan.out_region[node.inputs[1]], **common))
        else:
            raise NotImplementedError(
                f"no program lowering for {node.kind} ({node.name}); "
                f"Program covers the CNN layer kinds, the dense-LM op "
                f"vocabulary (embed/norm/flash_attention/matmul/mul) "
                f"and the family ops (moe_dispatch/ssm_scan/wkv/"
                f"cross_attention)")
    return Program(name=graph.name, hw_name=schedule.hw_name,
                   ops=tuple(ops), plan=plan)

"""Optimized-HLO analyzer: FLOPs / HBM bytes / collective bytes with
while-loop trip-count multipliers.

``compiled.cost_analysis()`` has two blind spots on scanned programs:
it reports per-device numbers (fine) but counts each while-loop body
exactly ONCE — a 32-layer scanned transformer shows ~1/32 of its FLOPs.
This module parses ``compiled.as_text()`` instead:

* computations are parsed into op lists;
* ``while`` ops recurse into their body/condition with a trip count
  extracted from the condition's comparison constant;
* FLOPs: dot (2 * numel(out) * contraction), convolution;
* HBM bytes: operand + result bytes of top-level fusions, dots,
  convolutions, copies and collectives (fusion internals are VMEM);
* collective link-bytes per chip with ring-algorithm factors.

All numbers are per-device (the HLO is the per-device SPMD program);
multiply FLOPs/bytes by n_chips for cluster totals.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from .roofline import DTYPE_BYTES

__all__ = ["HloStats", "analyze_hlo_text"]

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_CFG = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:\s*[\\"]*(\d+)')
_CALLED = re.compile(r"(?:condition|body|to_apply|branch_computations)="
                     r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONST_INT = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_IOTA_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPL_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shapes_bytes(text: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        b = DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _result_shape_numel(line: str) -> tuple[float, list[int]]:
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0.0, []
    m = _SHAPE_RE.search(lhs[1])
    if not m:
        return 0.0, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return float(n), dims


def _operand_shapes(line: str) -> list[list[int]]:
    """Shapes inside the op's parenthesized operand list."""
    start = line.find("(")
    if start < 0:
        return []
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = line[start + 1:end]
    out = []
    for m in _SHAPE_RE.finditer(inner):
        out.append([int(d) for d in m.group(2).split(",") if d])
    return out


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS.search(line)
    if m:
        return int(m.group(2)) or default
    m = _EXPL_GROUPS.search(line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return default


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_link_bytes: float = 0.0
    coll_counts: dict = field(default_factory=dict)
    coll_bytes: dict = field(default_factory=dict)
    while_trips: list = field(default_factory=list)

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_link_bytes += other.coll_link_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult


def _parse_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    buf: list[str] = []
    depth = 0
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START.match(line)
            if m and "{" in line:
                cur = m.group(1)
                buf = []
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    comps[cur] = []
                    cur = None
        else:
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                comps[cur] = buf
                cur = None
            else:
                buf.append(line)
    return comps


_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def _operand_entries(line: str) -> list[str]:
    """Names of the operands inside the op's parenthesized list."""
    eq = line.find(" = ")
    start = line.find("(", eq if eq >= 0 else 0)
    if start < 0:
        return []
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_NAME.findall(line[start + 1:end])


def _build_symtab(lines: list[str]) -> dict[str, tuple[list[int], int]]:
    """instruction name -> (result dims, dtype bytes) per computation."""
    tab: dict[str, tuple[list[int], int]] = {}
    for ln in lines:
        s = ln.strip()
        if " = " not in s:
            continue
        name_m = re.match(r"(?:ROOT\s+)?%([\w\.\-]+)\s+=", s)
        if not name_m:
            continue
        rhs = s.split(" = ", 1)[1]
        m = _SHAPE_RE.search(rhs)
        if not m:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        tab[name_m.group(1)] = (dims, DTYPE_BYTES.get(m.group(1), 4))
    return tab


def _dot_flops(line: str, symtab: dict) -> float:
    out_numel, _ = _result_shape_numel(line)
    names = _operand_entries(line)
    if not names or out_numel == 0:
        return 0.0
    lhs = symtab.get(names[0], ([], 4))[0]
    inline = _operand_shapes(line)
    if not lhs and inline:
        lhs = inline[0]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs):
                contract *= lhs[i]
    else:
        contract = lhs[-1] if lhs else 1
    return 2.0 * out_numel * contract


def _conv_flops(line: str, symtab: dict) -> float:
    out_numel, _ = _result_shape_numel(line)
    names = _operand_entries(line)
    rhs: list[int] = []
    if len(names) >= 2:
        rhs = symtab.get(names[1], ([], 4))[0]
    if not rhs:
        inline = _operand_shapes(line)
        if len(inline) >= 2:
            rhs = inline[1]
    if not rhs or out_numel == 0:
        return 0.0
    n = 1
    for d in rhs[:-1]:             # all but the output-feature dim
        n *= d
    return 2.0 * out_numel * n


def analyze_hlo_text(text: str, n_chips: int) -> HloStats:
    comps = _parse_computations(text)
    cache: dict[str, HloStats] = {}

    def trip_count(cond_name: str) -> float:
        lines = comps.get(cond_name, [])
        best = 1
        for ln in lines:
            for m in _CONST_INT.finditer(ln):
                best = max(best, int(m.group(1)))
        return float(best)

    def _sliced_param_bytes(comp_name: str) -> dict[int, float]:
        """For a fusion computation: parameter index -> bytes actually
        read when the parameter only feeds dynamic-slice ops (a scan
        body reading one layer of a stacked array must be charged the
        slice, not the stack)."""
        lines = comps.get(comp_name, [])
        sym = _build_symtab(lines)
        param_idx: dict[str, int] = {}
        for ln in lines:
            m = re.match(r"\s*(?:ROOT\s+)?%([\w\.\-]+)\s+=.*\sparameter\((\d+)\)",
                         ln)
            if m:
                param_idx[m.group(1)] = int(m.group(2))
        out: dict[int, float] = {}
        direct_use: set[str] = set()
        for ln in lines:
            s = ln.strip()
            if " = " not in s or " parameter(" in s:
                continue
            ops_names = _operand_entries(s)
            is_ds = " dynamic-slice(" in s
            is_dus = " dynamic-update-slice(" in s
            for pos, op_name in enumerate(ops_names):
                if op_name not in param_idx:
                    continue
                idx = param_idx[op_name]
                if is_ds and pos == 0:
                    res = _line_result_bytes(s)
                    out[idx] = max(out.get(idx, 0.0), res)
                elif is_dus and pos == 0:
                    # big buffer updated in place: charge the update size
                    upd = ops_names[1] if len(ops_names) > 1 else None
                    dims, b = sym.get(upd, ([], 0)) if upd else ([], 0)
                    n = 1
                    for d in dims:
                        n *= d
                    out[idx] = max(out.get(idx, 0.0), float(n * b))
                else:
                    direct_use.add(op_name)
        # a param also used directly must be charged in full
        for pname in direct_use:
            out.pop(param_idx[pname], None)
        return out

    def analyze(name: str, seen: tuple = ()) -> HloStats:
        if name in cache:
            return cache[name]
        if name in seen:
            return HloStats()
        stats = HloStats()
        lines = comps.get(name, [])
        symtab = _build_symtab(lines)

        def io_bytes(s: str, sliced: dict[int, float] | None = None
                     ) -> float:
            total = _line_result_bytes(s)
            for pos, op_name in enumerate(_operand_entries(s)):
                if sliced is not None and pos in sliced:
                    total += sliced[pos]
                    continue
                dims, b = symtab.get(op_name, ([], 0))
                n = 1
                for d in dims:
                    n *= d
                total += n * b if dims else 0
            return total

        for ln in lines:
            s = ln.strip()
            if " = " not in s:
                continue
            op_m = re.search(r"=\s+(?:\([^)]*\)\s+|\S+\s+)?([\w\-]+)\(", s)
            if not op_m:
                continue
            op = op_m.group(1)
            if op == "dot":
                stats.flops += _dot_flops(s, symtab)
                stats.hbm_bytes += io_bytes(s)
            elif op == "convolution":
                stats.flops += _conv_flops(s, symtab)
                stats.hbm_bytes += io_bytes(s)
            elif op == "fusion" or op == "copy" or op == "custom-call":
                fm = re.search(r"calls=%?([\w\.\-]+)", s)
                sliced = (_sliced_param_bytes(fm.group(1))
                          if fm and fm.group(1) in comps else None)
                stats.hbm_bytes += io_bytes(s, sliced)
                # count dots inside the fusion's computation
                if fm and fm.group(1) in comps:
                    fl_lines = comps[fm.group(1)]
                    fsym = _build_symtab(fl_lines)
                    for fl in fl_lines:
                        fs = fl.strip()
                        if " dot(" in fs:
                            stats.flops += _dot_flops(fs, fsym)
                        elif " convolution(" in fs:
                            stats.flops += _conv_flops(fs, fsym)
            elif op == "while":
                wm = re.search(r"condition=%?([\w\.\-]+),\s*body=%?"
                               r"([\w\.\-]+)", s)
                if wm:
                    tm = _TRIP_CFG.search(s)   # XLA's known_trip_count
                    trips = (float(tm.group(1)) if tm
                             else trip_count(wm.group(1)))
                    stats.while_trips.append(trips)
                    body_stats = analyze(wm.group(2), seen + (name,))
                    stats.add(body_stats, trips)
            elif op == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", s)
                called = []
                if bm:
                    called = [c.strip().lstrip("%")
                              for c in bm.group(1).split(",")]
                else:
                    tm = re.findall(r"(?:true|false)_computation=%?"
                                    r"([\w\.\-]+)", s)
                    called = tm
                for c in called:   # count every branch once (upper bound
                    if c in comps:  # ... for compute; both lower at runtime)
                        stats.add(analyze(c, seen + (name,)), 1.0)
            elif op == "call":
                cm = re.search(r"to_apply=%?([\w\.\-]+)", s)
                if cm and cm.group(1) in comps:
                    stats.add(analyze(cm.group(1), seen + (name,)), 1.0)
            else:
                for coll in _COLL_OPS:
                    if op == coll or op == coll + "-start":
                        raw = _line_result_bytes(s)
                        g = _group_size(s, n_chips)
                        frac = (g - 1) / g if g > 1 else 0.0
                        if coll == "all-reduce":
                            link = 2.0 * frac * raw
                        elif coll == "collective-permute":
                            link = raw
                        else:
                            link = frac * raw
                        stats.coll_counts[coll] = (
                            stats.coll_counts.get(coll, 0) + 1)
                        stats.coll_bytes[coll] = (
                            stats.coll_bytes.get(coll, 0.0) + raw)
                        stats.coll_link_bytes += link
                        stats.hbm_bytes += io_bytes(s)
                        break
        cache[name] = stats
        return stats

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
                break
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    return analyze(entry)


def _line_result_bytes(line: str) -> float:
    rhs = line.split(" = ", 1)
    if len(rhs) != 2:
        return 0.0
    head = rhs[1].split("(", 1)[0]
    if rhs[1].startswith("("):
        depth = 0
        for i, ch in enumerate(rhs[1]):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    head = rhs[1][: i + 1]
                    break
    return _shapes_bytes(head)


def _line_io_bytes(line: str) -> float:
    """result + operand bytes of one instruction line."""
    res = _line_result_bytes(line)
    start = line.find("(", line.find(" = "))
    ops = 0.0
    if start >= 0:
        depth = 0
        end = start
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        ops = _shapes_bytes(line[start:end + 1])
    return res + ops

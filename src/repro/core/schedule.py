"""Schedule emission — the compiler's "instruction generation" (paper §5.2, T5).

Snowflake's compiler walks the parsed layer objects and emits an
instruction stream: per-tile MAC/MAX loops with loads interleaved,
double-buffered instruction banks, bias/bypass VMOVs fused into the
writeback, and loop-vs-unroll decisions bounded by how much bookkeeping
hides under the vector-instruction latency.

The XLA analogue of the instruction stream is the compiled program; what
remains *ours* to decide is the schedule that parameterizes it.  This
module walks the ModelGraph and emits a ``LayerSchedule`` per node:

* tiling + dataflow (T2/T3, from tiling.py / dataflow.py),
* fusion flags — bias, activation, residual bypass folded into the
  producing kernel's epilogue (the paper's VMOV-on-writeback),
* a *bookkeeping ratio* check: epilogue work per tile relative to the
  MAC work of that tile.  The paper breaks/unrolls loops when scalar
  overhead can't hide under MAC latency; we grow the k-block (longer
  traces) when the ratio is too high,
* the distributed strategy + collective chunking (T3/T4),
* a remat (activation checkpoint) policy decided by the memory plan.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field

from .balance import balance_transfers, percent_imbalance
from .dataflow import (Dataflow, DataflowDecision, DistDecision,
                       choose_conv_dataflow, choose_dist_strategy,
                       choose_matmul_dataflow, materialization_roundtrip,
                       matmul_traffic)
from .hw import HardwareModel, MeshDescriptor, TPU_V5E
from .ir import (DepLabel, LayerKind, LayerNode, ModelGraph, _conv_out,
                 kernel_kind, pool_out)
from .regions import allocate_regions
from .tiling import (ConvTiling, MatmulTiling, conv_tiling_from,
                     enumerate_attention_blocks, matmul_vmem_bytes,
                     select_attention_blocks, select_conv_row_strips)

__all__ = ["LayerSchedule", "ModelSchedule", "compile_model"]


@dataclass(frozen=True)
class LayerSchedule:
    name: str
    kind: LayerKind
    dataflow: Dataflow | None            # None for non-matmul-like layers
    block: tuple[int, int, int] | None   # (bm, bk, bn) for matmul-like
    conv_tiling: ConvTiling | None
    fuse_bias: bool
    fuse_activation: str | None
    fuse_bypass: bool                    # residual add on writeback
    dist: DistDecision | None
    traffic_bytes: float
    flops: float
    bookkeeping_ratio: float             # epilogue ops / MAC ops per tile
    exec_time_s: float                   # hw.exec_time on this layer
    notes: dict = field(default_factory=dict)


@dataclass
class ModelSchedule:
    name: str
    layers: list[LayerSchedule]
    hw_name: str
    mesh: MeshDescriptor | None
    total_flops: float
    total_traffic_bytes: float
    total_exec_time_s: float
    memory_regions: dict
    load_imbalance_pct: float            # after T4 balancing
    remat_policy: str

    def layer(self, name: str) -> LayerSchedule:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    def summary(self) -> dict:
        return {
            "name": self.name,
            "layers": len(self.layers),
            "gflops": self.total_flops / 1e9,
            "traffic_gb": self.total_traffic_bytes / 1e9,
            "exec_time_ms": self.total_exec_time_s * 1e3,
            "avg_bw_gbps": (self.total_traffic_bytes
                            / max(self.total_exec_time_s, 1e-12) / 1e9),
            "load_imbalance_pct": self.load_imbalance_pct,
            "remat": self.remat_policy,
        }


def _epilogue_slots(node: LayerNode) -> int:
    """Count of per-output-element epilogue ops — the paper's bookkeeping
    instructions that must hide under MAC latency."""
    slots = 0
    if node.fused_bias:
        slots += 1
    if node.fused_activation:
        slots += 1
    if node.dep is DepLabel.RESIDUAL_SINK:
        slots += 2   # VMOV load of bypass + add (paper: VMOV per writeback MAC)
    return slots


def _tuned_matmul_decision(M: int, K: int, N: int, dtype_bytes: int,
                           hw: HardwareModel, entry: dict, *,
                           allow_output_stationary: bool
                           ) -> DataflowDecision | None:
    """A tuned-cache matmul entry as a DataflowDecision, or None when
    the entry is malformed or violates the feasibility constraints the
    chooser enforces (buffer caps, VMEM budget) — the caller then falls
    back to the analytic chooser, so a stale cache can degrade only to
    the untuned schedule, never to an unexecutable one."""
    try:
        df = Dataflow(entry["dataflow"])
        bm, bk, bn = (int(v) for v in entry["block"])
    except (KeyError, ValueError, TypeError):
        return None
    if df is Dataflow.OUTPUT_STATIONARY and not allow_output_stationary:
        return None
    budget = hw.vmem_budget()
    mcap = hw.maps_buffer_bytes or budget
    wcap = hw.weights_buffer_bytes or budget
    if df is Dataflow.MAPS_RESIDENT:
        vmem = matmul_vmem_bytes(bm, bk, bn, dtype_bytes, stream_a=False)
        fits = (bm * bk * dtype_bytes <= mcap
                and 2 * bk * bn * dtype_bytes <= wcap)
        grid = (math.ceil(M / bm), math.ceil(N / bn), 1)
    elif df is Dataflow.WEIGHTS_RESIDENT:
        vmem = matmul_vmem_bytes(bm, bk, bn, dtype_bytes, stream_b=False)
        fits = (bk * bn * dtype_bytes <= wcap
                and 2 * bm * bk * dtype_bytes <= mcap)
        grid = (math.ceil(M / bm), math.ceil(N / bn), 1)
    else:
        vmem = matmul_vmem_bytes(bm, bk, bn, dtype_bytes)
        fits = (2 * bm * bk * dtype_bytes <= mcap
                and 2 * bk * bn * dtype_bytes <= wcap)
        grid = (math.ceil(M / bm), math.ceil(N / bn), math.ceil(K / bk))
    if not fits or vmem > budget:
        return None
    tr = matmul_traffic(M, K, N, dtype_bytes, df, bm, bk, bn)
    return DataflowDecision(
        dataflow=df, tiling=MatmulTiling(bm, bk, bn, vmem, grid),
        traffic_bytes=tr, alternatives={df.value: tr, "tuned": True})


def _schedule_matmul(node: LayerNode, hw: HardwareModel,
                     mesh: MeshDescriptor | None,
                     paper_faithful: bool,
                     entry: dict | None = None) -> LayerSchedule:
    d = node.dims
    M, K, N = d["M"], d["K"], d["N"]
    dec: DataflowDecision | None = None
    if entry is not None and entry.get("kind") == "matmul":
        dec = _tuned_matmul_decision(
            M, K, N, node.dtype_bytes, hw, entry,
            allow_output_stationary=not paper_faithful)
    if dec is None:
        dec = choose_matmul_dataflow(
            M, K, N, node.dtype_bytes, hw,
            allow_output_stationary=not paper_faithful)
    t = dec.tiling
    # Bookkeeping check (paper §5.2): epilogue work per tile vs MAC work.
    # MAC ops per output element along the trace = 2*bk; epilogue slots
    # are per element.  Grow traces (bk) if the ratio exceeds ~1/16.
    slots = _epilogue_slots(node)
    ratio = (slots * hw.epilogue_slot_flops) / max(2.0 * t.bk, 1.0)
    notes = dict(dec.alternatives)
    if ratio > 1.0 / 16.0 and t.bk < K:
        notes["bookkeeping"] = f"ratio {ratio:.3f} high; prefer larger bk"

    dist = None
    if mesh is not None and mesh.model > 1:
        dist = choose_dist_strategy(
            M_local=max(1, M // max(mesh.data, 1)), K=K, N=N,
            dtype_bytes=node.dtype_bytes, mesh=mesh, hw=hw,
            overlappable_flops=2.0 * (M / max(mesh.data, 1)) * K * N
            / max(mesh.model, 1))

    flops = node.flops()
    return LayerSchedule(
        name=node.name, kind=node.kind, dataflow=dec.dataflow,
        block=(t.bm, t.bk, t.bn), conv_tiling=None,
        fuse_bias=node.fused_bias, fuse_activation=node.fused_activation,
        fuse_bypass=node.dep is DepLabel.RESIDUAL_SINK, dist=dist,
        traffic_bytes=dec.traffic_bytes, flops=flops,
        bookkeeping_ratio=ratio,
        exec_time_s=hw.exec_time(flops, dec.traffic_bytes), notes=notes)


def _schedule_conv(node: LayerNode, hw: HardwareModel,
                   paper_faithful: bool,
                   charge_materialization: bool = True,
                   entry: dict | None = None) -> LayerSchedule:
    d = node.dims
    # A tuned-cache entry pins (out_rows, kernels_per_tile, storage,
    # loop order) without calling the chooser; ``conv_tiling_from``
    # re-validates the feasibility constraints, so a stale entry falls
    # back to the analytic pick instead of emitting an unexecutable
    # schedule.
    ct = forced_df = None
    if entry is not None and entry.get("kind") == "conv2d":
        try:
            ct = conv_tiling_from(
                d["H"], d["W"], d["C_in"], d["C_out"], d["kh"], d["kw"],
                d["stride"], d["pad"], node.dtype_bytes, hw,
                out_rows=entry["out_rows"],
                kernels_per_tile=entry["kernels_per_tile"],
                strip_storage=entry["strip_storage"],
                batch=d.get("batch", 1))
            forced_df = Dataflow(entry["dataflow"])
            if paper_faithful and ct.strip_storage != "materialized":
                ct = forced_df = None
        except (KeyError, ValueError):
            ct = forced_df = None
    if ct is None:
        ct = select_conv_row_strips(d["H"], d["W"], d["C_in"], d["C_out"],
                                    d["kh"], d["kw"], d["stride"], d["pad"],
                                    node.dtype_bytes, hw,
                                    batch=d.get("batch", 1))
    # Strip storage is a compiler decision (overlap duplication vs
    # in-kernel re-fetch); the paper-faithful mode pins Snowflake's
    # DMA-mandated materialization.
    storage = "materialized" if paper_faithful else ct.strip_storage
    ob = node.operand_bytes()
    # The pool only actually fuses on the zero-copy path (ops.py runs a
    # separate reference pool when strips are materialized), so model it
    # only there — the pool node keeps its own traffic otherwise.
    fp = node.meta.get("fused_pool") if storage == "virtual" else None
    if fp:
        # The following maxpool runs in this conv's epilogue: the conv
        # output is pooled before writeback, shrinking the out stream.
        oh = pool_out(_conv_out(d["H"], d["kh"], d["stride"], d["pad"]),
                      fp["window"], fp["stride"], fp.get("pad", 0))
        ow = pool_out(_conv_out(d["W"], d["kw"], d["stride"], d["pad"]),
                      fp["window"], fp["stride"], fp.get("pad", 0))
        ob["out"] = d.get("batch", 1) * oh * ow * d["C_out"] * node.dtype_bytes
    # Mloop/Kloop on the strip grid — shared formulas (core/dataflow.py):
    # virtual strips stop charging the (1 + overlap_frac) duplication.
    df, traffic, alts = choose_conv_dataflow(
        ob["maps"], ob["weights"], ob["out"],
        n_map_tiles=ct.n_map_tiles, n_kernel_tiles=ct.n_kernel_tiles,
        overlap_frac=ct.overlap_frac, strip_storage=storage,
        charge_materialization=charge_materialization)
    kloop, mloop = alts["kloop"], alts["mloop"]
    if forced_df is not None:
        # The tuned loop order may differ from the analytic argmin —
        # that is the point: the measurement outranks the formula.
        df = forced_df
        traffic = kloop if df is Dataflow.MAPS_RESIDENT else mloop
    # The materialization round trip (read maps + write the halo-
    # augmented strips) that conv_strip_traffic charges, made visible.
    roundtrip = 0.0
    if storage == "materialized" and charge_materialization:
        roundtrip = materialization_roundtrip(ob["maps"], ct.overlap_frac)
    slots = _epilogue_slots(node)
    if fp:
        # The fused pool adds window^2 compares per pooled element —
        # ~window^2/stride^2 extra bookkeeping slots per conv output
        # element that must hide under the MAC latency.
        slots += fp["window"] ** 2 / float(fp["stride"] ** 2)
    trace = d["C_in"] * d["kh"] * d["kw"]     # the paper's "trace" length
    ratio = (slots * hw.epilogue_slot_flops) / max(2.0 * trace, 1.0)
    flops = node.flops()
    # Paper §5.2 stall model: bookkeeping (loop control, loads, bias /
    # bypass VMOVs) must hide under the vector-MAC latency (trace/width
    # cycles); short traces with fused bypass stall the CUs — "the last
    # 1x1 CONVs of ResNet18 and ResNet50".
    stall = 1.0
    if hw.epilogue_slot_flops:
        mac_cycles = max(trace / hw.mxu_dim, 1.0)
        bookkeeping = (6.0 + (6.0 if node.dep is DepLabel.RESIDUAL_SINK
                              else 0.0) + (2.0 if node.fused_bias else 0.0)
                       + (float(fp["window"] ** 2) if fp else 0.0))
        stall = max(1.0, bookkeeping / mac_cycles)
    t_exec = max(hw.compute_time(flops) * stall, hw.memory_time(traffic))
    notes = {"kloop": kloop, "mloop": mloop, "stall": stall,
             "strip_storage": storage}
    if forced_df is not None:
        notes["tuned"] = True
    if roundtrip:
        notes["materialize_roundtrip"] = roundtrip
    if fp:
        notes["fused_pool"] = fp
    return LayerSchedule(
        name=node.name, kind=node.kind, dataflow=df, block=None,
        conv_tiling=ct, fuse_bias=node.fused_bias,
        fuse_activation=node.fused_activation,
        fuse_bypass=node.dep is DepLabel.RESIDUAL_SINK, dist=None,
        traffic_bytes=traffic, flops=flops, bookkeeping_ratio=ratio,
        exec_time_s=t_exec, notes=notes)


def _schedule_attention(node: LayerNode, hw: HardwareModel,
                        entry: dict | None = None) -> LayerSchedule:
    """Flash-attention schedule: the (block_q, block_kv) tile pair is a
    compiler decision (T2 on the score loop), pinned into the Program so
    the kernel wrapper never re-derives it at run time.  A decode node
    (seq_q == 1, persistent KV cache) gets its cache-streaming block
    from the same chooser's decode regime."""
    d = node.dims
    page_size = node.meta.get("page_size")
    bq = bkv = tuned = None
    if entry is not None and entry.get("kind") in ("flash_attention",
                                                   "decode_attention"):
        cand = (int(entry.get("block_q", 1)), int(entry["block_kv"]))
        # Validate against the same VMEM test the chooser applies: a
        # tuned pair outside the feasible set falls back.  A paged
        # decode node's feasible set is the singleton (1, page_size).
        if cand in enumerate_attention_blocks(
                d["seq_q"], d["seq_kv"], d["head_dim"], node.dtype_bytes,
                hw, window=node.meta.get("window"), page_size=page_size):
            bq, bkv = cand
            tuned = True
    if bq is None:
        bq, bkv = select_attention_blocks(d["seq_q"], d["seq_kv"],
                                          d["head_dim"], node.dtype_bytes,
                                          hw, window=node.meta.get("window"),
                                          page_size=page_size)
    flops = node.flops()
    traffic = node.min_bytes()
    notes = {"block_q": bq, "block_kv": bkv,
             "causal": bool(d.get("causal", True))}
    if tuned:
        notes["tuned"] = True
    if node.meta.get("decode"):
        notes["decode"] = True
    if node.meta.get("window"):
        notes["window"] = node.meta["window"]
    if page_size:
        notes["page_size"] = page_size
    return LayerSchedule(
        name=node.name, kind=node.kind, dataflow=None, block=None,
        conv_tiling=None, fuse_bias=False, fuse_activation=None,
        fuse_bypass=node.dep is DepLabel.RESIDUAL_SINK, dist=None,
        traffic_bytes=traffic, flops=flops, bookkeeping_ratio=0.0,
        exec_time_s=hw.exec_time(flops, traffic), notes=notes)


def _schedule_other(node: LayerNode, hw: HardwareModel, *,
                    fused: bool = False) -> LayerSchedule:
    flops = node.flops()
    traffic = node.min_bytes()
    if fused:
        # This layer (a maxpool) runs inside its producer conv's
        # epilogue: no separate kernel launch, no HBM round trip.
        return LayerSchedule(
            name=node.name, kind=node.kind, dataflow=None, block=None,
            conv_tiling=None, fuse_bias=False, fuse_activation=None,
            fuse_bypass=False, dist=None, traffic_bytes=0.0, flops=flops,
            bookkeeping_ratio=0.0, exec_time_s=0.0,
            notes={"fused_into": node.meta["fused_into"]})
    return LayerSchedule(
        name=node.name, kind=node.kind, dataflow=None, block=None,
        conv_tiling=None, fuse_bias=node.fused_bias,
        fuse_activation=node.fused_activation,
        fuse_bypass=node.dep is DepLabel.RESIDUAL_SINK, dist=None,
        traffic_bytes=traffic, flops=flops, bookkeeping_ratio=0.0,
        exec_time_s=hw.exec_time(flops, traffic))


def compile_model(graph: ModelGraph, hw: HardwareModel = TPU_V5E, *,
                  mesh: MeshDescriptor | None = None,
                  paper_faithful: bool = False,
                  charge_materialization: bool = True,
                  hbm_activation_budget: float | None = None,
                  tuned=None, cost_model=None
                  ) -> ModelSchedule:
    """Walk the graph and emit the full model schedule.

    ``paper_faithful=True`` restricts dataflows to the paper's two loop
    orders (Mloop/Kloop) — used as the reproduction baseline; the default
    additionally considers the output-stationary generalization.
    ``charge_materialization=False`` drops the materialized-strip round
    trip from the traffic model (the paper's Fig. 4 / Table 2 frame,
    which counts only the conv's own streams).

    ``tuned`` is a measured-schedule lookup (``core/autotune.TunedView``
    or anything with ``.lookup(node) -> dict | None``): a hit overrides
    the analytic chooser's decision for that op, after re-validation
    against this ``hw``'s feasibility constraints.  ``cost_model`` is a
    calibrated ``core/cost.CostModel``; when given, every layer's
    ``exec_time_s`` is re-priced from measured coefficients instead of
    the raw analytic ``hw.exec_time``.
    """
    graph.mark_residuals()
    graph.mark_pool_fusion()
    layers: list[LayerSchedule] = []
    for node in graph:
        entry = tuned.lookup(node) if tuned is not None and node.kind in (
            LayerKind.CONV2D, LayerKind.MATMUL, LayerKind.ATTENTION) else None
        if node.kind in (LayerKind.MATMUL, LayerKind.MOE):
            if node.kind is LayerKind.MOE:
                # Schedule one expert matmul; dispatch handled by T4.
                mm = LayerNode(name=node.name, kind=LayerKind.MATMUL,
                               dims={"M": node.dims["M"] * node.dims["top_k"]
                                     // max(node.dims["experts"], 1) or 1,
                                     "K": node.dims["K"],
                                     "N": node.dims["N"]},
                               dtype_bytes=node.dtype_bytes,
                               fused_bias=node.fused_bias,
                               fused_activation=node.fused_activation,
                               bypass_of=node.bypass_of, dep=node.dep)
                s = _schedule_matmul(mm, hw, mesh, paper_faithful)
                # Account all experts' weights + routed tokens.
                ob = node.operand_bytes()
                traffic = ob["maps"] + ob["weights"] + ob["out"]
                s = LayerSchedule(**{**s.__dict__,
                                     "kind": LayerKind.MOE,
                                     "flops": node.flops(),
                                     "traffic_bytes": traffic,
                                     "exec_time_s": hw.exec_time(node.flops(), traffic)})
                layers.append(s)
            else:
                layers.append(_schedule_matmul(node, hw, mesh, paper_faithful,
                                               entry=entry))
        elif node.kind is LayerKind.CONV2D:
            layers.append(_schedule_conv(node, hw, paper_faithful,
                                         charge_materialization, entry=entry))
        elif node.kind is LayerKind.ATTENTION:
            layers.append(_schedule_attention(node, hw, entry=entry))
        else:
            # A pool is only free if its producer conv actually fused
            # it (recorded in the conv's schedule notes — requires the
            # zero-copy path; materialized strips pool separately).
            src = node.meta.get("fused_into")
            fused = any(ls.name == src and "fused_pool" in ls.notes
                        for ls in layers) if src else False
            layers.append(_schedule_other(node, hw, fused=fused))

    if cost_model is not None:
        # Re-price from measured coefficients.  Fused-away ops (zero
        # flops, zero traffic) stay free — γ would otherwise charge a
        # dispatch that never happens.  layers is 1:1 with graph nodes.
        layers = [
            ls if (ls.exec_time_s == 0 and ls.traffic_bytes == 0) else
            dataclasses.replace(ls, exec_time_s=cost_model.predict(
                kernel_kind(node), ls.flops, ls.traffic_bytes,
                ls.exec_time_s))
            for node, ls in zip(graph, layers)]

    # T4: balance each layer's tile transfers across load units and report
    # the residual imbalance (drives the Table 3 reproduction).
    imb = []
    for ls in layers:
        if ls.kind in (LayerKind.MATMUL, LayerKind.CONV2D, LayerKind.MOE):
            n = max(1, hw.load_units)
            # transfers: weights stream + maps stream per tile (coarse).
            w = ls.traffic_bytes * 0.5
            m = ls.traffic_bytes * 0.5
            res = balance_transfers([int(m), int(w)], n)
            imb.append(res.imbalance_after)
    avg_imb = sum(imb) / len(imb) if imb else 0.0

    # Remat policy from a coarse activation-memory plan.
    total_act = sum(l.traffic_bytes for l in layers
                    if l.kind is not LayerKind.EMBED) * 0.25
    budget = hbm_activation_budget or hw.hbm_bytes * 0.3
    if mesh is not None:
        budget *= mesh.n_chips
    remat = "none" if total_act < budget else (
        "block" if total_act < 4 * budget else "full")

    sched = ModelSchedule(
        name=graph.name, layers=layers, hw_name=hw.name, mesh=mesh,
        total_flops=sum(l.flops for l in layers),
        total_traffic_bytes=sum(l.traffic_bytes for l in layers),
        total_exec_time_s=sum(l.exec_time_s for l in layers),
        memory_regions={},
        load_imbalance_pct=avg_imb, remat_policy=remat)
    # §5.1 region counts come from the one real allocator (the same one
    # the executable Program reserves with) — no separate heuristic.
    plan = allocate_regions(graph, sched)
    sched.memory_regions = {"pingpong": plan.n_pingpong,
                            "residual": plan.n_pinned,
                            "total_bytes": plan.total_bytes}
    return sched

"""Layer-graph IR — the compiler's planning substrate (paper §5.1, T1).

The paper's compiler parses a Torch7 model into a doubly-linked list of
layer objects (step 1), then scans for non-sequential inter-layer
relations — residual/parallel paths — and attaches *dependency labels*
(step 2) that drive memory-region allocation and the fused bypass add.

This module is the JAX analogue: model configs are lowered into a
``ModelGraph`` of ``LayerNode``s.  Each node carries a workload
descriptor (enough to compute FLOPs / bytes / tile shapes), a dependency
label, and an optional ``bypass_of`` back-reference (the paper's
residual-add-on-writeback).  The schedule compiler (core/schedule.py)
consumes this graph; the models themselves execute separately and are
*parameterized* by the resulting schedule.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Iterator

__all__ = [
    "LayerKind",
    "DepLabel",
    "LayerNode",
    "ModelGraph",
    "matmul_node",
    "conv_node",
    "attention_node",
    "decode_attention_node",
    "cross_attention_node",
    "ssm_scan_node",
    "wkv_node",
    "moe_node",
    "norm_node",
    "embed_node",
    "elementwise_node",
    "pool_out",
    "kernel_kind",
]


class LayerKind(enum.Enum):
    MATMUL = "matmul"          # any dense projection (QKV, O, FFN, FC, lm head)
    CONV2D = "conv2d"          # the paper's own workloads
    ATTENTION = "attention"    # softmax attention (flash kernel)
    SSM_SCAN = "ssm_scan"      # Mamba2 chunked scan
    WKV = "wkv"                # RWKV6 recurrence
    MOE = "moe"                # expert-parallel grouped matmul
    NORM = "norm"
    EMBED = "embed"
    POOL = "pool"              # max/avg pool (paper's Maxpool/Avgpool)
    ELEMENTWISE = "elementwise"


class DepLabel(enum.Enum):
    """Paper §5.1 step 2: how a layer relates to its neighbours.

    SEQUENTIAL       — input comes only from the previous layer.
    RESIDUAL_SOURCE  — output is additionally consumed by a later bypass.
    RESIDUAL_SINK    — consumes a bypass; the add is fused into this
                       layer's writeback (paper: VMOV per write-back MAC).
    PARALLEL         — one of several layers sharing an input (GoogLeNet-
                       style branches; cross-attn streams in the VLM).
    """

    SEQUENTIAL = "sequential"
    RESIDUAL_SOURCE = "residual_source"
    RESIDUAL_SINK = "residual_sink"
    PARALLEL = "parallel"


@dataclass
class LayerNode:
    name: str
    kind: LayerKind
    # Workload descriptor.  Keys by kind:
    #   MATMUL: M, K, N                       (+ optional "groups" for GQA KV)
    #   CONV2D: H, W, C_in, C_out, kh, kw, stride, pad, batch
    #   ATTENTION: seq_q, seq_kv, heads, kv_heads, head_dim, batch, causal
    #   SSM_SCAN: seq, heads, head_dim, state, batch
    #   WKV: seq, heads, head_dim, batch
    #   MOE: M (tokens), K, N, experts, top_k
    #   NORM/ELEMENTWISE/POOL/EMBED: numel (+ EMBED: vocab, d_model)
    dims: dict = field(default_factory=dict)
    dtype_bytes: int = 2
    inputs: list[str] = field(default_factory=list)
    dep: DepLabel = DepLabel.SEQUENTIAL
    bypass_of: str | None = None   # residual source this sink adds on writeback
    # Epilogue ops fused into the producing kernel (paper's bias VMOV / ReLU).
    fused_bias: bool = False
    fused_activation: str | None = None  # "relu" | "silu" | "gelu" | None
    meta: dict = field(default_factory=dict)

    # --- workload accounting --------------------------------------------------
    def flops(self) -> float:
        d = self.dims
        k = self.kind
        if k is LayerKind.MATMUL:
            return 2.0 * d["M"] * d["K"] * d["N"]
        if k is LayerKind.CONV2D:
            oh = _conv_out(d["H"], d["kh"], d["stride"], d["pad"])
            ow = _conv_out(d["W"], d["kw"], d["stride"], d["pad"])
            return (2.0 * d.get("batch", 1) * oh * ow * d["C_out"]
                    * d["C_in"] * d["kh"] * d["kw"])
        if k is LayerKind.ATTENTION:
            b, h, hd = d["batch"], d["heads"], d["head_dim"]
            sq, skv = d["seq_q"], d["seq_kv"]
            causal = 0.5 if d.get("causal") and sq == skv else 1.0
            return 2.0 * 2.0 * b * h * sq * skv * hd * causal  # QK^T + PV
        if k is LayerKind.SSM_SCAN:
            b, h, hd, st = d["batch"], d["heads"], d["head_dim"], d["state"]
            return 2.0 * 3.0 * b * d["seq"] * h * hd * st      # dA, B-outer, C-contract
        if k is LayerKind.WKV:
            b, h, hd = d["batch"], d["heads"], d["head_dim"]
            return 2.0 * 2.0 * b * d["seq"] * h * hd * hd       # state update + readout
        if k is LayerKind.MOE:
            return 2.0 * d["M"] * d["K"] * d["N"] * d["top_k"]
        if k is LayerKind.EMBED:
            return 0.0
        return float(d.get("numel", 0))  # ~1 FLOP/elem for norms/elementwise

    def operand_bytes(self) -> dict[str, float]:
        """Minimum off-chip bytes per operand class (each element once)."""
        d, k = self.dims, self.kind
        by = self.dtype_bytes
        if k is LayerKind.MATMUL:
            return {"maps": d["M"] * d["K"] * by,
                    "weights": d["K"] * d["N"] * by,
                    "out": d["M"] * d["N"] * by}
        if k is LayerKind.CONV2D:
            oh = _conv_out(d["H"], d["kh"], d["stride"], d["pad"])
            ow = _conv_out(d["W"], d["kw"], d["stride"], d["pad"])
            b = d.get("batch", 1)
            return {"maps": b * d["H"] * d["W"] * d["C_in"] * by,
                    "weights": d["C_in"] * d["kh"] * d["kw"] * d["C_out"] * by,
                    "out": b * oh * ow * d["C_out"] * by}
        if k is LayerKind.MOE:
            return {"maps": d["M"] * d["K"] * by * d["top_k"],
                    "weights": d["experts"] * d["K"] * d["N"] * by,
                    "out": d["M"] * d["N"] * by * d["top_k"]}
        if k is LayerKind.SSM_SCAN:
            # Coarse Mamba2 block: h/x/dt/B/C streams in, h' out, plus
            # the recurrent state's read+write round trip (f32).
            b, h, hd, st = d["batch"], d["heads"], d["head_dim"], d["state"]
            dm = d.get("d_model", h * hd)
            return {"maps": b * d["seq"] * dm * by
                    + 2.0 * b * h * hd * st * 4.0,
                    "weights": float(d.get("weight_bytes", 0)),
                    "out": b * d["seq"] * dm * by}
        if k is LayerKind.WKV:
            # Coarse RWKV6 block: activations in/out plus the (h, hd,
            # hd) wkv state round trip (f32).
            b, h, hd = d["batch"], d["heads"], d["head_dim"]
            dm = d.get("d_model", h * hd)
            return {"maps": b * d["seq"] * dm * by
                    + 2.0 * b * h * hd * hd * 4.0,
                    "weights": float(d.get("weight_bytes", 0)),
                    "out": b * d["seq"] * dm * by}
        if k is LayerKind.ATTENTION:
            b, h, hd = d["batch"], d["heads"], d["head_dim"]
            kvh = d.get("kv_heads", h)
            q = b * h * d["seq_q"] * hd * by
            kv = 2 * b * kvh * d["seq_kv"] * hd * by
            return {"maps": q + kv, "weights": 0.0, "out": q}
        if k is LayerKind.EMBED:
            # maps: the int32 token ids; weights: the gathered rows (one
            # table row per token, not the whole table); out: the dense
            # activations the rest of the chain consumes.
            toks = d.get("tokens", d.get("numel", 0))
            dm = d.get("d_model", 1)
            return {"maps": toks * 4.0,
                    "weights": toks * dm * by,
                    "out": toks * dm * by}
        n = float(d.get("numel", 0))
        # Binary elementwise ops (GLU mul) stream both operands.
        reads = max(len(self.inputs), 1) if k is LayerKind.ELEMENTWISE else 1
        return {"maps": reads * n * by, "weights": 0.0, "out": n * by}

    def min_bytes(self) -> float:
        return sum(self.operand_bytes().values())

    def arithmetic_intensity(self) -> float:
        b = self.min_bytes()
        return self.flops() / b if b else float("inf")


def _conv_out(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


def kernel_kind(node: "LayerNode") -> str:
    """The executor kernel a node lowers to — the kind key shared by
    trace records (``runtime/executor``), cost-model fits
    (``core/cost``), and tuned-cache signatures (``core/autotune``)."""
    if node.kind is LayerKind.CONV2D:
        return "conv2d"
    if node.kind is LayerKind.MATMUL:
        return "matmul"
    if node.kind is LayerKind.MOE:
        return "moe_dispatch"
    if node.kind is LayerKind.ATTENTION:
        if node.meta.get("cross"):
            return "cross_attention"
        return ("decode_attention" if node.meta.get("decode")
                else "flash_attention")
    if node.kind is LayerKind.POOL:
        return "avgpool" if node.meta.get("op") == "avg" else "maxpool"
    if node.kind is LayerKind.EMBED:
        return "embed"
    if node.kind is LayerKind.NORM:
        return "norm"
    return node.meta.get("op", node.kind.value)


def pool_out(size: int, window: int, stride: int, pad: int = 0) -> int:
    """Pooled output extent — one definition shared by the scheduler and
    the conv2d fused-pool path (same formula as _conv_out, named for the
    call sites that mean pooling)."""
    return (size + 2 * pad - window) // stride + 1


# --- graph --------------------------------------------------------------------
@dataclass
class ModelGraph:
    """Ordered layer graph.  The paper's doubly-linked list + labels."""

    name: str
    nodes: list[LayerNode] = field(default_factory=list)

    def add(self, node: LayerNode) -> LayerNode:
        if node.name in self._index():
            raise ValueError(f"duplicate layer name: {node.name}")
        self.nodes.append(node)
        return node

    def _index(self) -> dict[str, LayerNode]:
        return {n.name: n for n in self.nodes}

    def __iter__(self) -> Iterator[LayerNode]:
        return iter(self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)

    def get(self, name: str) -> LayerNode:
        return self._index()[name]

    def _consumers(self) -> dict[str, list[str]]:
        """name -> names of nodes reading it via ``inputs`` (bypass_of
        reads are tracked separately by the passes that care)."""
        consumers: dict[str, list[str]] = {}
        for n in self.nodes:
            for inp in n.inputs:
                consumers.setdefault(inp, []).append(n.name)
        return consumers

    # --- paper step 2: dependency labelling -----------------------------------
    def mark_residuals(self) -> None:
        """Scan inter-layer relations and attach dependency labels.

        Any node consumed by a non-adjacent later node becomes a
        RESIDUAL_SOURCE; the consumer that lists it in ``bypass_of``
        becomes a RESIDUAL_SINK.  Nodes sharing an input are PARALLEL.
        """
        idx = self._index()
        consumers = self._consumers()
        order = {n.name: i for i, n in enumerate(self.nodes)}
        for n in self.nodes:
            if n.bypass_of is not None:
                n.dep = DepLabel.RESIDUAL_SINK
                src = idx.get(n.bypass_of)
                if src is not None and src.dep is DepLabel.SEQUENTIAL:
                    src.dep = DepLabel.RESIDUAL_SOURCE
        for src, cons in consumers.items():
            if len(cons) > 1:
                for c in cons:
                    node = idx[c]
                    if node.dep is DepLabel.SEQUENTIAL:
                        node.dep = DepLabel.PARALLEL
                if src in idx and idx[src].dep is DepLabel.SEQUENTIAL:
                    idx[src].dep = DepLabel.RESIDUAL_SOURCE
        # Sanity: a sink's source must precede it.
        for n in self.nodes:
            if n.bypass_of and n.bypass_of in order:
                if order[n.bypass_of] >= order[n.name]:
                    raise ValueError(
                        f"bypass source {n.bypass_of} does not precede {n.name}")

    def mark_pool_fusion(self) -> None:
        """Mark conv -> pool pairs fusable into the conv's epilogue.

        Fusable when the pool directly follows the conv, consumes only
        it, and the raw conv output has no other reader (no residual /
        parallel path off it) — then the pool can run on-chip before
        writeback and its HBM round trip vanishes.  Both max and avg
        pools fuse; the pool op rides along in the meta so the epilogue
        knows whether to take a running max or a window-sum/divide.
        This is a *graph* property; whether the fusion actually
        executes is the scheduler's call (it needs the zero-copy strip
        path), recorded in the conv's ``LayerSchedule.notes``.
        """
        consumers = self._consumers()
        bypass_sources = {n.bypass_of for n in self.nodes if n.bypass_of}
        for i, n in enumerate(self.nodes[:-1]):
            nxt = self.nodes[i + 1]
            if (n.kind is not LayerKind.CONV2D
                    or nxt.kind is not LayerKind.POOL
                    or nxt.meta.get("op", "max") not in ("max", "avg")
                    or "window" not in nxt.meta
                    or nxt.inputs != [n.name]
                    or n.name in bypass_sources
                    or consumers.get(n.name, []) != [nxt.name]):
                continue
            n.meta["fused_pool"] = {"window": nxt.meta["window"],
                                    "stride": nxt.meta["stride"],
                                    "pad": nxt.meta.get("pad", 0),
                                    "op": nxt.meta.get("op", "max")}
            nxt.meta["fused_into"] = n.name

    # --- aggregates ------------------------------------------------------------
    def total_flops(self) -> float:
        return sum(n.flops() for n in self.nodes)

    def total_min_bytes(self) -> float:
        return sum(n.min_bytes() for n in self.nodes)


# --- node constructors ----------------------------------------------------------
def matmul_node(name: str, M: int, K: int, N: int, *, dtype_bytes: int = 2,
                inputs: list[str] | None = None, bypass_of: str | None = None,
                fused_bias: bool = False, fused_activation: str | None = None,
                **meta) -> LayerNode:
    return LayerNode(
        name=name, kind=LayerKind.MATMUL,
        dims={"M": M, "K": K, "N": N}, dtype_bytes=dtype_bytes,
        inputs=inputs or [], bypass_of=bypass_of, fused_bias=fused_bias,
        fused_activation=fused_activation, meta=meta)


def attention_node(name: str, *, seq_q: int, seq_kv: int, heads: int,
                   kv_heads: int, head_dim: int, batch: int = 1,
                   causal: bool = True, dtype_bytes: int = 2,
                   inputs: list[str] | None = None, **meta) -> LayerNode:
    """Softmax-attention node; ``inputs`` is [q, k, v] producer names."""
    return LayerNode(
        name=name, kind=LayerKind.ATTENTION,
        dims={"seq_q": seq_q, "seq_kv": seq_kv, "heads": heads,
              "kv_heads": kv_heads, "head_dim": head_dim, "batch": batch,
              "causal": causal},
        dtype_bytes=dtype_bytes, inputs=inputs or [], meta=meta)


def decode_attention_node(name: str, *, cache_len: int, heads: int,
                          kv_heads: int, head_dim: int, slots: int,
                          k_cache: str, v_cache: str, dtype_bytes: int = 2,
                          window: int | None = None,
                          inputs: list[str] | None = None,
                          **meta) -> LayerNode:
    """Single-token decode attention against a persistent KV cache.

    ``inputs`` is [q, k_new, v_new] producer names (the per-token QKV
    projections); ``k_cache`` / ``v_cache`` name the *persistent*
    regions (core/regions.py) the op reads the history from and writes
    the new token's K/V into at the per-slot position — the position is
    a runtime operand carried by the executor's ``ProgramState``, never
    baked into the instruction stream.

    ``window`` marks sliding-window attention: the §5.1 region plan
    then sizes the cache at ``cache_len = min(max_len, window)`` rows
    per slot and eviction is the rolling overwrite at ``pos %
    cache_len`` — older rows are never attendable, so they never need
    to be resident."""
    win_meta = {"window": window} if window else {}
    return LayerNode(
        name=name, kind=LayerKind.ATTENTION,
        dims={"seq_q": 1, "seq_kv": cache_len, "heads": heads,
              "kv_heads": kv_heads, "head_dim": head_dim, "batch": slots,
              "causal": True},
        dtype_bytes=dtype_bytes, inputs=inputs or [],
        meta={"decode": True, "k_cache": k_cache, "v_cache": v_cache,
              **win_meta, **meta})


def cross_attention_node(name: str, *, seq_q: int, mem_len: int, heads: int,
                         kv_heads: int, head_dim: int, batch: int = 1,
                         k_mem: str, v_mem: str, dtype_bytes: int = 2,
                         decode: bool = False,
                         inputs: list[str] | None = None, **meta) -> LayerNode:
    """Cross-attention against *read-only* persistent encoder memory.

    ``inputs`` is just [q]; ``k_mem`` / ``v_mem`` name the persistent
    regions (core/regions.py state_specs) holding the encoder's K/V,
    written once at admission and only ever read afterwards — there is
    no per-token cache write and no ring, so the op is position-free.
    The decode variant reads the same regions at batch = slots."""
    return LayerNode(
        name=name, kind=LayerKind.ATTENTION,
        dims={"seq_q": seq_q, "seq_kv": mem_len, "heads": heads,
              "kv_heads": kv_heads, "head_dim": head_dim, "batch": batch,
              "causal": False},
        dtype_bytes=dtype_bytes, inputs=inputs or [],
        meta={"cross": True, "k_cache": k_mem, "v_cache": v_mem,
              **({"decode": True} if decode else {}), **meta})


def ssm_scan_node(name: str, *, seq: int, heads: int, head_dim: int,
                  state: int, d_model: int, batch: int = 1,
                  weight_bytes: float = 0.0, dtype_bytes: int = 2,
                  inputs: list[str] | None = None,
                  bypass_of: str | None = None, **meta) -> LayerNode:
    """Coarse Mamba2 block op: norm + in_proj + causal conv + selective
    scan + gated out_proj, residual add fused on the writeback.  ``meta``
    names the persistent recurrence regions (``ssm_state`` and
    ``conv_state``) and the stacked-parameter group path."""
    return LayerNode(
        name=name, kind=LayerKind.SSM_SCAN,
        dims={"seq": seq, "heads": heads, "head_dim": head_dim,
              "state": state, "d_model": d_model, "batch": batch,
              "weight_bytes": weight_bytes},
        dtype_bytes=dtype_bytes, inputs=inputs or [], bypass_of=bypass_of,
        meta=meta)


def wkv_node(name: str, *, seq: int, heads: int, head_dim: int,
             d_model: int, batch: int = 1, weight_bytes: float = 0.0,
             dtype_bytes: int = 2, inputs: list[str] | None = None,
             **meta) -> LayerNode:
    """Coarse RWKV6 block op: ln1 + time-mix (wkv recurrence) + ln2 +
    channel-mix, both residual adds internal.  ``meta`` names the
    persistent ``wkv_state`` / ``shift_t`` / ``shift_c`` regions and
    the stacked-parameter group path."""
    return LayerNode(
        name=name, kind=LayerKind.WKV,
        dims={"seq": seq, "heads": heads, "head_dim": head_dim,
              "d_model": d_model, "batch": batch,
              "weight_bytes": weight_bytes},
        dtype_bytes=dtype_bytes, inputs=inputs or [], meta=meta)


def moe_node(name: str, *, tokens: int, d_model: int, d_ff: int,
             experts: int, top_k: int, dtype_bytes: int = 2,
             inputs: list[str] | None = None, bypass_of: str | None = None,
             fused_activation: str | None = None, **meta) -> LayerNode:
    """Capacity-bucketed expert-MLP dispatch (paper §6 load balancing):
    route each token to its top-k experts, bucket per expert up to the
    capacity granule, run the expert FFN as grouped matmuls, and
    combine weighted by the router probabilities.  One op per MoE
    layer's MLP; the residual add fuses on the writeback."""
    return LayerNode(
        name=name, kind=LayerKind.MOE,
        dims={"M": tokens, "K": d_model, "N": d_ff,
              "experts": experts, "top_k": top_k},
        dtype_bytes=dtype_bytes, inputs=inputs or [], bypass_of=bypass_of,
        fused_activation=fused_activation, meta=meta)


def norm_node(name: str, numel: int, *, dtype_bytes: int = 2,
              inputs: list[str] | None = None, **meta) -> LayerNode:
    return LayerNode(name=name, kind=LayerKind.NORM,
                     dims={"numel": numel}, dtype_bytes=dtype_bytes,
                     inputs=inputs or [], meta=meta)


def embed_node(name: str, tokens: int, vocab: int, d_model: int, *,
               dtype_bytes: int = 2, **meta) -> LayerNode:
    """Token-embedding gather; reads the model input (int32 token ids)."""
    return LayerNode(name=name, kind=LayerKind.EMBED,
                     dims={"tokens": tokens, "vocab": vocab,
                           "d_model": d_model},
                     dtype_bytes=dtype_bytes, meta=meta)


def elementwise_node(name: str, op: str, numel: int, *,
                     dtype_bytes: int = 2,
                     inputs: list[str] | None = None, **meta) -> LayerNode:
    """Binary elementwise op (``op``: "mul" | "add") on two inputs —
    the GLU gating multiply is the LM lowering's only standalone one
    (residual adds fuse into the producing matmul's writeback)."""
    return LayerNode(name=name, kind=LayerKind.ELEMENTWISE,
                     dims={"numel": numel}, dtype_bytes=dtype_bytes,
                     inputs=inputs or [], meta={"op": op, **meta})


def conv_node(name: str, H: int, W: int, C_in: int, C_out: int, kh: int,
              kw: int, stride: int = 1, pad: int = 0, batch: int = 1, *,
              dtype_bytes: int = 2, inputs: list[str] | None = None,
              bypass_of: str | None = None, fused_bias: bool = True,
              fused_activation: str | None = "relu", **meta) -> LayerNode:
    return LayerNode(
        name=name, kind=LayerKind.CONV2D,
        dims={"H": H, "W": W, "C_in": C_in, "C_out": C_out, "kh": kh,
              "kw": kw, "stride": stride, "pad": pad, "batch": batch},
        dtype_bytes=dtype_bytes, inputs=inputs or [], bypass_of=bypass_of,
        fused_bias=fused_bias, fused_activation=fused_activation, meta=meta)

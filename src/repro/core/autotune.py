"""Schedule autotuner — measure, calibrate, search, pin (stage 7).

The analytic schedule (core/schedule.py) picks tilings, loop order,
strip storage and attention blocks from traffic formulas alone.  This
module closes the paper's Table-1 loop in the other direction, the
design-space-exploration move: trace the executor
(``runtime/executor.trace_program``), fit the cost model
(``core/cost.fit_cost_model``), enumerate each op's *feasible*
candidate set (the same sets the choosers search —
``enumerate_conv_tilings`` / ``enumerate_matmul_candidates`` /
``enumerate_attention_blocks``), rank by calibrated cost, measure the
top-k by replay (``runtime/replay.replay_record``), and pin the winner
in an on-disk **TunedCache**.

The cache is keyed ``(config name, hw fingerprint, batch, op
signature)`` and consulted by ``compile_model`` *before* the analytic
choosers run (models pass a ``TunedView``), so an unchanged model
compiles straight to the tuned Program with zero re-search and zero
replay measurements.  ``TunedCache.generation()`` is a content hash of
the entries; the models' compile caches key on it, so a re-tune
invalidates every memoized Program (the stale-Program bugfix).

``require_no_model_regression`` (default on) only admits candidates
whose *modeled* traffic is at or below the incumbent's — the tuned
schedule's modeled cost is then provably <= the untuned one (the CI
smoke asserts exactly this), and measurement can only improve on it.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from dataclasses import dataclass, field

from .cost import CostModel, error_table, fit_cost_model, format_error_table
from .dataflow import (conv_strip_traffic, enumerate_matmul_candidates,
                       matmul_traffic)
from .hw import SNOWFLAKE, TPU_V5E, HardwareModel
from .ir import (LayerKind, LayerNode, ModelGraph, _conv_out, kernel_kind,
                 pool_out)
from .tiling import (ConvTiling, conv_tiling_from, enumerate_attention_blocks,
                     enumerate_conv_tilings)

__all__ = ["hw_fingerprint", "op_signature", "kernel_kind", "TunedCache",
           "TunedView", "enumerate_candidates", "tune_program", "tune_cnn",
           "tune_lm_decode", "TuneReport", "OpTuneResult", "activate",
           "deactivate", "active", "active_generation"]

TUNABLE = ("conv2d", "matmul", "flash_attention", "decode_attention")


def hw_fingerprint(hw: HardwareModel) -> str:
    """Identity of the machine a measurement is valid on: the hardware
    *model* parameters plus the physical backend executing the kernels
    (a CPU-interpret measurement must never be served to a TPU run)."""
    import jax
    dev = jax.devices()[0]
    payload = {"hw": dataclasses.asdict(hw),
               "backend": jax.default_backend(),
               "device_kind": dev.device_kind}
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def op_signature(node: LayerNode) -> str:
    """Stable per-op key: kernel kind + full geometry + dtype width.
    Two nodes with the same signature are interchangeable workloads, so
    one tuned entry serves every occurrence (e.g. all L identical
    transformer blocks collapse to a handful of signatures)."""
    dims = ",".join(f"{k}={node.dims[k]}" for k in sorted(node.dims))
    return f"{kernel_kind(node)}[{dims}]dt{node.dtype_bytes}"


# --- the on-disk cache -------------------------------------------------------------
@dataclass
class TunedCache:
    """Persisted tuned schedules + fitted cost models.

    ``entries`` maps ``config|hw_fp|b<batch>|<op signature>`` to the
    winning decisions (plus measurement bookkeeping); ``cost_models``
    maps hw fingerprints to ``CostModel`` fits.  ``generation()`` is a
    content hash — compile caches key on it so mutating the cache
    invalidates memoized Programs.
    """
    path: str | None = None
    entries: dict = field(default_factory=dict)
    cost_models: dict = field(default_factory=dict)

    @staticmethod
    def key(config: str, hw_fp: str, batch: int, sig: str) -> str:
        return f"{config}|{hw_fp}|b{batch}|{sig}"

    def generation(self) -> str:
        if not self.entries and not self.cost_models:
            return "empty"
        blob = json.dumps({"entries": self.entries,
                           "cost_models": self.cost_models}, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:12]

    def lookup(self, config: str, hw_fp: str, batch: int,
               sig: str) -> dict | None:
        return self.entries.get(self.key(config, hw_fp, batch, sig))

    def store(self, config: str, hw_fp: str, batch: int, sig: str,
              entry: dict) -> None:
        self.entries[self.key(config, hw_fp, batch, sig)] = entry

    def cost_model(self, hw_fp: str) -> CostModel | None:
        raw = self.cost_models.get(hw_fp)
        return CostModel.from_json(json.dumps(raw)) if raw else None

    def set_cost_model(self, hw_fp: str, model: CostModel) -> None:
        self.cost_models[hw_fp] = json.loads(model.to_json())

    def view(self, config: str, hw_fp: str, batch: int) -> "TunedView":
        return TunedView(self, config, hw_fp, batch)

    def save(self, path: str | None = None) -> None:
        path = path or self.path
        if path is None:
            raise ValueError("TunedCache has no path")
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": self.entries,
                       "cost_models": self.cost_models},
                      f, indent=2, sort_keys=True)
        self.path = path

    @classmethod
    def load(cls, path: str) -> "TunedCache":
        """Missing file => empty cache bound to the path (first tune
        creates it)."""
        if not os.path.exists(path):
            return cls(path=path)
        with open(path) as f:
            raw = json.load(f)
        return cls(path=path, entries=raw.get("entries", {}),
                   cost_models=raw.get("cost_models", {}))


@dataclass(frozen=True)
class TunedView:
    """What ``compile_model`` sees: node -> tuned decisions (or None).
    Duck-typed on purpose — core/schedule.py never imports this module,
    so the schedule emitter stays import-cycle-free."""
    cache: TunedCache
    config: str
    hw_fp: str
    batch: int

    def lookup(self, node: LayerNode) -> dict | None:
        return self.cache.lookup(self.config, self.hw_fp, self.batch,
                                 op_signature(node))


# --- the process-wide active cache -------------------------------------------------
_ACTIVE: TunedCache | None = None


def activate(cache: TunedCache) -> None:
    """Make ``cache`` the cache every ``compile_program`` consults."""
    global _ACTIVE
    _ACTIVE = cache


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> TunedCache | None:
    return _ACTIVE


def active_generation() -> str:
    """Content hash of the active cache — the compile-cache key
    component (the stale-Program bugfix: re-tuning changes the
    generation, which invalidates every memoized Program)."""
    return _ACTIVE.generation() if _ACTIVE is not None else "none"


# --- candidate enumeration ---------------------------------------------------------
def _conv_candidate_traffic(node: LayerNode, ct: ConvTiling, order: str,
                            charge_materialization: bool = True) -> float:
    """Modeled HBM bytes of one conv candidate — *identical* accounting
    to ``core/schedule._schedule_conv`` (fused-pool output shrink on the
    zero-copy path included), so the tuner's no-regression filter and
    the compiled schedule's traffic can never disagree."""
    d = node.dims
    ob = node.operand_bytes()
    fp = node.meta.get("fused_pool") if ct.strip_storage == "virtual" else None
    if fp:
        oh = pool_out(_conv_out(d["H"], d["kh"], d["stride"], d["pad"]),
                      fp["window"], fp["stride"], fp.get("pad", 0))
        ow = pool_out(_conv_out(d["W"], d["kw"], d["stride"], d["pad"]),
                      fp["window"], fp["stride"], fp.get("pad", 0))
        ob["out"] = d.get("batch", 1) * oh * ow * d["C_out"] * node.dtype_bytes
    kloop, mloop = conv_strip_traffic(
        ob["maps"], ob["weights"], ob["out"], n_map_tiles=ct.n_map_tiles,
        n_kernel_tiles=ct.n_kernel_tiles, overlap_frac=ct.overlap_frac,
        strip_storage=ct.strip_storage,
        charge_materialization=charge_materialization)
    return kloop if order == "kloop" else mloop


def enumerate_candidates(node: LayerNode, hw: HardwareModel, *,
                         paper_faithful: bool = False,
                         charge_materialization: bool = True) -> list[dict]:
    """Every feasible schedule for one tunable node, with its modeled
    traffic — the tuner's search space.  Decisions are JSON-plain (the
    cache stores them verbatim); ``entry_to_replay_candidate`` turns one
    into the replay harness's substitution dict."""
    d = node.dims
    out: list[dict] = []
    if node.kind is LayerKind.CONV2D:
        for ct in enumerate_conv_tilings(
                d["H"], d["W"], d["C_in"], d["C_out"], d["kh"], d["kw"],
                d["stride"], d["pad"], node.dtype_bytes, hw,
                batch=d.get("batch", 1)):
            if paper_faithful and ct.strip_storage != "materialized":
                continue
            for order in ("kloop", "mloop"):
                out.append({
                    "kind": "conv2d", "out_rows": ct.out_rows,
                    "kernels_per_tile": ct.kernels_per_tile,
                    "strip_storage": ct.strip_storage, "dataflow": order,
                    "modeled_traffic": _conv_candidate_traffic(
                        node, ct, order, charge_materialization)})
    elif node.kind is LayerKind.MATMUL:
        for df, t, traffic in enumerate_matmul_candidates(
                d["M"], d["K"], d["N"], node.dtype_bytes, hw,
                allow_output_stationary=not paper_faithful):
            out.append({"kind": "matmul", "dataflow": df.value,
                        "block": [t.bm, t.bk, t.bn],
                        "modeled_traffic": traffic})
    elif node.kind is LayerKind.ATTENTION:
        kind = kernel_kind(node)
        traffic = node.min_bytes()   # blocks move where, not how many
        for bq, bkv in enumerate_attention_blocks(
                d["seq_q"], d["seq_kv"], d["head_dim"], node.dtype_bytes,
                hw, window=node.meta.get("window")):
            out.append({"kind": kind, "block_q": bq, "block_kv": bkv,
                        "modeled_traffic": traffic})
    return out


def entry_to_replay_candidate(node: LayerNode, entry: dict,
                              hw: HardwareModel) -> dict:
    """Tuned-entry decisions -> the substitution dict
    ``runtime/replay.op_from_record`` understands.  Conv entries are
    re-validated through ``conv_tiling_from`` (raises on an infeasible
    or stale entry)."""
    if entry["kind"] == "conv2d":
        d = node.dims
        ct = conv_tiling_from(
            d["H"], d["W"], d["C_in"], d["C_out"], d["kh"], d["kw"],
            d["stride"], d["pad"], node.dtype_bytes, hw,
            out_rows=entry["out_rows"],
            kernels_per_tile=entry["kernels_per_tile"],
            strip_storage=entry["strip_storage"],
            batch=d.get("batch", 1))
        return {"conv_tiling": ct, "dataflow": entry["dataflow"]}
    if entry["kind"] == "matmul":
        return {"dataflow": entry["dataflow"],
                "block": tuple(entry["block"])}
    if entry["kind"] == "flash_attention":
        return {"block_q": entry["block_q"], "block_kv": entry["block_kv"]}
    return {"block_kv": entry["block_kv"]}        # decode_attention


def _incumbent_decisions(rec) -> dict:
    """The traced op's own schedule, as a candidate-shaped dict."""
    s = rec.schedule
    if rec.kind == "conv2d":
        ct = s["conv_tiling"]
        return {"kind": "conv2d", "out_rows": ct["out_rows"],
                "kernels_per_tile": ct["kernels_per_tile"],
                "strip_storage": s.get("strip_storage")
                or ct.get("strip_storage", "materialized"),
                "dataflow": s["dataflow"]}
    if rec.kind == "matmul":
        return {"kind": "matmul", "dataflow": s["dataflow"],
                "block": list(s["block"])}
    a = s["attn"]
    if rec.kind == "flash_attention":
        return {"kind": "flash_attention", "block_q": a["block_q"],
                "block_kv": a["block_kv"]}
    return {"kind": "decode_attention", "block_kv": a["block_kv"]}


def _same_decisions(a: dict, b: dict) -> bool:
    keys = set(a) | set(b)
    keys -= {"modeled_traffic", "measured_time_s", "incumbent_time_s",
             "sig", "measured"}
    return all(a.get(k) == b.get(k) for k in keys)


# --- the tuner ---------------------------------------------------------------------
@dataclass
class OpTuneResult:
    name: str
    sig: str
    kind: str
    incumbent: dict
    winner: dict
    measurements: int                  # replay timings performed
    incumbent_time_s: float | None = None
    winner_time_s: float | None = None
    cached: bool = False               # served from the cache, untouched


@dataclass
class TuneReport:
    config: str
    hw_fp: str
    batch: int
    results: list
    n_measurements: int
    error_rows: list = field(default_factory=list)

    def summary(self) -> str:
        lines = [f"tune {self.config} (hw {self.hw_fp}, batch "
                 f"{self.batch}): {len(self.results)} tunable ops, "
                 f"{self.n_measurements} replay measurements"]
        for r in self.results:
            if r.cached:
                lines.append(f"  {r.name:<16} cached")
                continue
            changed = not _same_decisions(r.incumbent, r.winner)
            t = (f"{r.winner_time_s * 1e6:8.1f}us"
                 if r.winner_time_s is not None else "   (modeled)")
            base = (f" vs {r.incumbent_time_s * 1e6:.1f}us analytic"
                    if r.incumbent_time_s is not None else "")
            lines.append(f"  {r.name:<16} {'TUNED ' if changed else 'kept  '}"
                         f"{t}{base}")
        return "\n".join(lines)


def tune_program(program, graph: ModelGraph, params, x, *, config_name: str,
                 batch: int, hw: HardwareModel, cache: TunedCache | None =
                 None, impl: str = "auto", interpret: bool | None = None,
                 top_k: int = 3, repeats: int = 3, measure: bool = True,
                 require_no_model_regression: bool = True, state=None,
                 mask=None, seed: int = 0,
                 paper_faithful: bool = False) -> TuneReport:
    """Trace -> calibrate -> search -> measure -> pin, for one Program.

    For every tunable op not already covered by ``cache``: enumerate the
    feasible candidates, drop any whose modeled traffic exceeds the
    incumbent's (``require_no_model_regression``), rank the rest by
    calibrated cost, replay-measure the best ``top_k`` (incumbent always
    included), and pin the fastest.  Ties go to lower modeled traffic,
    then to the incumbent.  ``measure=False`` ranks on the calibrated
    model alone (CI smoke with a tiny budget).

    Ops already in the cache are *not* re-measured — a fully covered
    Program tunes with zero replay measurements.
    """
    from ..runtime.executor import trace_program
    from ..runtime.replay import replay_record
    cache = cache if cache is not None else TunedCache()
    fp = hw_fingerprint(hw)
    nodes = {n.name: n for n in graph}
    trace = trace_program(program, params, x, impl=impl, interpret=interpret,
                          repeats=repeats, measure=measure, state=state,
                          mask=mask)
    cm = None
    if measure:
        cm = fit_cost_model(trace.record_dicts())
        cache.set_cost_model(fp, cm)
    else:
        cm = cache.cost_model(fp)

    results: list[OpTuneResult] = []
    n_meas = 0
    for rec in trace.records:
        if rec.kind not in TUNABLE or rec.name not in nodes:
            continue
        node = nodes[rec.name]
        sig = op_signature(node)
        incumbent = _incumbent_decisions(rec)
        hit = cache.lookup(config_name, fp, batch, sig)
        if hit is not None:
            results.append(OpTuneResult(
                name=rec.name, sig=sig, kind=rec.kind, incumbent=incumbent,
                winner=hit, measurements=0, cached=True))
            continue

        cands = enumerate_candidates(node, hw,
                                     paper_faithful=paper_faithful)
        inc_traffic = next(
            (c["modeled_traffic"] for c in cands
             if _same_decisions(c, incumbent)), rec.traffic_bytes)
        if require_no_model_regression:
            cands = [c for c in cands
                     if c["modeled_traffic"] <= inc_traffic * (1 + 1e-9)]

        def predicted(c):
            analytic = hw.exec_time(rec.flops, c["modeled_traffic"])
            if cm is None:
                return analytic
            return cm.predict(rec.kind, rec.flops, c["modeled_traffic"],
                              analytic)

        cands.sort(key=lambda c: (predicted(c), c["modeled_traffic"]))
        short = cands[:max(top_k, 1)]
        if not any(_same_decisions(c, incumbent) for c in short):
            short.append({**incumbent, "modeled_traffic": inc_traffic})

        scored = []
        for c in short:
            if measure:
                try:
                    rc = entry_to_replay_candidate(node, c, hw)
                except ValueError:
                    continue           # infeasible candidate: skip
                _, t = replay_record(rec, candidate=rc, impl=impl,
                                     interpret=interpret, repeats=repeats,
                                     seed=seed)
                n_meas += 1
            else:
                t = predicted(c)
            scored.append((t, c["modeled_traffic"],
                           0 if _same_decisions(c, incumbent) else 1, c))
        scored.sort(key=lambda s: s[:3])
        t_win, traffic_win, _, winner = scored[0]
        t_inc = next((s[0] for s in scored
                      if _same_decisions(s[3], incumbent)), None)
        entry = {k: v for k, v in winner.items() if k != "modeled_traffic"}
        entry.update(sig=sig, modeled_traffic=traffic_win,
                     measured_time_s=t_win if measure else None,
                     incumbent_time_s=t_inc if measure else None)
        cache.store(config_name, fp, batch, sig, entry)
        results.append(OpTuneResult(
            name=rec.name, sig=sig, kind=rec.kind, incumbent=incumbent,
            winner=entry, measurements=len(scored) if measure else 0,
            incumbent_time_s=t_inc if measure else None,
            winner_time_s=t_win if measure else None))

    if cache.path:
        cache.save()
    rows = error_table(trace.record_dicts(), cm) if measure else []
    return TuneReport(config=config_name, hw_fp=fp, batch=batch,
                      results=results, n_measurements=n_meas,
                      error_rows=rows)


# --- model-level entry points ------------------------------------------------------
def tune_cnn(cfg, batch: int = 1, hw: HardwareModel = TPU_V5E, *,
             cache: TunedCache | None = None, impl: str = "auto",
             interpret: bool | None = None, top_k: int = 3,
             repeats: int = 3, measure: bool = True,
             require_no_model_regression: bool = True,
             paper_faithful: bool = False, seed: int = 0) -> TuneReport:
    """Tune a CNN config's Program (synthetic params/input)."""
    import jax
    import jax.numpy as jnp

    from ..models import cnn
    from ..models.common import init_params
    program = cnn.compile_program(cfg, batch=batch, hw=hw,
                                  paper_faithful=paper_faithful)
    dtype_bytes = jnp.dtype(cfg.jdtype).itemsize
    graph = cnn.to_graph(cfg, batch=batch, dtype_bytes=dtype_bytes)
    graph.mark_residuals()
    graph.mark_pool_fusion()
    params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (batch, cfg.input_hw, cfg.input_hw, cfg.input_ch),
                          cfg.jdtype)
    return tune_program(program, graph, params, x, config_name=cfg.name,
                        batch=batch, hw=hw, cache=cache, impl=impl,
                        interpret=interpret, top_k=top_k, repeats=repeats,
                        measure=measure, paper_faithful=paper_faithful,
                        require_no_model_regression=require_no_model_regression,
                        seed=seed)


def tune_lm_decode(cfg, slots: int = 2, max_len: int = 32,
                   prompt_len: int | None = None,
                   hw: HardwareModel = TPU_V5E, *,
                   cache: TunedCache | None = None, impl: str = "auto",
                   interpret: bool | None = None, top_k: int = 3,
                   repeats: int = 3, measure: bool = True,
                   require_no_model_regression: bool = True,
                   seed: int = 0) -> TuneReport:
    """Tune an LM's decode Program: prefill every slot (realistic cache
    occupancy), then trace + tune the per-token decode step.  The cache
    scope's batch is ``slots`` — the decode step's true batch."""
    import jax
    import jax.numpy as jnp

    from ..models import transformer
    from ..models.common import init_params
    from ..runtime.executor import init_program_state, run_prefill
    pair = transformer.compile_program_pair(cfg, slots=slots,
                                            max_len=max_len, hw=hw)
    graph = transformer.to_decode_graph(cfg, slots=slots, max_len=max_len)
    graph.mark_residuals()
    graph.mark_pool_fusion()
    params = init_params(transformer.param_defs(cfg), jax.random.PRNGKey(seed))
    state = init_program_state(pair)
    plen = prompt_len if prompt_len is not None else max(max_len // 2, 1)
    for slot in range(slots):
        toks = jax.random.randint(jax.random.PRNGKey(seed + 2 + slot),
                                  (1, max_len), 0, cfg.vocab, jnp.int32)
        _, state = run_prefill(pair.prefill, params, toks, state, slot, plen,
                               impl=impl, interpret=interpret)
    step = jax.random.randint(jax.random.PRNGKey(seed + 99), (slots,), 0,
                              cfg.vocab, jnp.int32)
    return tune_program(pair.decode, graph, params, step,
                        config_name=cfg.name, batch=slots, hw=hw,
                        cache=cache, impl=impl, interpret=interpret,
                        top_k=top_k, repeats=repeats, measure=measure,
                        require_no_model_regression=require_no_model_regression,
                        state=state, seed=seed)


_HW = {"tpu_v5e": TPU_V5E, "snowflake": SNOWFLAKE}


def main(argv=None) -> int:
    from ..configs import get_config
    from ..configs.base import CNNConfig
    ap = argparse.ArgumentParser(description="trace + calibrate + tune")
    ap.add_argument("--config", required=True,
                    help="config name (CNN or LM; -smoke suffix ok)")
    ap.add_argument("--cache", required=True, help="tuned-cache JSON path")
    ap.add_argument("--batch", type=int, default=1, help="CNN batch size")
    ap.add_argument("--slots", type=int, default=2, help="LM decode slots")
    ap.add_argument("--max-len", type=int, default=32, help="LM max_len")
    ap.add_argument("--hw", choices=sorted(_HW), default="tpu_v5e")
    ap.add_argument("--impl", default="auto")
    ap.add_argument("--interpret", action="store_true",
                    help="force pallas interpret mode")
    ap.add_argument("--top-k", type=int, default=3,
                    help="candidates measured per op")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--no-measure", action="store_true",
                    help="rank on the calibrated model only (no replay)")
    args = ap.parse_args(argv)
    cfg = get_config(args.config)
    cache = TunedCache.load(args.cache)
    interp = True if args.interpret else None
    kw = dict(cache=cache, impl=args.impl, interpret=interp,
              top_k=args.top_k, repeats=args.repeats,
              measure=not args.no_measure, hw=_HW[args.hw])
    if isinstance(cfg, CNNConfig):
        report = tune_cnn(cfg, batch=args.batch, **kw)
    else:
        report = tune_lm_decode(cfg, slots=args.slots, max_len=args.max_len,
                                **kw)
    print(report.summary())
    if report.error_rows:
        print(format_error_table(report.error_rows))
    cache.save(args.cache)
    print(f"cache {args.cache}: {len(cache.entries)} entries, "
          f"generation {cache.generation()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Tile selection (paper §5.1 steps 3-4, T2).

The paper decomposes maps into output-row-strip tiles and kernels into
single-kernel tiles sized to the on-chip buffers, double buffered.  On
TPU the on-chip buffer is VMEM and the tile shape *is* the Pallas
BlockSpec; the pipeline emitter provides the double buffering, so the
tiler charges 2x for every streamed operand.

Key constraints carried over from the paper:
* tiles must fit the buffer (VMEM budget, incl. double-buffer factor);
* compute-unit alignment — the paper pads to the 16-wide vMAC; we pad
  matmul dims to the 128-wide MXU (``hw.mxu_dim``) and the (8,128)
  sublane/lane layout;
* bigger tiles amortize "bookkeeping" (here: fewer grid steps, better
  pipeline efficiency) but raise the buffer footprint and the overlap
  waste for convolutions (halo rows re-loaded per strip).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from .hw import HardwareModel

__all__ = [
    "round_up",
    "round_down_multiple",
    "pow2_candidates",
    "MatmulTiling",
    "select_matmul_tiles",
    "enumerate_matmul_tilings",
    "ConvTiling",
    "select_conv_row_strips",
    "enumerate_conv_tilings",
    "conv_tiling_from",
    "select_attention_blocks",
    "enumerate_attention_blocks",
    "virtual_strips_fit",
]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def round_down_multiple(x: int, m: int) -> int:
    return max(m, (x // m) * m)


def pow2_candidates(limit: int, base: int) -> list[int]:
    """base, 2*base, 4*base ... <= limit (always at least [base])."""
    out = [base]
    while out[-1] * 2 <= limit:
        out.append(out[-1] * 2)
    return out


# --- matmul ---------------------------------------------------------------------
@dataclass(frozen=True)
class MatmulTiling:
    bm: int
    bk: int
    bn: int
    vmem_bytes: int          # working set incl. double buffering + accumulator
    grid: tuple[int, int, int]   # (m, n, k) tile counts

    @property
    def tiles(self) -> int:
        m, n, k = self.grid
        return m * n * k


def matmul_vmem_bytes(bm: int, bk: int, bn: int, dtype_bytes: int,
                      *, stream_a: bool = True, stream_b: bool = True,
                      acc_bytes: int = 4) -> int:
    """VMEM working set for one grid step.

    Streamed operands are double buffered (x2) by the Pallas pipeline;
    resident operands are held once.  The accumulator lives in VMEM at
    f32 (``acc_bytes``).
    """
    a = bm * bk * dtype_bytes * (2 if stream_a else 1)
    b = bk * bn * dtype_bytes * (2 if stream_b else 1)
    c = bm * bn * max(acc_bytes, dtype_bytes) * 2   # out is always streamed
    return a + b + c


def select_matmul_tiles(M: int, K: int, N: int, dtype_bytes: int,
                        hw: HardwareModel, *,
                        favor: str = "balanced") -> MatmulTiling:
    """Pick (bm, bk, bn) for an output-stationary tiled matmul.

    ``favor`` skews the VMEM split between the maps (A) and weights (B)
    operands — the within-kernel face of the paper's Mloop/Kloop dial:

    * ``"maps"``   — large bm (A-tile reuse; kernels streamed more: Kloop)
    * ``"weights"``— large bn (B-tile reuse; maps streamed more: Mloop)
    * ``"balanced"`` — minimize refetch traffic (N/bn)*A + (M/bm)*B.
    """
    base = hw.mxu_dim
    budget = hw.vmem_budget()
    Mp, Kp, Np = (round_up(max(d, 1), base) for d in (M, K, N))

    mcap = hw.maps_buffer_bytes or budget
    wcap = hw.weights_buffer_bytes or budget
    best: tuple[float, MatmulTiling] | None = None
    for bm in pow2_candidates(min(Mp, 2048), base):
        for bn in pow2_candidates(min(Np, 2048), base):
            for bk in pow2_candidates(min(Kp, 4096), base):
                vmem = matmul_vmem_bytes(bm, bk, bn, dtype_bytes)
                if vmem > budget:
                    continue
                if (2 * bm * bk * dtype_bytes > mcap
                        or 2 * bk * bn * dtype_bytes > wcap):
                    continue
                grid = (math.ceil(Mp / bm), math.ceil(Np / bn),
                        math.ceil(Kp / bk))
                # Refetch traffic for output-stationary order (k innermost).
                a_bytes = Mp * Kp * dtype_bytes
                b_bytes = Kp * Np * dtype_bytes
                traffic = grid[1] * a_bytes + grid[0] * b_bytes
                if favor == "maps":
                    cost = grid[0] * b_bytes + 1e-6 * traffic
                elif favor == "weights":
                    cost = grid[1] * a_bytes + 1e-6 * traffic
                else:
                    cost = traffic
                # Prefer fewer grid steps on ties (pipeline efficiency);
                # prefer larger bk (longer traces, the paper's MAC-latency
                # hiding: more MAC work per bookkeeping slot).
                cost += grid[0] * grid[1] * grid[2] * 1e-3
                cost -= bk * 1e-6
                cand = MatmulTiling(bm, bk, bn, vmem, grid)
                if best is None or cost < best[0]:
                    best = (cost, cand)
    assert best is not None, "no feasible tiling (VMEM too small?)"
    return best[1]


def enumerate_matmul_tilings(M: int, K: int, N: int, dtype_bytes: int,
                             hw: HardwareModel) -> list[MatmulTiling]:
    """Every feasible output-stationary (bm, bk, bn) the chooser's own
    loops would consider — the autotuner's matmul candidate set (the
    resident-slab flavors are enumerated by
    ``dataflow.enumerate_matmul_candidates``, which combines both).
    Feasibility is exactly ``select_matmul_tiles``'s: VMEM budget plus
    the split maps/weights buffer caps."""
    base = hw.mxu_dim
    budget = hw.vmem_budget()
    Mp, Kp, Np = (round_up(max(d, 1), base) for d in (M, K, N))
    mcap = hw.maps_buffer_bytes or budget
    wcap = hw.weights_buffer_bytes or budget
    out: list[MatmulTiling] = []
    for bm in pow2_candidates(min(Mp, 2048), base):
        for bn in pow2_candidates(min(Np, 2048), base):
            for bk in pow2_candidates(min(Kp, 4096), base):
                vmem = matmul_vmem_bytes(bm, bk, bn, dtype_bytes)
                if vmem > budget:
                    continue
                if (2 * bm * bk * dtype_bytes > mcap
                        or 2 * bk * bn * dtype_bytes > wcap):
                    continue
                grid = (math.ceil(Mp / bm), math.ceil(Np / bn),
                        math.ceil(Kp / bk))
                out.append(MatmulTiling(bm, bk, bn, vmem, grid))
    return out


# --- attention blocks -------------------------------------------------------------
def select_attention_blocks(Sq: int, Skv: int, D: int, dtype_bytes: int,
                            hw: HardwareModel, *,
                            window: int | None = None,
                            page_size: int | None = None) -> tuple[int, int]:
    """Pick (block_q, block_kv) for flash attention — T2 applied to the
    attention score loop: the q tile, double-buffered k+v tiles, the f32
    accumulator and the (bq, bkv) score tile must fit the VMEM budget.
    This is the compiler's decision; the flash kernel wrapper
    (kernels/flash_attention/ops.py) defers to it, and the LM Program
    lowering pins the result into each ``flash_attention`` op.

    ``Sq == 1`` is the **decode regime**: one new query token against a
    KV cache.  There is no score-loop freedom — the cache is the only
    big operand — so block_q is 1 and block_kv is sized to stream the
    cache at full bandwidth (k+v double buffered).  One chooser for
    both regimes: kernels/decode_attention/ops.py defers here, and the
    LM decode-Program lowering pins the result into each
    ``decode_attention`` op.

    ``window`` (causal sliding window) caps the kv extent a query ever
    touches: no score-loop tile should outgrow the window, so the
    effective Skv is ``min(Skv, window)``.  For a windowed *decode*
    node the cache region itself is already window-sized (the §5.1
    rolling plan), so both arguments agree.

    ``page_size`` marks a **paged** decode node (the §5.1 paged plan):
    the KV rows live in fixed-size pool pages gathered through a
    per-slot page table, so the kv stream has no contiguity beyond one
    page — the natural (and only) kv block IS the page.  The chooser
    pins ``block_kv = page_size`` and the paged kernel's grid walks the
    table one page per step."""
    budget = hw.vmem_budget()
    if window is not None:
        Skv = min(Skv, window)
    if Sq == 1 and page_size is not None:
        return (1, page_size)
    if Sq == 1:
        bkv = 128
        for b in (256, 512, 1024, 2048, 4096):
            if b <= max(Skv, 128) and 4 * b * D * dtype_bytes <= budget:
                bkv = b
        return (1, bkv)
    best = (hw.lane, hw.lane)
    for bq in (128, 256, 512, 1024, 2048):
        if bq > max(Sq, 128):
            break
        for bkv in (128, 256, 512, 1024, 2048):
            if bkv > max(Skv, 128):
                break
            use = (bq * D * dtype_bytes                 # q tile
                   + 2 * 2 * bkv * D * dtype_bytes      # k+v double-buffered
                   + bq * D * 4 + 2 * bq * 128 * 4      # acc + m/l scratch
                   + bq * bkv * 4)                      # score tile
            if use <= budget:
                best = (bq, bkv)
    return best


def enumerate_attention_blocks(Sq: int, Skv: int, D: int, dtype_bytes: int,
                               hw: HardwareModel, *,
                               window: int | None = None,
                               page_size: int | None = None
                               ) -> list[tuple[int, int]]:
    """Every feasible (block_q, block_kv) pair under the same VMEM test
    ``select_attention_blocks`` applies — the autotuner's attention
    candidate set.  ``Sq == 1`` enumerates the decode regime: (1, bkv)
    for every cache-streaming block that fits.  A paged decode node has
    no block freedom at all (the page is the kv tile), so its candidate
    set is the singleton (1, page_size)."""
    budget = hw.vmem_budget()
    if window is not None:
        Skv = min(Skv, window)
    if Sq == 1 and page_size is not None:
        return [(1, page_size)]
    if Sq == 1:
        out = [(1, 128)]
        for b in (256, 512, 1024, 2048, 4096):
            if b <= max(Skv, 128) and 4 * b * D * dtype_bytes <= budget:
                out.append((1, b))
        return out
    pairs: list[tuple[int, int]] = [(hw.lane, hw.lane)]
    for bq in (128, 256, 512, 1024, 2048):
        if bq > max(Sq, 128):
            break
        for bkv in (128, 256, 512, 1024, 2048):
            if bkv > max(Skv, 128):
                break
            use = (bq * D * dtype_bytes
                   + 2 * 2 * bkv * D * dtype_bytes
                   + bq * D * 4 + 2 * bq * 128 * 4
                   + bq * bkv * 4)
            if use <= budget:
                pairs.append((bq, bkv))
    return sorted(set(pairs))


# --- conv row strips --------------------------------------------------------------
@dataclass(frozen=True)
class ConvTiling:
    out_rows: int            # output rows per maps tile (paper: row granularity)
    in_rows: int             # input rows needed incl. halo
    kernels_per_tile: int    # output channels per kernel tile
    vmem_bytes: int
    n_map_tiles: int
    n_kernel_tiles: int
    overlap_frac: float      # fraction of maps bytes re-loaded due to halos
    # Compiler decision: where the halo overlap lives.  "materialized"
    # duplicates augmented strips in HBM (Snowflake's single-burst-DMA
    # constraint); "virtual" keeps the whole per-image maps resident in
    # VMEM and gathers strips in-kernel — zero extra HBM copies.  Chosen
    # by a VMEM-residency test in select_conv_row_strips.
    strip_storage: str = "materialized"

    @property
    def grid(self) -> tuple[int, int]:
        return (self.n_map_tiles, self.n_kernel_tiles)


def virtual_strips_fit(H: int, W: int, C_in: int, kh: int, stride: int,
                       pad: int, dtype_bytes: int, hw: HardwareModel,
                       kernel_tile_bytes: int, out_tile_bytes: int) -> bool:
    """VMEM-residency test for zero-copy (virtual) strips.

    Virtual strips hand the kernel the *whole* padded per-image maps as
    one block (double buffered across the batch grid dimension) and
    slice strips out in-kernel, so the hardware must support random
    access into the resident buffer, and the full padded plane — not
    just one strip — must fit the maps budget alongside the streamed
    kernel tile and the f32 output accumulator.
    """
    if not hw.random_buffer_access:
        return False               # contiguous-DMA hardware (Snowflake)
    budget = hw.vmem_budget()
    mcap = hw.maps_buffer_bytes or budget
    Hp = H + 2 * pad + max(0, kh - stride)     # + worst-case bottom fill
    Wp = W + 2 * pad
    maps_bytes = Hp * Wp * C_in * dtype_bytes * 2      # dbl buf
    if maps_bytes > mcap:
        return False
    return maps_bytes + kernel_tile_bytes + out_tile_bytes <= budget


def _strip_candidate(H: int, W: int, C_in: int, C_out: int, kh: int,
                     kw: int, stride: int, pad: int, dtype_bytes: int,
                     hw: HardwareModel, batch: int,
                     out_rows: int) -> ConvTiling | None:
    """One materialized-storage candidate at the given strip height:
    the widest kernel tile that fits next to the maps strip, shrunk
    until the f32 output accumulator also fits — exactly the chooser's
    per-``out_rows`` step, shared with ``enumerate_conv_tilings`` so
    the search space and the analytic pick can never drift."""
    oh = (H + 2 * pad - kh) // stride + 1
    ow = (W + 2 * pad - kw) // stride + 1
    budget = hw.vmem_budget()
    mcap = hw.maps_buffer_bytes or budget
    wcap = hw.weights_buffer_bytes or budget
    kernel_bytes_each = C_in * kh * kw * dtype_bytes
    in_rows = min(H, (out_rows - 1) * stride + kh)
    maps_bytes = in_rows * W * C_in * dtype_bytes * 2              # dbl buf
    if maps_bytes > mcap:
        return None
    remaining = min(budget - maps_bytes, wcap)
    if remaining <= kernel_bytes_each * 2:
        return None
    kpt = min(C_out, remaining // (kernel_bytes_each * 2))
    kpt = max(1, min(kpt, C_out))
    # Align kernel-tile width to the compute unit when possible.
    if kpt >= hw.mxu_dim:
        kpt = round_down_multiple(kpt, hw.mxu_dim)
    # Shrink the kernel tile until the f32 output strip also fits.
    while kpt > 1:
        out_acc = out_rows * ow * kpt * 4
        if maps_bytes + kpt * kernel_bytes_each * 2 + out_acc <= budget:
            break
        kpt = max(1, kpt // 2)
    out_acc = out_rows * ow * kpt * 4
    vmem = maps_bytes + kpt * kernel_bytes_each * 2 + out_acc
    if vmem > budget:
        return None
    n_map = math.ceil(oh / out_rows) * batch
    n_ker = math.ceil(C_out / kpt)
    halo = max(0, in_rows - out_rows * stride)
    overlap = (halo * (math.ceil(oh / out_rows) - 1)) / max(H, 1)
    return ConvTiling(out_rows, in_rows, kpt, vmem, n_map, n_ker, overlap)


def _virtual_variant(t: ConvTiling, H: int, W: int, C_in: int, C_out: int,
                     kh: int, kw: int, stride: int, pad: int,
                     dtype_bytes: int, hw: HardwareModel
                     ) -> ConvTiling | None:
    """The zero-copy twin of a materialized tiling, or None when the
    whole padded per-image maps is not VMEM-resident."""
    ow = (W + 2 * pad - kw) // stride + 1
    kernel_bytes_each = C_in * kh * kw * dtype_bytes
    ker_tile = t.kernels_per_tile * kernel_bytes_each * 2
    out_tile = t.out_rows * ow * t.kernels_per_tile * 4
    if not virtual_strips_fit(H, W, C_in, kh, stride, pad, dtype_bytes, hw,
                              ker_tile, out_tile):
        return None
    Hp = H + 2 * pad + max(0, kh - stride)
    Wp = W + 2 * pad
    return dataclasses.replace(
        t, strip_storage="virtual",
        vmem_bytes=Hp * Wp * C_in * dtype_bytes * 2 + ker_tile + out_tile)


def select_conv_row_strips(H: int, W: int, C_in: int, C_out: int, kh: int,
                           kw: int, stride: int, pad: int,
                           dtype_bytes: int, hw: HardwareModel,
                           batch: int = 1) -> ConvTiling:
    """Row-strip, channel-major conv tiling (paper §2: strips lower the
    replicated-overlap bytes vs 2D block tiles).

    A maps tile holds ``in_rows`` full-width input rows across all input
    channels; a kernel tile holds ``kernels_per_tile`` complete kernels
    (single-kernel granularity, as in the paper).  Output strip is
    accumulated in VMEM.
    """
    oh = (H + 2 * pad - kh) // stride + 1
    ow = (W + 2 * pad - kw) // stride + 1
    kernel_bytes_each = C_in * kh * kw * dtype_bytes

    best: ConvTiling | None = None
    for out_rows in range(1, oh + 1):
        cand = _strip_candidate(H, W, C_in, C_out, kh, kw, stride, pad,
                                dtype_bytes, hw, batch, out_rows)
        if cand is None:
            break  # strips only grow from here
        # Objective: fewest total tile-loads weighted by overlap waste.
        def cost(t: ConvTiling) -> float:
            return (t.n_map_tiles * t.n_kernel_tiles
                    + t.overlap_frac * t.n_map_tiles * 10.0)
        if best is None or cost(cand) < cost(best):
            best = cand
    if best is None:
        # Degenerate: single output row at a time, one kernel each.
        in_rows = min(H, kh)
        best = ConvTiling(1, in_rows, 1,
                          in_rows * W * C_in * dtype_bytes * 2
                          + kernel_bytes_each * 2 + ow * 4,
                          oh * batch, C_out, 0.0)
    # Strip-storage decision (overlap re-fetch vs duplication): go
    # zero-copy when the whole padded per-image maps is VMEM-resident.
    virt = _virtual_variant(best, H, W, C_in, C_out, kh, kw, stride, pad,
                            dtype_bytes, hw)
    return virt if virt is not None else best


def enumerate_conv_tilings(H: int, W: int, C_in: int, C_out: int, kh: int,
                           kw: int, stride: int, pad: int, dtype_bytes: int,
                           hw: HardwareModel, batch: int = 1
                           ) -> list[ConvTiling]:
    """The autotuner's conv candidate set: every feasible row-strip
    height (with its derived kernel tile) in both storages the hardware
    admits.  Superset of ``select_conv_row_strips``'s pick — same
    per-``out_rows`` feasibility step, just not reduced to one winner."""
    oh = (H + 2 * pad - kh) // stride + 1
    out: list[ConvTiling] = []
    seen: set[tuple] = set()
    for out_rows in range(1, oh + 1):
        cand = _strip_candidate(H, W, C_in, C_out, kh, kw, stride, pad,
                                dtype_bytes, hw, batch, out_rows)
        if cand is None:
            break
        for t in (cand, _virtual_variant(cand, H, W, C_in, C_out, kh, kw,
                                         stride, pad, dtype_bytes, hw)):
            if t is None:
                continue
            key = (t.out_rows, t.kernels_per_tile, t.strip_storage)
            if key not in seen:
                seen.add(key)
                out.append(t)
    return out


def conv_tiling_from(H: int, W: int, C_in: int, C_out: int, kh: int,
                     kw: int, stride: int, pad: int, dtype_bytes: int,
                     hw: HardwareModel, *, out_rows: int,
                     kernels_per_tile: int,
                     strip_storage: str = "materialized",
                     batch: int = 1) -> ConvTiling:
    """Reconstruct a ConvTiling from pinned (out_rows, kernels_per_tile,
    strip_storage) — how a tuned-cache entry becomes a schedule without
    re-searching.  Validates the same feasibility constraints the
    analytic chooser enforces (maps/weights buffer caps, VMEM budget,
    virtual residency) and raises ``ValueError`` on violation, so a
    stale or hand-edited cache can never emit an unexecutable schedule."""
    oh = (H + 2 * pad - kh) // stride + 1
    ow = (W + 2 * pad - kw) // stride + 1
    if not 1 <= out_rows <= oh:
        raise ValueError(f"out_rows {out_rows} outside [1, {oh}]")
    if not 1 <= kernels_per_tile <= C_out:
        raise ValueError(
            f"kernels_per_tile {kernels_per_tile} outside [1, {C_out}]")
    budget = hw.vmem_budget()
    mcap = hw.maps_buffer_bytes or budget
    wcap = hw.weights_buffer_bytes or budget
    kernel_bytes_each = C_in * kh * kw * dtype_bytes
    in_rows = min(H, (out_rows - 1) * stride + kh)
    maps_bytes = in_rows * W * C_in * dtype_bytes * 2
    ker_tile = kernels_per_tile * kernel_bytes_each * 2
    out_acc = out_rows * ow * kernels_per_tile * 4
    if maps_bytes > mcap:
        raise ValueError(f"maps strip {maps_bytes}B exceeds the maps "
                         f"buffer cap {mcap}B")
    if ker_tile > wcap:
        raise ValueError(f"kernel tile {ker_tile}B exceeds the weights "
                         f"buffer cap {wcap}B")
    if maps_bytes + ker_tile + out_acc > budget:
        raise ValueError(f"working set {maps_bytes + ker_tile + out_acc}B "
                         f"exceeds the VMEM budget {budget}B")
    n_map = math.ceil(oh / out_rows) * batch
    n_ker = math.ceil(C_out / kernels_per_tile)
    halo = max(0, in_rows - out_rows * stride)
    overlap = (halo * (math.ceil(oh / out_rows) - 1)) / max(H, 1)
    t = ConvTiling(out_rows, in_rows, kernels_per_tile,
                   maps_bytes + ker_tile + out_acc, n_map, n_ker, overlap)
    if strip_storage == "virtual":
        virt = _virtual_variant(t, H, W, C_in, C_out, kh, kw, stride, pad,
                                dtype_bytes, hw)
        if virt is None:
            raise ValueError("virtual strips do not fit the VMEM budget "
                             "(or the hardware lacks random buffer access)")
        return virt
    return t

"""Three-term roofline analysis from compiled dry-run artifacts.

Per (arch x shape x mesh):

    compute_s    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory_s     = HLO_bytes / (chips * HBM_bw)
    collective_s = sum(per-chip collective link bytes) / link_bw

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO
text and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops, converting each to per-chip link
bytes with the standard ring-algorithm factors.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from .hw import HardwareModel, TPU_V5E

__all__ = [
    "CollectiveStats",
    "collective_stats_from_hlo",
    "RooflineReport",
    "roofline_report",
    "DTYPE_BYTES",
]

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# op name -> per-chip link-bytes factor as a function of (bytes, group)
# using ring-algorithm accounting:
#   all-gather: output bytes * (g-1)/g leave/enter each chip
#   reduce-scatter: input bytes * (g-1)/g
#   all-reduce: 2 * (g-1)/g * bytes (RS + AG)
#   all-to-all: bytes * (g-1)/g
#   collective-permute: full operand bytes
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*(?:\}[^}]*)*?)\}\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    """bytes of one 'dtype[d0,d1,...]' shape string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0.0
    dt, dims = m.groups()
    b = DTYPE_BYTES.get(dt, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return float(n * b)


def _result_bytes(line: str) -> float:
    """Sum bytes of the result shape(s) on an HLO instruction line."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0.0
    rhs = lhs[1]
    # result type precedes the op name: 'bf16[8,128]{1,0} all-gather(...)'
    # tuples: '(bf16[8,128], bf16[8,128]) all-gather(...)'
    head = rhs.split("(", 1)[0] if rhs.startswith("(") else rhs
    if rhs.startswith("("):
        # tuple result: take everything up to the matching ')'
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    head = rhs[: i + 1]
                    break
    total = 0.0
    for m in _SHAPE_RE.finditer(head):
        total += _shape_bytes(m.group(0))
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        total, groups_shape = int(m.group(1)), int(m.group(2))
        # iota format [N]<=[N] with dims: group size = N / num_groups; the
        # simple '[a,b]' form means a groups of b? Actually format is
        # replica_groups=[G,S]<=[...] : G groups of size S.
        return groups_shape if groups_shape > 0 else default
    m2 = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m2:
        first = m2.group(1)
        return max(1, len([x for x in first.split(",") if x.strip() != ""]))
    return default


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)        # op -> count
    op_bytes: dict = field(default_factory=dict)      # op -> raw result bytes
    link_bytes_per_chip: float = 0.0                  # ring-accounted

    def total_raw_bytes(self) -> float:
        return sum(self.op_bytes.values())


def collective_stats_from_hlo(hlo_text: str, n_chips: int) -> CollectiveStats:
    """Parse optimized HLO and accumulate collective traffic."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        opm = None
        for op in _COLL_OPS:
            # match ' op(' or ' op-start(' / ' op-done('
            if re.search(rf"\s{op}(-start|-done)?\(", s):
                opm = op
                break
        if opm is None:
            continue
        if f"{opm}-done" in s:
            continue  # counted at -start
        raw = _result_bytes(s)
        if raw == 0.0:
            continue
        g = _group_size(s, n_chips)
        frac = (g - 1) / g if g > 1 else 0.0
        if opm == "all-reduce":
            link = 2.0 * frac * raw
        elif opm == "collective-permute":
            link = raw
        else:
            link = frac * raw
        stats.counts[opm] = stats.counts.get(opm, 0) + 1
        stats.op_bytes[opm] = stats.op_bytes.get(opm, 0.0) + raw
        stats.link_bytes_per_chip += link
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float            # total across chips
    hlo_bytes: float
    coll_link_bytes: float      # per chip
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    coll_counts: dict
    step_time_s: float = 0.0
    notes: str = ""

    def as_row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "coll": dict(self.coll_counts),
        }


def roofline_report(*, arch: str, shape: str, mesh_name: str, n_chips: int,
                    cost_analysis: dict | None, hlo_text: str,
                    model_flops: float, hw: HardwareModel = TPU_V5E,
                    analytic_flops: float | None = None,
                    analytic_bytes: float | None = None) -> RooflineReport:
    """Build the three-term report for one dry-run cell.

    FLOPs/bytes/collective traffic come from the while-loop-aware HLO
    analyzer (core/hlo_analysis.py); ``cost_analysis`` is recorded for
    cross-checking only (it counts each scan body once).
    """
    from .hlo_analysis import analyze_hlo_text
    st = analyze_hlo_text(hlo_text, n_chips)
    notes = []
    flops = st.flops * n_chips            # per-device HLO -> cluster total
    byts = st.hbm_bytes * n_chips
    if flops <= 0 and analytic_flops:
        flops = analytic_flops
        notes.append("flops=analytic")
    ca = cost_analysis or {}
    ca_flops = float(ca.get("flops", 0.0) or 0.0)
    if ca_flops:
        notes.append(f"cost_analysis_flops_per_dev={ca_flops:.3g}")

    link_bw = hw.ici_bandwidth * max(hw.ici_links_per_axis, 1)
    compute_s = flops / (n_chips * hw.peak_flops)
    memory_s = byts / (n_chips * hw.hbm_bandwidth)
    collective_s = st.coll_link_bytes / link_bw

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=lambda k: terms[k])
    useful = model_flops / flops if flops > 0 else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_link_bytes=st.coll_link_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        coll_counts={k: round(v, 1) for k, v in st.coll_counts.items()},
        step_time_s=max(compute_s, memory_s, collective_s),
        notes=";".join(notes))

"""Memory-region allocation (paper §5.1 step 5 / §5.3).

The paper's compiler turns the dependency labels into a *region plan*
for main memory: a sequential chain ping-pongs between two activation
regions (the consumer reads one while the producer writes the other),
and every residual/parallel source holds a dedicated pinned region
until its last consumer retires it.  The instruction stream then reads
and writes region ids, never raw addresses.

This module is that allocator for a ``ModelGraph`` + ``ModelSchedule``
pair: it walks the executed op order (a pool fused into its producer
conv is one op), decides ping-pong vs pinned per output from the
consumer distances, reuses pinned regions after their last read, and
sizes every region at the largest output it ever holds.  The resulting
``RegionPlan`` is embedded in the executable ``Program``
(core/program.py) and drives the executor's region file.

Beyond the paper's transient activation regions, the allocator also
owns **persistent** regions: state that outlives a single Program run
(the serving KV cache — one (slots, cache_len, kv_heads, head_dim)
region per transformer block and cache side).  A persistent region is
never assigned to an op output, never retired and never reused; its id
is shared by every Program compiled against the same persistent table
(the prefill/decode pair), so the runtime's ``ProgramState`` buffers
are addressed identically by both.  The sizing rule is the paper's
"region sized at the largest output it holds" applied to state: a
sliding-window attention config can never attend past its window, so
its cache_len is ``min(max_len, attn_window)`` (the caller's
``PersistentSpec`` shape) and eviction is the runtime's rolling
overwrite at ``pos % cache_len`` — a region-plan decision, not a
runtime one.

Invariants:

* **Region ids are allocator-owned.**  This module is the only place
  a region id is ever minted — transient ids by ``allocate_regions``,
  persistent ids by ``extend_with_persistent`` — the Program lowering
  maps producer/state names to these ids and the executor keys its
  region file by them.  No other module may invent, renumber or alias
  a region.
* The allocator is label-agnostic at assignment time: pinning follows
  *consumer distances* in the executed op order, so any graph shape —
  ResNet shortcuts, the transformer residual stream, QKV fan-outs —
  is handled by the same rule (read past the next op => pinned until
  one step after the last read, then the region is reused).
* Pinned-region reuse keeps the footprint depth-independent for
  repeated structures: a dense transformer needs 2 ping-pong + 4
  pinned regions regardless of layer count.  Persistent regions are
  exempt: state cannot be reused across layers, so the KV table grows
  with depth by design.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

from .ir import ModelGraph

__all__ = ["Region", "RegionPlan", "PersistentSpec", "PagedPlan",
           "StateCaps", "allocate_regions", "extend_with_persistent",
           "paged_kv_specs", "pages_for_len", "register_state_family",
           "state_specs", "PAGE_TABLE_REGION"]

N_PINGPONG = 2          # the paper's sequential double-buffer pair


@dataclass(frozen=True)
class Region:
    rid: int
    kind: str            # "pingpong" | "pinned" | "persistent"
    size_bytes: int      # largest output this region ever holds
    # Persistent regions only: allocation identity the runtime builds
    # its state buffers from.  Transient regions leave these None.
    name: str | None = None
    shape: tuple | None = None
    dtype: str | None = None     # numpy dtype name ("float32", "bfloat16")


@dataclass(frozen=True)
class PersistentSpec:
    """One named persistent buffer to reserve.

    Historically always a KV table; a spec is now *generic named
    state*: an SSM recurrence ``(slots, heads, dn, dh)``, an rwkv
    wkv/shift pair, a hybrid's conv tail, or read-only encoder memory
    for cross-attention.  ``read_only`` marks state the decode stream
    only ever reads (encoder memory written once at admission); the
    executor never scatters into such a region and tests pin that.
    """

    name: str
    shape: tuple
    dtype: str                   # numpy dtype name
    size_bytes: int
    read_only: bool = False


@dataclass(frozen=True)
class RegionPlan:
    regions: tuple[Region, ...]          # transient regions: rid == index
    out_region: dict                     # layer name -> rid of its output
    input_region: int                    # rid the model input arrives in
    output_region: int                   # rid holding the final output
    # name -> rid of every persistent region (allocator-owned ids minted
    # by extend_with_persistent; shared across a Program pair).
    persistent: dict = field(default_factory=dict)

    @property
    def n_pingpong(self) -> int:
        return sum(1 for r in self.regions if r.kind == "pingpong")

    @property
    def n_pinned(self) -> int:
        return sum(1 for r in self.regions if r.kind == "pinned")

    @property
    def n_persistent(self) -> int:
        return sum(1 for r in self.regions if r.kind == "persistent")

    @property
    def total_bytes(self) -> int:
        """Activation footprint the plan reserves (sum of region sizes —
        the paper allocates the regions once, up front)."""
        return sum(r.size_bytes for r in self.regions
                   if r.kind != "persistent")

    @property
    def persistent_bytes(self) -> int:
        return sum(r.size_bytes for r in self.regions
                   if r.kind == "persistent")

    def region(self, rid: int) -> Region:
        # Transient rids index the tuple directly; persistent rids may
        # sit past a shared base (pair-aligned), so fall back to search.
        if rid < len(self.regions) and self.regions[rid].rid == rid:
            return self.regions[rid]
        for r in self.regions:
            if r.rid == rid:
                return r
        raise KeyError(rid)

    def persistent_regions(self) -> tuple:
        return tuple(r for r in self.regions if r.kind == "persistent")


def _fused_into(node, schedule) -> str | None:
    """Producer this pool runs inside of, under the given schedule (the
    schedule decides — materialized strips do not fuse), falling back to
    the graph annotation when no schedule is supplied."""
    src = node.meta.get("fused_into")
    if src is None:
        return None
    if schedule is None:
        return src
    try:
        return src if "fused_pool" in schedule.layer(src).notes else None
    except KeyError:
        return None


def allocate_regions(graph: ModelGraph, schedule=None) -> RegionPlan:
    """Turn dependency labels into the §5.1 region plan.

    Outputs consumed only by the next executed op alternate between the
    two ping-pong regions; an output read later than that (residual
    source, parallel-path input) is pinned to its own region until its
    last consumer executes, after which the region is reused.
    """
    nodes = list(graph)
    # --- executed-op order: a fused pool collapses into its conv ------------
    step_of: dict[str, int] = {}         # node name -> executed step
    out_bytes: dict[int, float] = {}     # step -> bytes its output occupies
    steps: list = []                     # step -> producing node
    for node in nodes:
        src = _fused_into(node, schedule)
        if src is not None and src in step_of:
            s = step_of[src]
            step_of[node.name] = s       # pool output lives in conv's region
            out_bytes[s] = node.operand_bytes()["out"]   # pooled, smaller
            continue
        s = len(steps)
        steps.append(node)
        step_of[node.name] = s
        out_bytes[s] = node.operand_bytes()["out"]

    # --- consumer steps per producing step ----------------------------------
    consumers: dict[int, list[int]] = {s: [] for s in range(len(steps))}
    input_consumers: list[int] = []      # steps reading the model input
    prev: str | None = None
    for node in nodes:
        s = step_of[node.name]
        reads = list(node.inputs)
        if node.bypass_of:
            reads.append(node.bypass_of)
        if not node.inputs and prev is not None:
            reads.append(prev)           # implicit sequential input
        for r in reads:
            ps = step_of.get(r)
            if ps is not None and ps != s:
                consumers[ps].append(s)
            elif ps is None:
                input_consumers.append(s)
        if not reads:
            input_consumers.append(s)
        prev = node.name
    for s in consumers:
        consumers[s] = sorted(set(consumers[s]))

    # --- assignment ----------------------------------------------------------
    input_bytes = steps[0].operand_bytes().get("maps", 0.0) if steps else 0.0
    sizes: dict[int, float] = {0: input_bytes, 1: 0.0}
    kinds: dict[int, str] = {0: "pingpong", 1: "pingpong"}
    out_region: dict[str, int] = {}
    input_region = 0
    free_pinned: list[int] = []
    retire_at: dict[int, list[int]] = {}   # step -> pinned rids freed after it

    if input_consumers and max(input_consumers) > 0:
        # The raw input outlives step 0's write slot: pin it.  (No paper
        # CNN does this — the graphs branch on layer outputs only — but
        # the allocator must not silently corrupt such a graph.)
        input_region = 2
        kinds[input_region] = "pinned"
        sizes[input_region] = sizes.pop(0)
        sizes[0] = 0.0

    def assign(step: int, rid: int) -> None:
        sizes[rid] = max(sizes.get(rid, 0.0), out_bytes[step])

    for s, node in enumerate(steps):
        for rid in retire_at.pop(s, []):
            free_pinned.append(rid)
        cons = consumers[s]
        pinned = bool(cons) and max(cons) > s + 1
        if pinned:
            if free_pinned:
                rid = min(free_pinned)
                free_pinned.remove(rid)
            else:
                rid = len(sizes)
                kinds[rid] = "pinned"
            # Free one step AFTER the last consumer: the consuming op is
            # still streaming this region while it writes its own output,
            # so the region cannot double as that output.
            retire_at.setdefault(max(cons) + 1, []).append(rid)
        else:
            # Strict alternation: the input occupies ping-pong 0, step s
            # writes ping-pong (s+1) % 2.  Anything still needed past the
            # next step is pinned above, so the overwritten slot is dead.
            rid = (s + 1) % N_PINGPONG
        assign(s, rid)
        out_region[node.name] = rid

    # Alias fused pools (and any other collapsed nodes) to their step's rid.
    for name, s in step_of.items():
        if name not in out_region:
            out_region[name] = out_region[steps[s].name]

    regions = tuple(Region(rid, kinds[rid], int(sizes.get(rid, 0.0)))
                    for rid in range(len(sizes)))
    final = out_region[steps[-1].name] if steps else input_region
    return RegionPlan(regions=regions, out_region=out_region,
                      input_region=input_region, output_region=final)


def extend_with_persistent(plan: RegionPlan, specs: tuple,
                           base_rid: int | None = None) -> RegionPlan:
    """Reserve persistent regions on top of a transient plan.

    Persistent ids start at ``base_rid`` (default: one past the
    transient regions) so a *pair* of Programs can share one persistent
    table: compile both transient plans first, pass the same
    ``base_rid = max(len(p.regions) for p in plans)`` and the same
    ``specs`` to each, and the minted ids coincide — the runtime's
    state buffers are then addressed identically by both instruction
    streams.  Persistent regions never appear in ``out_region`` and are
    never reused or retired by the transient allocator.
    """
    base = len(plan.regions) if base_rid is None else base_rid
    if base < len(plan.regions):
        raise ValueError(
            f"persistent base rid {base} collides with "
            f"{len(plan.regions)} transient regions")
    persistent = dict(plan.persistent)
    extra = []
    for i, spec in enumerate(specs):
        if spec.name in persistent:
            raise ValueError(f"duplicate persistent region {spec.name!r}")
        rid = base + i
        persistent[spec.name] = rid
        extra.append(Region(rid, "persistent", int(spec.size_bytes),
                            name=spec.name, shape=tuple(spec.shape),
                            dtype=spec.dtype))
    return replace(plan, regions=plan.regions + tuple(extra),
                   persistent=persistent)


# --- paged KV plan (§5.1 third scheme: ping-pong, rolling-ring, paged) -------------
PAGE_TABLE_REGION = "page_table"     # the pair's one per-slot page-table region


@dataclass(frozen=True)
class PagedPlan:
    """The §5.1 allocator's paged-KV decision record.

    Instead of one contiguous (slots, cache_len) row table per block
    and side, the plan reserves a **fixed-size page pool** — ``n_pages``
    pages of ``page_size`` rows each, shared by every slot — plus one
    per-slot **page table** (slots, pages_per_slot) int32 mapping each
    slot's virtual row range onto pool pages.  Page ids are *slot
    agnostic*: two slots whose tables name the same page share its rows
    (copy-on-write prefix sharing), and a short sequence holds only the
    pages it has touched — admission stops reserving worst-case rows.

    Page 0 is the **null page**: never handed out by the runtime
    allocator, it is the write target for masked rows (dead slots, the
    shared span of a prefill) so scatters stay dense and branch-free.

    ``kv_dtype`` is the pool element type — "int8" stores quantized
    pages with one float32 scale per page and side (dequantized in the
    gather), any float dtype stores rows verbatim.  The virtual extent
    rule is ``ring_kv_len(pos, cache_len)`` with ``cache_len =
    pages_per_slot * page_size`` — the same shared rule as the rolling
    ring, applied through the table."""

    page_size: int
    n_pages: int                     # pool pages per block+side (incl. null)
    pages_per_slot: int
    kv_dtype: str = "float32"

    @property
    def cache_len(self) -> int:
        return self.pages_per_slot * self.page_size

    @property
    def quantized(self) -> bool:
        return self.kv_dtype == "int8"


def paged_kv_specs(*, n_layers: int, kv_heads: int, head_dim: int,
                   slots: int, max_len: int, page_size: int,
                   n_pages: int | None = None,
                   kv_dtype: str = "float32"
                   ) -> tuple[tuple[PersistentSpec, ...], PagedPlan]:
    """Mint the paged persistent table: per block+side a page pool
    ``l{i}.k_pages`` / ``l{i}.v_pages`` of (n_pages, page_size,
    kv_heads, head_dim) — int8 pools additionally carry per-page scale
    vectors ``l{i}.k_scale`` / ``l{i}.v_scale`` (n_pages,) float32 —
    plus the single shared ``page_table`` region (slots,
    pages_per_slot) int32.

    ``n_pages`` defaults to worst case (every slot fully resident plus
    the null page); a caller fixing an HBM budget passes fewer pages
    and the runtime allocator admits only what fits — the
    serve-more-sequences-per-byte knob."""
    if max_len % page_size:
        raise ValueError(
            f"paged KV needs max_len ({max_len}) divisible by "
            f"page_size ({page_size}) so prefill rows tile into pages")
    pages_per_slot = max_len // page_size
    if n_pages is None:
        # +1 null page, and never below the floor (one full slot + a
        # spare COW/fork page) even for a single-slot pool.
        n_pages = max(1 + slots * pages_per_slot, 2 + pages_per_slot)
    if n_pages < 2 + pages_per_slot:
        raise ValueError(
            f"page pool of {n_pages} cannot hold even one full slot "
            f"({pages_per_slot} pages) plus the null page")
    from jax import numpy as jnp          # bfloat16/float8 dtype names
    pool_shape = (n_pages, page_size, kv_heads, head_dim)
    by = jnp.dtype(kv_dtype).itemsize
    pool_bytes = math.prod(pool_shape) * by
    specs: list[PersistentSpec] = []
    for i in range(n_layers):
        specs.append(PersistentSpec(f"l{i}.k_pages", pool_shape,
                                    "int8" if kv_dtype == "int8" else kv_dtype,
                                    pool_bytes))
        specs.append(PersistentSpec(f"l{i}.v_pages", pool_shape,
                                    "int8" if kv_dtype == "int8" else kv_dtype,
                                    pool_bytes))
        if kv_dtype == "int8":
            specs.append(PersistentSpec(f"l{i}.k_scale", (n_pages,),
                                        "float32", n_pages * 4))
            specs.append(PersistentSpec(f"l{i}.v_scale", (n_pages,),
                                        "float32", n_pages * 4))
    specs.append(PersistentSpec(PAGE_TABLE_REGION, (slots, pages_per_slot),
                                "int32", slots * pages_per_slot * 4))
    plan = PagedPlan(page_size=page_size, n_pages=n_pages,
                     pages_per_slot=pages_per_slot, kv_dtype=kv_dtype)
    return tuple(specs), plan


def pages_for_len(length: int, page_size: int) -> int:
    """Pages a sequence of ``length`` rows occupies (host-side rule the
    runtime page allocator and the admission path share)."""
    return max(0, math.ceil(length / page_size))


# --- generic named state: the per-family state_specs hook ----------------------
@dataclass(frozen=True)
class StateCaps:
    """What the serving engine may do with a family's persistent state.

    The engine's paged/COW, windowed, chunked-prefill and speculative-
    decode gates consult these instead of assuming KV shape:

    * ``paged``       — state is row-addressable KV, so the §5.1 paged
                        plan (page pools + page table, COW prefix
                        sharing) applies.
    * ``windowed``    — a sliding ``attn_window`` maps onto ring
                        eviction at ``pos % cache_len``.
    * ``chunkable``   — prefill may be split into row chunks; true only
                        when mid-prefill state is a pure row table (a
                        half-written recurrence is not resumable by the
                        chunk runner).
    * ``speculatable``— rejected draft tokens can be rolled back by
                        truncating ``lengths`` (KV rows are simply
                        overwritten; a mutated recurrence cannot be
                        un-stepped).
    """

    paged: bool = False
    windowed: bool = False
    chunkable: bool = False
    speculatable: bool = False


# family name -> fn(cfg, slots, max_len) -> (tuple[PersistentSpec], StateCaps)
_STATE_FAMILIES: dict = {}


def register_state_family(family: str, fn) -> None:
    """Register a family's persistent-state minting hook.

    Model modules call this at import time (``models/registry.py``
    imports them all), keeping the allocator the only place region ids
    are minted while the *shapes* stay family-owned.
    """
    _STATE_FAMILIES[family] = fn


def state_specs(cfg, slots: int, max_len: int
                ) -> tuple[tuple[PersistentSpec, ...], StateCaps]:
    """Mint the persistent-state specs + capabilities for one config.

    Every spec's leading axis is ``slots`` — the one engine-visible
    invariant; everything after that is family business (KV rows, SSM
    heads, wkv matrices, encoder memory...).  Raises
    ``NotImplementedError`` naming the family when no hook is
    registered, which the serving engine surfaces as its fallback
    reason.
    """
    fn = _STATE_FAMILIES.get(cfg.family)
    if fn is None:
        raise NotImplementedError(
            f"{cfg.name} is blocked by: family {cfg.family!r} has no "
            f"registered state_specs hook — it still runs the scan "
            f"forward")
    specs, caps = fn(cfg, slots, max_len)
    for s in specs:
        if not s.shape or s.shape[0] != slots:
            raise ValueError(
                f"state spec {s.name!r} leading axis {s.shape[:1]} != "
                f"slots ({slots}); per-slot addressing requires axis 0 "
                f"to be the slot axis")
    return tuple(specs), caps

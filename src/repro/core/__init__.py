"""Core compiler: the paper's contribution generalized for TPU.

Pipeline:  ModelGraph (ir) -> tiles (tiling) -> loop order (dataflow)
        -> balance (balance) -> ModelSchedule (schedule)
        -> regions (regions) -> Program (program) -> runtime/executor.
"""
from .hw import (HardwareModel, MeshDescriptor, MULTI_POD, SINGLE_POD,
                 SNOWFLAKE, TPU_V5E)
from .ir import (DepLabel, LayerKind, LayerNode, ModelGraph, conv_node,
                 matmul_node)
from .tiling import (ConvTiling, MatmulTiling, select_conv_row_strips,
                     select_matmul_tiles)
from .dataflow import (Dataflow, DataflowDecision, DistDecision,
                       DistStrategy, choose_dist_strategy,
                       choose_matmul_dataflow, matmul_traffic)
from .balance import (assign_lpt, balance_transfers, moe_capacity,
                      percent_imbalance, split_transfer)
from .schedule import LayerSchedule, ModelSchedule, compile_model
from .regions import Region, RegionPlan, allocate_regions
from .program import Program, ProgramOp, lower_to_program
from .quant import (Q5_11, Q8_8, QFormat, dequantize, int8_matmul,
                    int8_quantize_per_channel, qmatmul, quantize,
                    validate_layerwise)
from .roofline import (CollectiveStats, RooflineReport,
                       collective_stats_from_hlo, roofline_report)

__all__ = [n for n in dir() if not n.startswith("_")]

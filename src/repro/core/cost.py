"""Measured cost calibration — from roofline guess to fitted predictor.

The schedule compiler prices every layer with an analytic roofline,
``hw.exec_time(flops, bytes) = max(compute, memory)``.  That model has
the right *shape* (linear in flops and bytes) but made-up *constants*:
real kernels pay launch overhead, achieve a fraction of peak, and hide
different amounts of traffic.  This module closes the gap the way
byteprofile-style profilers do: take executor trace records (see
``runtime/executor.ExecutorTrace``), and fit, per kernel kind,

    t_measured  ~=  alpha * flops  +  beta * traffic_bytes  +  gamma

by ordinary least squares.  ``alpha`` is an effective 1/FLOPs-rate,
``beta`` an effective 1/bandwidth, ``gamma`` the per-call overhead —
the same three quantities the roofline hard-codes, now measured.

Kinds with too few distinct records for a stable 3-parameter fit fall
back to a single multiplicative correction (``scale`` mode): the median
measured/modeled ratio applied to the analytic prediction.  Kinds never
seen at all pass the analytic prediction through unchanged, so a
``CostModel`` is always total: calibration refines, never breaks.
The family op kinds (``ssm_scan`` / ``wkv`` / ``moe_dispatch`` /
``cross_attention``) enter as ordinary kinds — fitted when their trace
records carry measurements, analytic passthrough otherwise; the
autotuner never *replays* them (``autotune.TUNABLE`` excludes them —
they stay identity-only), but their calibration still re-prices the
schedule's exec_time.

The fitted model serializes to JSON and rides in the tuned-schedule
cache (``core/autotune.py``); ``compile_model(..., cost_model=...)``
re-prices every ``LayerSchedule.exec_time_s`` with it.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field

__all__ = ["KindFit", "CostModel", "fit_cost_model", "error_table",
           "format_error_table"]

# Minimum records for a full 3-coefficient least-squares fit; below
# this the normal equations are under-determined (or fit noise) and the
# scale fallback is safer.
MIN_LSQ_RECORDS = 4


@dataclass(frozen=True)
class KindFit:
    """Calibration for one kernel kind.

    ``mode`` is ``"lsq"`` (alpha/beta/gamma valid) or ``"scale"``
    (only ``scale`` valid, applied to the analytic prediction).
    """
    mode: str
    alpha: float = 0.0          # s per flop
    beta: float = 0.0           # s per byte
    gamma: float = 0.0          # s per call
    scale: float = 1.0          # measured/modeled ratio (scale mode)
    n_records: int = 0
    mean_abs_rel_err: float = 0.0   # of the fit, on its own records


def _lsq3(rows: list[tuple[float, float, float]],
          ys: list[float]) -> tuple[float, float, float] | None:
    """Solve min ||X c - y|| for X rows (flops, bytes, 1) via the
    normal equations with Gaussian elimination — 3x3, no numpy needed.
    Returns None when the system is singular (e.g. all-identical rows).
    """
    # Column scaling keeps the 3x3 well conditioned (flops ~1e9 vs 1).
    sf = max(max(abs(r[0]) for r in rows), 1.0)
    sb = max(max(abs(r[1]) for r in rows), 1.0)
    xs = [(r[0] / sf, r[1] / sb, r[2]) for r in rows]
    ata = [[0.0] * 3 for _ in range(3)]
    aty = [0.0] * 3
    for x, y in zip(xs, ys):
        for i in range(3):
            aty[i] += x[i] * y
            for j in range(3):
                ata[i][j] += x[i] * x[j]
    # Gaussian elimination with partial pivoting.
    m = [row[:] + [atyv] for row, atyv in zip(ata, aty)]
    for col in range(3):
        piv = max(range(col, 3), key=lambda r: abs(m[r][col]))
        if abs(m[piv][col]) < 1e-18:
            return None
        m[col], m[piv] = m[piv], m[col]
        for r in range(3):
            if r != col:
                f = m[r][col] / m[col][col]
                for c in range(col, 4):
                    m[r][c] -= f * m[col][c]
    c = [m[i][3] / m[i][i] for i in range(3)]
    return c[0] / sf, c[1] / sb, c[2]


def _records_for_fit(records: list[dict]) -> dict[str, list[dict]]:
    by_kind: dict[str, list[dict]] = {}
    for r in records:
        if r.get("measured_time_s") is None:
            continue
        by_kind.setdefault(str(r["kind"]), []).append(r)
    return by_kind


def _fit_kind(recs: list[dict]) -> KindFit:
    ys = [float(r["measured_time_s"]) for r in recs]
    rows = [(float(r.get("flops", 0.0)),
             float(r.get("traffic_bytes", 0.0)), 1.0) for r in recs]
    distinct = len({(r[0], r[1]) for r in rows})
    coeffs = (_lsq3(rows, ys)
              if len(recs) >= MIN_LSQ_RECORDS and distinct >= 3 else None)
    if coeffs is not None:
        a, b, g = coeffs
        preds = [max(a * r[0] + b * r[1] + g, 0.0) for r in rows]
        # A fit that predicts non-positive time for real records is
        # extrapolating garbage; fall back to scale mode.
        if all(p > 0.0 for p in preds):
            err = _mean_abs_rel_err(preds, ys)
            return KindFit("lsq", alpha=a, beta=b, gamma=g,
                           n_records=len(recs), mean_abs_rel_err=err)
    ratios = sorted(float(r["measured_time_s"])
                    / max(float(r.get("modeled_time_s", 0.0)), 1e-12)
                    for r in recs)
    scale = ratios[len(ratios) // 2]     # median: robust to one outlier
    preds = [scale * max(float(r.get("modeled_time_s", 0.0)), 1e-12)
             for r in recs]
    return KindFit("scale", scale=scale, n_records=len(recs),
                   mean_abs_rel_err=_mean_abs_rel_err(preds, ys))


def _mean_abs_rel_err(preds: list[float], ys: list[float]) -> float:
    errs = [abs(p - y) / max(abs(y), 1e-12) for p, y in zip(preds, ys)]
    return sum(errs) / max(len(errs), 1)


@dataclass(frozen=True)
class CostModel:
    """Total function from (kind, flops, bytes, analytic guess) to
    calibrated seconds.  Immutable; build with ``fit_cost_model`` or
    ``CostModel.from_json``."""
    fits: dict[str, KindFit] = field(default_factory=dict)

    def predict(self, kind: str, flops: float, traffic_bytes: float,
                fallback_time_s: float) -> float:
        f = self.fits.get(kind)
        if f is None:
            return fallback_time_s
        if f.mode == "lsq":
            t = f.alpha * flops + f.beta * traffic_bytes + f.gamma
            if t > 0.0:
                return t
            # degenerate extrapolation -> analytic guess is safer
            return fallback_time_s
        return f.scale * fallback_time_s

    def to_json(self) -> str:
        return json.dumps(
            {k: dataclasses.asdict(v) for k, v in sorted(self.fits.items())},
            indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CostModel":
        raw = json.loads(text)
        return cls({k: KindFit(**v) for k, v in raw.items()})


def fit_cost_model(records: list[dict]) -> CostModel:
    """Fit per-kind coefficients over executor trace records.

    Each record needs ``kind``, ``flops``, ``traffic_bytes``,
    ``modeled_time_s`` and ``measured_time_s`` (records without a
    measurement are skipped — e.g. interpret-mode traces used only for
    schema checks).
    """
    return CostModel({k: _fit_kind(v)
                      for k, v in _records_for_fit(records).items()})


def error_table(records: list[dict],
                model: CostModel | None = None) -> list[dict]:
    """Measured-vs-predicted summary per kernel kind.

    One row per kind: record count, mean |rel err| of the *analytic*
    model, and — when a fitted ``model`` is given — of the calibrated
    prediction, plus the calibration mode.  This is the table the
    replay harness prints (ISSUE 6 acceptance: "the measured-vs-
    predicted error table is emitted by the replay harness").
    """
    out: list[dict] = []
    for kind, recs in sorted(_records_for_fit(records).items()):
        ys = [float(r["measured_time_s"]) for r in recs]
        analytic = [float(r.get("modeled_time_s", 0.0)) for r in recs]
        row = {
            "kind": kind,
            "n": len(recs),
            "mean_measured_us": 1e6 * sum(ys) / len(ys),
            "analytic_abs_rel_err": _mean_abs_rel_err(analytic, ys),
        }
        if model is not None:
            preds = [model.predict(kind, float(r.get("flops", 0.0)),
                                   float(r.get("traffic_bytes", 0.0)),
                                   float(r.get("modeled_time_s", 0.0)))
                     for r in recs]
            row["calibrated_abs_rel_err"] = _mean_abs_rel_err(preds, ys)
            f = model.fits.get(kind)
            row["mode"] = f.mode if f else "passthrough"
        out.append(row)
    return out


def format_error_table(rows: list[dict]) -> str:
    """Fixed-width rendering of ``error_table`` rows for CLI output."""
    if not rows:
        return "(no measured records)"
    hdr = (f"{'kind':<18} {'n':>4} {'measured_us':>12} "
           f"{'analytic_err':>13} {'calibrated_err':>15} {'mode':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        cal = r.get("calibrated_abs_rel_err")
        lines.append(
            f"{r['kind']:<18} {r['n']:>4} {r['mean_measured_us']:>12.2f} "
            f"{r['analytic_abs_rel_err']:>12.1%} "
            + (f"{cal:>14.1%} " if cal is not None else f"{'-':>15} ")
            + f"{r.get('mode', '-'):>8}")
    return "\n".join(lines)

"""Sharded token data pipeline.

Deterministic, restart-safe (the iterator state is one integer — the
global step — checkpointed with the model), host-sharded (each host
materializes only its slice of the global batch), with background
prefetch.  Two sources:

* ``SyntheticLM`` — seeded random tokens with a simple learnable n-gram
  structure (used by the end-to-end examples and tests);
* ``PackedFileDataset`` — memory-mapped uint16/uint32 token files
  (one long stream), packed into fixed-length rows.

The paper's T4 applies here too: hosts are "load units" — the sampler
assigns disjoint, contiguous row ranges per host so byte traffic is
balanced (percent imbalance 0 by construction).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["SyntheticLM", "PackedFileDataset", "Prefetcher", "make_batches"]


@dataclass(frozen=True)
class SyntheticLM:
    """Seeded synthetic LM stream: token t+1 = (a*t + noise) % vocab.

    Loss decreases measurably within a few hundred steps on a ~100M
    model, which is what the end-to-end example needs to demonstrate.
    """

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.9   # prob. that the next token is predictable

    def batch_at(self, step: int, host_id: int = 0,
                 n_hosts: int = 1) -> dict:
        per_host = self.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_id]))
        B, S, V = per_host, self.seq_len, self.vocab
        noise = rng.integers(0, V, size=(B, S), dtype=np.int32)
        first = rng.integers(0, V, size=(B, 1), dtype=np.int32)
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = first[:, 0]
        structured = rng.random((B, S)) < self.structure
        for t in range(1, S):
            pred = (toks[:, t - 1] * 31 + 7) % V
            toks[:, t] = np.where(structured[:, t], pred, noise[:, t])
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        return {"tokens": toks, "labels": labels}


class PackedFileDataset:
    """Memory-mapped token stream packed into (seq_len+1)-sized rows."""

    def __init__(self, path: str, vocab: int, seq_len: int,
                 global_batch: int, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.rows = (len(self.tokens) - 1) // seq_len

    def batch_at(self, step: int, host_id: int = 0,
                 n_hosts: int = 1) -> dict:
        per_host = self.global_batch // n_hosts
        start_row = (step * self.global_batch + host_id * per_host)
        S = self.seq_len
        toks = np.empty((per_host, S), np.int32)
        labels = np.empty((per_host, S), np.int32)
        for i in range(per_host):
            r = (start_row + i) % self.rows
            seg = np.asarray(self.tokens[r * S: r * S + S + 1], np.int32)
            toks[i] = seg[:-1] % self.vocab
            labels[i] = seg[1:] % self.vocab
        return {"tokens": toks, "labels": labels}


class Prefetcher:
    """Background thread producing batches ahead of the training loop."""

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 host_id: int = 0, n_hosts: int = 1):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._host = host_id
        self._n_hosts = n_hosts
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step, self._host, self._n_hosts)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)


def make_batches(source, sharding=None):
    """Generator of device-placed batches (single-host path)."""
    step = 0
    while True:
        batch = source.batch_at(step)
        if sharding is not None:
            batch = jax.tree.map(
                lambda x: jax.device_put(x, sharding), batch)
        yield step, batch
        step += 1

from .pipeline import PackedFileDataset, Prefetcher, SyntheticLM, make_batches
__all__ = ["PackedFileDataset", "Prefetcher", "SyntheticLM", "make_batches"]

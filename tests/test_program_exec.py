"""Program execution: graph -> schedule -> regions -> Program ->
executor parity with the legacy layer-by-layer forward and the oracle
kernels, the schedule flags observably driving the executed ops, and
the §5.1 region allocator's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CNN_REGISTRY
from repro.configs.base import CNNConfig, CNNLayer as C
from repro.core import (SNOWFLAKE, TPU_V5E, ModelGraph, allocate_regions,
                        compile_model, conv_node, matmul_node)
from repro.models import cnn, init_params
from repro.models.cnn import reference_forward as legacy_forward
from repro.runtime import executor

K0 = jax.random.PRNGKey(0)


TINY = CNNConfig(
    name="tiny-prog", input_hw=16, input_ch=4, n_classes=10,
    layers=(
        C("conv", 8, 3, 1, 1),
        C("maxpool", k=2, stride=2),           # fuses into conv 0
        C("conv", 8, 3, 1, 1),
        C("conv", 8, 3, 1, 1, activation="relu", bypass_of=1),  # residual
        C("fc", 10, activation=None),
    ))


# --- end-to-end parity -------------------------------------------------------------
@pytest.mark.parametrize("name", ["alexnet-owt", "resnet18"])
def test_program_matches_legacy_forward_and_ref(name):
    cfg = CNN_REGISTRY[name]
    params = init_params(cnn.param_defs(cfg), K0)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, cfg.input_hw, cfg.input_hw, cfg.input_ch),
                          jnp.float32)
    program = cnn.compile_program(cfg, batch=1)
    out = executor.run(program, params, x, impl="reference")
    ref = legacy_forward(params, x, cfg)         # conv2d_ref chain
    assert out.shape == (1, cfg.n_classes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)
    # the thin wrapper is the same path (jit may reassociate: <=1e-5)
    fwd = cnn.forward(params, x, cfg, impl="reference")
    np.testing.assert_allclose(np.asarray(fwd), np.asarray(out),
                               rtol=0, atol=1e-5)


def test_program_pallas_interpret_residual_and_fused_pool():
    """The Pallas kernels execute the program with the schedule's exact
    tiling — covering a fused-pool conv and a residual-bypass conv."""
    cfg = TINY
    program = cnn.compile_program(cfg, batch=2)
    op0 = program.op("conv_00")
    assert op0.fuse_pool == (2, 2, 0, "max")     # schedule flag -> executed op
    assert op0.strip_storage == "virtual"
    assert op0.conv_tiling is not None
    sink = program.op("conv_03")
    assert sink.fuse_bypass and sink.bypass_region is not None
    params = init_params(cnn.param_defs(cfg), K0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 4), jnp.float32)
    ref = legacy_forward(params, x, cfg)
    out = executor.run(program, params, x, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_forward_is_cached_per_config_hw_batch():
    p1 = cnn.compile_program(TINY, batch=2)
    assert cnn.compile_program(TINY, batch=2) is p1
    assert cnn.compile_program(TINY, batch=4) is not p1
    assert cnn.compile_program(TINY, batch=2, hw=SNOWFLAKE) is not p1


# --- the schedule drives the program ----------------------------------------------
def test_schedule_flags_drive_program_ops():
    cfg = CNN_REGISTRY["alexnet-owt"]
    # TPU schedule: zero-copy strips, conv->pool fused, pool op gone.
    prog_tpu = cnn.compile_program(cfg, batch=1, hw=TPU_V5E)
    names = [op.name for op in prog_tpu.ops]
    assert "maxpool_01" not in names
    assert prog_tpu.op("conv_00").fuse_pool == (3, 2, 0, "max")
    assert prog_tpu.op("conv_00").strip_storage == "virtual"
    # Snowflake paper-faithful schedule: materialized strips, no fused
    # pool -> the pool is its own instruction.
    prog_sf = cnn.compile_program(cfg, batch=1, hw=SNOWFLAKE,
                                  paper_faithful=True)
    names_sf = [op.name for op in prog_sf.ops]
    assert "maxpool_01" in names_sf
    assert prog_sf.op("conv_00").fuse_pool is None
    assert prog_sf.op("conv_00").strip_storage == "materialized"
    # the two programs execute identical numerics regardless
    params = init_params(cnn.param_defs(cfg), K0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 224, 224, 3),
                          jnp.float32)
    a = executor.run(prog_tpu, params, x, impl="reference")
    b = executor.run(prog_sf, params, x, impl="reference")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=0, atol=1e-5)


def test_program_listing_is_paper_style_trace():
    prog = cnn.compile_program(CNN_REGISTRY["alexnet-owt"], batch=1)
    listing = prog.listing()
    assert "program alexnet-owt" in listing
    assert "%00 conv2d" in listing
    assert "r0->r1" in listing and "+pool3s2" in listing
    assert len(listing.splitlines()) == len(prog.ops) + 1


# --- region allocator --------------------------------------------------------------
def _seq_graph(n=4):
    g = ModelGraph("seq")
    prev = None
    for i in range(n):
        g.add(conv_node(f"c{i}", 16, 16, 8, 8, 3, 3, pad=1,
                        inputs=[prev] if prev else [], dtype_bytes=2))
        prev = f"c{i}"
    return g


def test_regions_sequential_pingpong():
    g = _seq_graph(5)
    sched = compile_model(g, TPU_V5E)
    plan = allocate_regions(g, sched)
    assert plan.n_pingpong == 2 and plan.n_pinned == 0
    # strict alternation, never writing the region just read
    rids = [plan.out_region[f"c{i}"] for i in range(5)]
    assert rids == [1, 0, 1, 0, 1]
    assert plan.input_region == 0
    assert plan.output_region == rids[-1]


def test_regions_residual_pins_until_sink_retires():
    g = ModelGraph("res")
    g.add(conv_node("c0", 16, 16, 8, 8, 3, 3, pad=1, dtype_bytes=2))
    g.add(conv_node("c1", 16, 16, 8, 8, 3, 3, pad=1, inputs=["c0"],
                    dtype_bytes=2))
    g.add(conv_node("c2", 16, 16, 8, 8, 3, 3, pad=1, inputs=["c1"],
                    bypass_of="c0", dtype_bytes=2))
    g.add(conv_node("c3", 16, 16, 8, 8, 3, 3, pad=1, inputs=["c2"],
                    dtype_bytes=2))
    sched = compile_model(g, TPU_V5E)
    plan = allocate_regions(g, sched)
    assert plan.n_pinned == 1                     # c0 pinned for the bypass
    pinned = plan.out_region["c0"]
    assert plan.region(pinned).kind == "pinned"
    # the sink reads the pinned region but writes elsewhere
    assert plan.out_region["c2"] != pinned
    # pinned region sized for exactly c0's output
    assert plan.region(pinned).size_bytes == 16 * 16 * 8 * 2


def test_regions_projection_shortcut_needs_two_pinned():
    # ResNet18 stage-entry block: source feeds proj + main path, proj
    # output crosses two ops to the sink -> two concurrent pinned.
    prog = cnn.compile_program(CNN_REGISTRY["resnet18"], batch=1)
    assert prog.plan.n_pingpong == 2
    assert prog.plan.n_pinned == 2


def test_regions_peak_bytes():
    g = _seq_graph(3)          # all activations 16*16*8 @2B = 4096 B
    sched = compile_model(g, TPU_V5E)
    plan = allocate_regions(g, sched)
    assert plan.total_bytes == 2 * 4096            # two ping-pong regions
    # fused pool shrinks the producer's region to the pooled output
    prog = cnn.compile_program(TINY, batch=1)
    r0 = prog.plan.region(prog.op("conv_00").out_region)
    pooled_bytes = 8 * 8 * 8 * 4                   # 16x16 pooled 2x, f32
    assert r0.size_bytes == pooled_bytes


def test_executor_matmul_residual_bypass():
    """A matmul residual sink (MLP block): the executor must add the
    bypass region on writeback, exactly as the listing's '+bypass'."""
    from repro.core import lower_to_program
    g = ModelGraph("mlp_res")
    g.add(matmul_node("up", 4, 8, 8, dtype_bytes=4, fused_bias=True,
                      param="l0"))
    g.add(matmul_node("mid", 4, 8, 8, dtype_bytes=4, fused_bias=True,
                      fused_activation="relu", inputs=["up"], param="l1"))
    g.add(matmul_node("down", 4, 8, 8, dtype_bytes=4, fused_bias=True,
                      inputs=["mid"], bypass_of="up", param="l2"))
    sched = compile_model(g, TPU_V5E)
    prog = lower_to_program(g, sched)
    sink = prog.op("down")
    assert sink.fuse_bypass and sink.bypass_region is not None
    ks = jax.random.split(K0, 7)
    params = {f"l{i}": {"w": jax.random.normal(ks[2 * i], (8, 8)) * 0.3,
                        "b": jax.random.normal(ks[2 * i + 1], (8,)) * 0.1}
              for i in range(3)}
    x = jax.random.normal(ks[6], (4, 8), jnp.float32)
    out = executor.run(prog, params, x, impl="reference")
    h0 = x @ params["l0"]["w"] + params["l0"]["b"]
    h1 = jax.nn.relu(h0 @ params["l1"]["w"] + params["l1"]["b"])
    want = h1 @ params["l2"]["w"] + params["l2"]["b"] + h0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_regions_matmul_chain():
    g = ModelGraph("mlp")
    g.add(matmul_node("up", 8, 16, 32, dtype_bytes=4))
    g.add(matmul_node("down", 8, 32, 16, inputs=["up"], dtype_bytes=4))
    sched = compile_model(g, TPU_V5E)
    plan = allocate_regions(g, sched)
    assert plan.n_pingpong == 2 and plan.n_pinned == 0
    assert plan.region(plan.out_region["up"]).size_bytes == 8 * 32 * 4


# --- serving fast path -------------------------------------------------------------
def test_serving_engine_program_fast_path():
    from repro.serving import Request, ServingEngine
    cfg = TINY
    params = init_params(cnn.param_defs(cfg), K0)
    eng = ServingEngine(cfg, params, slots=2, impl="reference")
    assert eng.program is not None
    rng = np.random.default_rng(0)
    imgs = [rng.standard_normal((16, 16, 4)).astype(np.float32)
            for _ in range(3)]
    for i, img in enumerate(imgs):
        eng.submit(Request(uid=i, prompt=img))
    done = eng.run_until_drained()
    assert len(done) == 3 and all(r.done for r in done)
    # engine results match the plain forward path
    ref = cnn.forward(params, jnp.asarray(np.stack(imgs)), cfg,
                      impl="reference")
    want = [int(np.argmax(np.asarray(ref)[i])) for i in range(3)]
    got = [r.out_tokens[0] for r in sorted(done, key=lambda r: r.uid)]
    assert got == want

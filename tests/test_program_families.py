"""Per-family Program parity: generic named persistent state.

Every registered state family — MoE (capacity-bucketed expert
dispatch), pure SSM (mamba2), rwkv6 recurrence, zamba2 hybrid
(SSM + shared windowed attention), whisper encoder-decoder
(cross-attention over read-only encoder memory) — compiles to the same
(prefill, decode) Program pair and matches its legacy cache loop at
<=1e-5, with persistent regions minted through the one generic
``regions.state_specs`` hook.

Oracle note (MoE): the legacy *batched* forward routes every
sequence's tokens jointly through the capacity buckets, so it is NOT a
per-request oracle.  Teacher-forcing the legacy ``decode_step`` routes
exactly the Program's token batches (slots per tick), and at smoke
scale no expert ever exceeds its capacity, so parity is exact."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.core import regions
from repro.models import get_model, init_params, transformer
from repro.runtime import executor

K0 = jax.random.PRNGKey(0)

FAMILY_ARCHS = ["granite-moe-1b-a400m", "mamba2", "rwkv6-7b",
                "zamba2-7b", "whisper-base"]


def _setup(name, slots=2, max_len=16, **over):
    cfg = REGISTRY[name].smoke()
    if over:
        cfg = dataclasses.replace(cfg, **over)
    api = get_model(cfg)
    params = init_params(api.param_defs(cfg), K0)
    pair = transformer.compile_program_pair(cfg, slots=slots,
                                            max_len=max_len)
    state = executor.init_program_state(pair)
    return cfg, api, params, pair, state


def _write_memory(api, cfg, params, pair, state, cache, slot, frames):
    """Admission-time write of read-only encoder memory: scatter the
    ``encode_memory`` rows into the Program state at ``slot`` AND into
    the legacy cache's cross K/V (same source, both sides of the
    parity check)."""
    rows = api.encode_memory(params, jnp.asarray(frames), cfg,
                             impl="reference")
    for nm, row in rows.items():
        rid = pair.persistent[nm]
        buf = state.caches[rid]
        state.caches[rid] = buf.at[slot].set(row.astype(buf.dtype))
    for i in range(cfg.n_layers):
        for side in ("k", "v"):
            leg = cache[f"cross_{side}"]
            row = rows[f"l{i}.cross_{side}"]          # (Te, KV, hd)
            cache[f"cross_{side}"] = leg.at[i, slot].set(
                row.transpose(1, 0, 2).astype(leg.dtype))
    return state, cache


def _prefill_slot(pair, params, state, slot, prompt, max_len):
    padded = np.zeros((1, max_len), np.int32)
    padded[0, :len(prompt)] = prompt
    return executor.run_prefill(pair.prefill, params,
                                jnp.asarray(padded), state, slot,
                                len(prompt), impl="reference")


# --- prefill + N-decode parity vs each family's legacy cache loop ------------------
@pytest.mark.parametrize("name", FAMILY_ARCHS)
def test_family_prefill_decode_parity(name):
    """Program prefill + N decode steps == teacher-forcing the same
    tokens through the family's legacy ``init_cache``/``decode_step``
    loop, logits <=1e-5 at every step."""
    slots, max_len, P, N = 2, 16, 5, 4
    cfg, api, params, pair, state = _setup(name, slots, max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(slots, P)).astype(np.int32)

    cache = api.init_cache(cfg, slots, max_len)
    if api.extra_input == "encoder_frames":
        for s in range(slots):
            frames = rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
            state, cache = _write_memory(api, cfg, params, pair, state,
                                         cache, s, frames)

    for t in range(P):
        leg_logits, cache = api.decode_step(
            params, cache, jnp.asarray(prompts[:, t]), cfg,
            impl="reference")

    for slot in range(slots):
        logits, state = _prefill_slot(pair, params, state, slot,
                                      prompts[slot], max_len)
        np.testing.assert_allclose(
            np.asarray(logits[0, P - 1], np.float32),
            np.asarray(leg_logits[slot], np.float32), rtol=0, atol=1e-5)
    assert list(np.asarray(state.lengths)) == [P] * slots

    toks = np.argmax(np.asarray(leg_logits), axis=-1).astype(np.int32)
    for _ in range(N):
        leg_logits, cache = api.decode_step(
            params, cache, jnp.asarray(toks), cfg, impl="reference")
        dec_logits, state = executor.run_decode(
            pair.decode, params, jnp.asarray(toks), state,
            impl="reference")
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(leg_logits, np.float32), rtol=0, atol=1e-5)
        toks = np.argmax(np.asarray(leg_logits), axis=-1).astype(np.int32)
    assert list(np.asarray(state.lengths)) == [P + N] * slots


@pytest.mark.parametrize("name", ["mamba2", "rwkv6-7b", "zamba2-7b"])
def test_family_state_carries_past_max_len(name):
    """Recurrent state has no sequence axis, so decode runs straight
    past ``max_len``: lengths keep counting, the hybrid's attention
    ring rolls, and logits still match the legacy loop."""
    slots, max_len, P, N = 1, 8, 8, 4                 # P+N > max_len
    cfg, api, params, pair, state = _setup(name, slots, max_len)
    prompt = np.arange(1, P + 1, dtype=np.int32)
    cache = api.init_cache(cfg, slots, max_len)
    for t in range(P):
        leg_logits, cache = api.decode_step(
            params, cache, jnp.asarray(prompt[t:t + 1]), cfg,
            impl="reference")
    _, state = _prefill_slot(pair, params, state, 0, prompt, max_len)
    toks = np.argmax(np.asarray(leg_logits), axis=-1).astype(np.int32)
    for _ in range(N):
        leg_logits, cache = api.decode_step(
            params, cache, jnp.asarray(toks), cfg, impl="reference")
        dec_logits, state = executor.run_decode(
            pair.decode, params, jnp.asarray(toks), state,
            impl="reference")
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(leg_logits, np.float32), rtol=0, atol=1e-5)
        toks = np.argmax(np.asarray(leg_logits), axis=-1).astype(np.int32)
    assert int(np.asarray(state.lengths)[0]) == P + N


def test_family_same_tick_slot_reuse():
    """A slot freed mid-tick (EOS/max_new on the prefill token) admits
    the next queued request in the same tick — family state is reset by
    the prefill, so recurrent leftovers cannot leak."""
    from repro.serving import Request, ServingEngine
    cfg = REGISTRY["rwkv6-7b"].smoke()
    params = init_params(get_model(cfg).param_defs(cfg), K0)
    eng = ServingEngine(cfg, params, slots=1, max_len=8,
                        impl="reference", use_program=True)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=np.asarray([5, 6], np.int32),
                           max_new_tokens=1))
    finished = eng.step()
    assert len(finished) == 2 and not eng.queue
    assert eng.n_prefills == 2 and eng.n_prefill_recomputes == 0


# --- region-plan units --------------------------------------------------------------
def test_ssm_state_regions_are_o1_in_seq_len():
    """Pure-recurrence families (ssm, hybrid-without-attention) mint
    persistent state with NO sequence axis: the specs are byte-for-byte
    identical at max_len 16 and 1024."""
    for name in ("rwkv6-7b", "mamba2"):
        cfg = REGISTRY[name].smoke()
        short, caps_s = regions.state_specs(cfg, 2, 16)
        long, caps_l = regions.state_specs(cfg, 2, 1024)
        assert short == long and caps_s == caps_l
    # the hybrid's SSM/conv specs are O(1) too; only the shared
    # attention KV rows scale (capped by the window)
    zcfg = REGISTRY["zamba2-7b"].smoke()
    zs, _ = regions.state_specs(zcfg, 2, 16)
    zl, _ = regions.state_specs(zcfg, 2, 1024)
    recur = lambda specs: [s for s in specs if "ssm" in s.name
                           or "conv" in s.name]
    assert recur(zs) == recur(zl)
    kv_rows = lambda specs: {s.name: s.shape[1] for s in specs
                             if s not in recur(specs)}
    assert all(r == 16 for r in kv_rows(zs).values())
    assert all(r == min(1024, zcfg.attn_window)
               for r in kv_rows(zl).values())


def test_encoder_memory_pinned_read_only():
    """Whisper's cross K/V regions are marked read-only and the decode
    stream never scatters into them: after prefill + decode ticks the
    memory buffers are bitwise what admission wrote."""
    cfg = REGISTRY["whisper-base"].smoke()
    specs, caps = regions.state_specs(cfg, 2, 16)
    ro = {s.name for s in specs if s.read_only}
    assert ro == {f"l{i}.cross_{sd}" for i in range(cfg.n_layers)
                  for sd in ("k", "v")}
    assert not any(s.read_only for s in specs if "cross" not in s.name)

    api = get_model(cfg)
    cfg2, api, params, pair, state = _setup("whisper-base", 1, 16)
    cache = api.init_cache(cfg2, 1, 16)
    rng = np.random.default_rng(1)
    frames = rng.standard_normal(
        (cfg2.encoder_seq, cfg2.d_model)).astype(np.float32)
    state, cache = _write_memory(api, cfg2, params, pair, state, cache,
                                 0, frames)
    mem_rids = [pair.persistent[n] for n in ro]
    written = {rid: np.asarray(state.caches[rid]) for rid in mem_rids}
    _, state = _prefill_slot(pair, params, state, 0,
                             np.asarray([3, 1, 4], np.int32), 16)
    for _ in range(3):
        _, state = executor.run_decode(
            pair.decode, params, jnp.asarray([7], jnp.int32), state,
            impl="reference")
    for rid in mem_rids:
        np.testing.assert_array_equal(np.asarray(state.caches[rid]),
                                      written[rid])


def test_family_capability_table():
    """The per-family StateCaps matrix the serving gates consult
    (pinned here and documented in ARCHITECTURE.md Stage 6)."""
    expect = {
        "smollm-360m":          (True,  True,  True,  True),
        "granite-moe-1b-a400m": (True,  True,  False, False),
        "zamba2-7b":            (False, True,  False, False),
        "mamba2":               (False, True,  False, False),
        "rwkv6-7b":             (False, False, False, False),
        "whisper-base":         (False, False, False, False),
    }
    for name, (paged, windowed, chunk, spec) in expect.items():
        cfg = REGISTRY[name].smoke()
        _, caps = regions.state_specs(cfg, 2, 16)
        assert (caps.paged, caps.windowed, caps.chunkable,
                caps.speculatable) == (paged, windowed, chunk, spec), name


def test_state_specs_hook_validation():
    """The allocator rejects hooks whose specs drop the slot axis, and
    names the family when no hook is registered at all."""
    import types
    fake = types.SimpleNamespace(family="_test_fam", name="fake-cfg")

    def bad_hook(cfg, slots, max_len):
        return (regions.PersistentSpec("s", (3, 4), "float32", 48),), \
            regions.StateCaps()

    regions.register_state_family("_test_fam", bad_hook)
    try:
        with pytest.raises(ValueError, match="slot axis"):
            regions.state_specs(fake, 2, 16)
    finally:
        regions._STATE_FAMILIES.pop("_test_fam", None)
    missing = types.SimpleNamespace(family="_nope", name="fake-cfg")
    with pytest.raises(NotImplementedError, match="_nope"):
        regions.state_specs(missing, 2, 16)


# --- serving round trip -------------------------------------------------------------
def test_whisper_serving_round_trip():
    """Audio requests serve end-to-end on the Program path: admission
    encodes the request's frames into read-only memory, and a request
    without frames is refused loudly."""
    from repro.serving import Request, ServingEngine
    cfg = REGISTRY["whisper-base"].smoke()
    params = init_params(get_model(cfg).param_defs(cfg), K0)
    eng = ServingEngine(cfg, params, slots=2, max_len=16,
                        impl="reference", use_program=True)
    assert eng.on_program_path, eng.fallback_reason
    rng = np.random.default_rng(0)
    for i in range(2):
        frames = rng.standard_normal(
            (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        eng.submit(Request(uid=i, prompt=np.asarray([4, 2], np.int32),
                           max_new_tokens=4, extra=frames))
    done = eng.run_until_drained()
    assert len(done) == 2
    assert all(len(r.out_tokens) == 4 for r in done)
    assert eng.n_prefill_recomputes == 0

    eng.submit(Request(uid=9, prompt=np.asarray([1], np.int32),
                       max_new_tokens=1))
    with pytest.raises(ValueError, match="encoder"):
        eng.step()

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.kernels import (attention_ref, conv2d, conv2d_ref,
                           decode_attention, decode_attention_ref,
                           flash_ref, flash_attention, matmul, matmul_ref,
                           mamba2_scan, mamba2_scan_ref, wkv6, wkv6_ref)
from repro.kernels.mamba2 import mamba2_decode_step
from repro.kernels.rwkv6 import wkv6_decode_step

K0 = jax.random.PRNGKey(0)


def keys(n):
    return jax.random.split(K0, n)


# --- matmul ------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (300, 520, 260),
                                   (64, 1000, 72), (1, 256, 512),
                                   (257, 129, 383)])
@pytest.mark.parametrize("dataflow", list(Dataflow))
def test_matmul_shapes_dataflows(M, K, N, dataflow):
    ks = keys(4)
    a = jax.random.normal(ks[0], (M, K), jnp.float32)
    b = jax.random.normal(ks[1], (K, N), jnp.float32)
    out = matmul(a, b, impl="pallas", dataflow=dataflow,
                 block=(128, 128, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(matmul_ref(a, b)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_fused_epilogue(dtype):
    ks = keys(4)
    a = jax.random.normal(ks[0], (192, 256), dtype)
    b = jax.random.normal(ks[1], (256, 160), dtype)
    bias = jax.random.normal(ks[2], (160,), dtype)
    byp = jax.random.normal(ks[3], (192, 160), dtype)
    for act in (None, "relu", "silu", "gelu"):
        out = matmul(a, b, bias=bias, activation=act, bypass=byp,
                     impl="pallas", dataflow=Dataflow.OUTPUT_STATIONARY,
                     block=(128, 128, 128), interpret=True)
        ref = matmul_ref(a, b, bias=bias, activation=act, bypass=byp)
        tol = 1e-3 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol)


def test_matmul_batched_lead_dims():
    ks = keys(2)
    a = jax.random.normal(ks[0], (2, 3, 64, 96), jnp.float32)
    b = jax.random.normal(ks[1], (96, 80), jnp.float32)
    out = matmul(a, b, impl="pallas", dataflow=Dataflow.MAPS_RESIDENT,
                 block=(128, 128, 128), interpret=True)
    assert out.shape == (2, 3, 64, 80)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=2e-4, atol=2e-4)


# --- conv2d ------------------------------------------------------------------------
@pytest.mark.parametrize("H,W,Cin,Cout,k,s,p", [
    (29, 31, 16, 24, 3, 2, 1),      # odd sizes, stride 2
    (27, 27, 8, 16, 5, 1, 2),       # AlexNet conv2 shape family
    (16, 16, 4, 8, 1, 1, 0),        # 1x1
    (56, 56, 16, 16, 3, 1, 1),      # ResNet block shape family
    (13, 13, 32, 16, 3, 1, 1),
])
@pytest.mark.parametrize("dataflow", [Dataflow.MAPS_RESIDENT,
                                      Dataflow.WEIGHTS_RESIDENT])
def test_conv2d_sweep(H, W, Cin, Cout, k, s, p, dataflow):
    ks = keys(4)
    x = jax.random.normal(ks[0], (2, H, W, Cin), jnp.float32)
    w = jax.random.normal(ks[1], (k, k, Cin, Cout), jnp.float32) * 0.2
    b = jax.random.normal(ks[2], (Cout,), jnp.float32) * 0.1
    ref = conv2d_ref(x, w, stride=s, pad=p, bias=b, activation="relu")
    byp = jax.random.normal(ks[3], ref.shape, jnp.float32)
    out = conv2d(x, w, stride=s, pad=p, bias=b, activation="relu",
                 bypass=byp, impl="pallas", interpret=True,
                 dataflow=dataflow)
    ref2 = conv2d_ref(x, w, stride=s, pad=p, bias=b, activation="relu",
                      bypass=byp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref2),
                               rtol=1e-3, atol=1e-3)


def test_conv2d_bypass_first_resnet_order():
    ks = keys(3)
    x = jax.random.normal(ks[0], (1, 16, 16, 8), jnp.float32)
    w = jax.random.normal(ks[1], (3, 3, 8, 8), jnp.float32) * 0.2
    byp = jax.random.normal(ks[2], (1, 16, 16, 8), jnp.float32)
    out = conv2d(x, w, pad=1, activation="relu", bypass=byp,
                 bypass_first=True, impl="pallas", interpret=True)
    ref = conv2d_ref(x, w, pad=1, activation="relu", bypass=byp,
                     bypass_first=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


# --- flash attention -----------------------------------------------------------------
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (6, 1)])
@pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                           (True, 48)])
def test_flash_attention_gqa_masks(Hq, Hkv, causal, window):
    B, S, D = 2, 192, 32
    ks = keys(3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          impl="pallas", block_q=64, block_kv=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    fr = flash_ref(q, k, v, causal=causal, window=window, chunk=64)
    np.testing.assert_allclose(np.asarray(fr), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_cross_attention_unequal_seq():
    B, Hq, Hkv, Sq, Skv, D = 1, 4, 2, 96, 160, 32
    ks = keys(3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32)
    ref = attention_ref(q, k, v)
    out = flash_attention(q, k, v, impl="pallas", block_q=32,
                          block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_ref_gradients_match_naive():
    B, H, S, D = 1, 2, 64, 16
    ks = keys(3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    g1 = jax.grad(lambda q, k, v: (attention_ref(q, k, v, causal=True)
                                   ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: (flash_ref(q, k, v, causal=True,
                                             chunk=16) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


# --- decode attention ----------------------------------------------------------------
@pytest.mark.parametrize("S,block", [(256, 64), (384, 128), (128, 128)])
def test_decode_attention_varlen(S, block):
    B, Hq, Hkv, D = 3, 8, 2, 64
    ks = keys(3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    kvl = jnp.array([S, S // 2, 7], jnp.int32)
    ref = decode_attention_ref(q, k, v, kv_len=kvl)
    out = decode_attention(q, k, v, kv_len=kvl, impl="pallas",
                           block_kv=block, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_fp8_cache():
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    ks = keys(3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = (jax.random.normal(ks[1], (B, Hkv, S, D)) * 0.3
         ).astype(jnp.float8_e4m3fn)
    v = jax.random.normal(ks[2], (B, Hkv, S, D)).astype(jnp.float8_e4m3fn)
    ref = decode_attention_ref(q, k.astype(jnp.float32),
                               v.astype(jnp.float32))
    out = decode_attention(q, k, v, impl="reference")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


# --- mamba2 --------------------------------------------------------------------------
@pytest.mark.parametrize("L,chunk", [(128, 32), (256, 64), (64, 64)])
def test_mamba2_chunked_vs_sequential(L, chunk):
    Bt, H, P, N = 2, 3, 32, 16
    ks = keys(6)
    x = jax.random.normal(ks[0], (Bt, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, L, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bt, L, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, L, N)) * 0.5
    D = jax.random.normal(ks[5], (H,)) * 0.1
    yr, hr = mamba2_scan_ref(x, dt, A, B, C, D_skip=D, return_state=True)
    yp, hp = mamba2_scan(x, dt, A, B, C, D_skip=D, return_state=True,
                         impl="pallas", chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_mamba2_state_carry_and_decode():
    """Scan over [0:L1] then decode steps == full scan (streaming)."""
    Bt, L, H, P, N = 1, 32, 2, 16, 8
    ks = keys(5)
    x = jax.random.normal(ks[0], (Bt, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, L, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bt, L, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, L, N)) * 0.5
    y_full = mamba2_scan_ref(x, dt, A, B, C)
    L1 = 24
    y1, h = mamba2_scan_ref(x[:, :L1], dt[:, :L1], A, B[:, :L1],
                            C[:, :L1], return_state=True)
    ys = [y1]
    for t in range(L1, L):
        yt, h = mamba2_decode_step(h, x[:, t], dt[:, t], A, B[:, t],
                                   C[:, t])
        ys.append(yt[:, None])
    y_stream = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_stream), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


# --- rwkv6 ---------------------------------------------------------------------------
@pytest.mark.parametrize("L,chunk", [(64, 16), (128, 64)])
def test_wkv6_vs_sequential(L, chunk):
    B, H, D = 2, 2, 32
    ks = keys(5)
    r = jax.random.normal(ks[0], (B, L, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, L, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, L, H, D))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, L, H, D)) * 0.5))
    u = jax.random.normal(ks[4], (H, D)) * 0.3
    yr, sr = wkv6_ref(r, k, v, w, u, return_state=True)
    yp, sp = wkv6(r, k, v, w, u, return_state=True, impl="pallas",
                  chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_decode_streaming():
    B, L, H, D = 1, 24, 2, 16
    ks = keys(5)
    r = jax.random.normal(ks[0], (B, L, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, L, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, L, H, D))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, L, H, D)) * 0.5))
    u = jax.random.normal(ks[4], (H, D)) * 0.3
    y_full = wkv6_ref(r, k, v, w, u)
    S = jnp.zeros((B, H, D, D))
    ys = []
    for t in range(L):
        yt, S = wkv6_decode_step(S, r[:, t], k[:, t], v[:, t], w[:, t], u)
        ys.append(yt[:, None])
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Hq,Hkv,causal,window", [
    (4, 4, True, None), (4, 2, True, None),
    (4, 2, False, None), (6, 2, True, 48)])
def test_flash_pallas_backward_kernels(Hq, Hkv, causal, window):
    """Pallas dq/dkv kernels (bwd_kernel.py) vs naive autodiff."""
    B, S, D = 2, 128, 32
    ks = keys(4)
    q = jax.random.normal(ks[0], (B, Hq, S, D)) * 0.5
    k = jax.random.normal(ks[1], (B, Hkv, S, D)) * 0.5
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    dO = jax.random.normal(ks[3], (B, Hq, S, D))

    def loss_p(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window,
                            impl="pallas_trainable", block_q=32,
                            block_kv=32, interpret=True)
        return jnp.sum(o * dO)

    def loss_r(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal,
                                     window=window) * dO)

    gp = jax.grad(loss_p, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

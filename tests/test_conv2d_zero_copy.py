"""Zero-copy (virtual-strip) conv2d: equivalence against the
materialized-strip baseline and the oracle, the strip-storage compiler
decision, the shared traffic formulas, and the fused-pool epilogue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SNOWFLAKE, TPU_V5E
from repro.core.dataflow import (Dataflow, choose_conv_dataflow,
                                 conv_strip_traffic)
from repro.core.tiling import select_conv_row_strips
from repro.kernels import conv2d, conv2d_ref, maxpool2d_ref

K0 = jax.random.PRNGKey(0)

pytestmark = pytest.mark.pallas


def keys(n):
    return jax.random.split(K0, n)


def _case(H, W, Cin, Cout, k, scale=0.2):
    ks = keys(3)
    x = jax.random.normal(ks[0], (2, H, W, Cin), jnp.float32)
    w = jax.random.normal(ks[1], (k, k, Cin, Cout), jnp.float32) * scale
    b = jax.random.normal(ks[2], (Cout,), jnp.float32) * 0.1
    return x, w, b


# --- equivalence sweep: virtual vs materialized vs oracle --------------------------
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("pad", [0, 1, 2])
def test_virtual_vs_materialized_vs_ref(stride, pad):
    # odd H -> ragged last strip; W != H to catch transposes
    x, w, b = _case(H=23, W=18, Cin=6, Cout=10, k=3)
    ref = conv2d_ref(x, w, stride=stride, pad=pad, bias=b,
                     activation="relu")
    virt = conv2d(x, w, stride=stride, pad=pad, bias=b, activation="relu",
                  impl="pallas", interpret=True, strip_storage="virtual")
    mat = conv2d(x, w, stride=stride, pad=pad, bias=b, activation="relu",
                 impl="pallas", interpret=True, strip_storage="materialized")
    np.testing.assert_allclose(np.asarray(virt), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mat), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dataflow", [Dataflow.MAPS_RESIDENT,
                                      Dataflow.WEIGHTS_RESIDENT])
def test_virtual_both_loop_orders(dataflow):
    x, w, b = _case(H=17, W=17, Cin=8, Cout=12, k=3)
    ref = conv2d_ref(x, w, stride=1, pad=1, bias=b, activation="relu")
    out = conv2d(x, w, stride=1, pad=1, bias=b, activation="relu",
                 impl="pallas", interpret=True, strip_storage="virtual",
                 dataflow=dataflow)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_kpt_not_dividing_cout():
    # Cout=13 is prime: the kernel-tile width must collapse to a divisor.
    x, w, b = _case(H=11, W=11, Cin=4, Cout=13, k=3)
    ref = conv2d_ref(x, w, stride=1, pad=1, bias=b, activation=None)
    out = conv2d(x, w, stride=1, pad=1, bias=b, impl="pallas",
                 interpret=True, strip_storage="virtual")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bypass_first", [False, True])
def test_virtual_bypass_orders(bypass_first):
    x, w, b = _case(H=15, W=15, Cin=8, Cout=8, k=3)
    ref0 = conv2d_ref(x, w, stride=1, pad=1, bias=b)
    byp = jax.random.normal(keys(1)[0], ref0.shape, jnp.float32)
    ref = conv2d_ref(x, w, stride=1, pad=1, bias=b, activation="relu",
                     bypass=byp, bypass_first=bypass_first)
    out = conv2d(x, w, stride=1, pad=1, bias=b, activation="relu",
                 bypass=byp, bypass_first=bypass_first, impl="pallas",
                 interpret=True, strip_storage="virtual")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_scalar_prefetch_offsets_match_affine():
    x, w, b = _case(H=19, W=16, Cin=6, Cout=8, k=3)
    affine = conv2d(x, w, stride=2, pad=1, bias=b, impl="pallas",
                    interpret=True, strip_storage="virtual",
                    strip_offsets="affine")
    prefetch = conv2d(x, w, stride=2, pad=1, bias=b, impl="pallas",
                      interpret=True, strip_storage="virtual",
                      strip_offsets="prefetch")
    np.testing.assert_allclose(np.asarray(prefetch), np.asarray(affine),
                               rtol=0, atol=0)


# --- fused maxpool epilogue --------------------------------------------------------
@pytest.mark.parametrize("H,k,s,p,pool", [
    (55, 11, 4, 2, (3, 2, 0)),     # AlexNet stem family
    (27, 5, 1, 2, (3, 2, 0)),      # AlexNet conv2 -> pool
    (56, 7, 2, 3, (3, 2, 1)),      # ResNet stem (padded pool)
    (16, 3, 1, 1, (2, 2, 0)),      # non-overlapping windows
])
def test_fused_pool_epilogue(H, k, s, p, pool):
    x, w, b = _case(H=H, W=H, Cin=4, Cout=8, k=k)
    ref = maxpool2d_ref(
        conv2d_ref(x, w, stride=s, pad=p, bias=b, activation="relu"),
        window=pool[0], stride=pool[1], pad=pool[2])
    out = conv2d(x, w, stride=s, pad=p, bias=b, activation="relu",
                 impl="pallas", interpret=True, strip_storage="virtual",
                 fuse_pool=pool)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_pool_with_bypass_falls_back():
    x, w, b = _case(H=16, W=16, Cin=4, Cout=8, k=3)
    conv = conv2d_ref(x, w, stride=1, pad=1, bias=b)
    byp = jax.random.normal(keys(1)[0], conv.shape, jnp.float32)
    ref = maxpool2d_ref(
        conv2d_ref(x, w, stride=1, pad=1, bias=b, activation="relu",
                   bypass=byp),
        window=2, stride=2)
    out = conv2d(x, w, stride=1, pad=1, bias=b, activation="relu",
                 bypass=byp, impl="pallas", interpret=True,
                 strip_storage="virtual", fuse_pool=(2, 2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# --- compiler decision + traffic model ---------------------------------------------
def test_strip_storage_decision_tpu_vs_snowflake():
    # TPU VMEM swallows a ResNet-block plane -> virtual; Snowflake's
    # 128 KB maps buffer cannot -> the paper's materialized strips.
    ct_tpu = select_conv_row_strips(56, 56, 64, 64, 3, 3, 1, 1, 2, TPU_V5E)
    assert ct_tpu.strip_storage == "virtual"
    ct_sf = select_conv_row_strips(56, 56, 64, 64, 3, 3, 1, 1, 2, SNOWFLAKE)
    assert ct_sf.strip_storage == "materialized"
    assert ct_tpu.vmem_bytes <= TPU_V5E.vmem_budget()


def test_virtual_traffic_drops_overlap_term():
    # conv-loop-only accounting (charge_materialization=False): virtual
    # drops exactly the duplicated-overlap bytes from each loop order.
    maps, weights, out = 1e6, 2e5, 8e5
    k_mat, m_mat = conv_strip_traffic(maps, weights, out, n_map_tiles=8,
                                      n_kernel_tiles=4, overlap_frac=0.25,
                                      strip_storage="materialized",
                                      charge_materialization=False)
    k_virt, m_virt = conv_strip_traffic(maps, weights, out, n_map_tiles=8,
                                        n_kernel_tiles=4, overlap_frac=0.25,
                                        strip_storage="virtual")
    assert k_mat - k_virt == pytest.approx(0.25 * maps)
    assert m_mat - m_virt == pytest.approx(4 * 0.25 * maps)
    # zero overlap: storage makes no difference
    assert conv_strip_traffic(maps, weights, out, n_map_tiles=8,
                              n_kernel_tiles=4, overlap_frac=0.0,
                              strip_storage="materialized") == (k_virt, m_virt)


def test_materialization_roundtrip_charged():
    """Pins the full materialized formula (ROADMAP follow-up from PR 1):
    building the halo-augmented strips costs a round trip — read the
    maps once, write the (1 + overlap) augmented copy — on top of the
    conv's own streams; the virtual path never pays it."""
    maps, weights, out = 1e6, 2e5, 8e5
    ov, nm, nk = 0.25, 8, 4
    k_mat, m_mat = conv_strip_traffic(maps, weights, out, n_map_tiles=nm,
                                      n_kernel_tiles=nk, overlap_frac=ov,
                                      strip_storage="materialized")
    roundtrip = maps + (1 + ov) * maps
    assert k_mat == pytest.approx(roundtrip + (1 + ov) * maps
                                  + nm * weights + out)
    assert m_mat == pytest.approx(roundtrip + nk * (1 + ov) * maps
                                  + weights + out)
    # the round trip shifts both loop orders equally: it never flips the
    # Mloop/Kloop decision
    df_on, _, _ = choose_conv_dataflow(
        maps, weights, out, n_map_tiles=nm, n_kernel_tiles=nk,
        overlap_frac=ov, strip_storage="materialized")
    df_off, _, _ = choose_conv_dataflow(
        maps, weights, out, n_map_tiles=nm, n_kernel_tiles=nk,
        overlap_frac=ov, strip_storage="materialized",
        charge_materialization=False)
    assert df_on is df_off
    # zero overlap needs no augmentation -> no round trip
    k0, _ = conv_strip_traffic(maps, weights, out, n_map_tiles=nm,
                               n_kernel_tiles=nk, overlap_frac=0.0,
                               strip_storage="materialized")
    assert k0 == pytest.approx(maps + nm * weights + out)


def test_schedule_notes_materialize_roundtrip():
    from repro.core import compile_model, conv_node, ModelGraph
    g = ModelGraph("one_conv")
    g.add(conv_node("c", 27, 27, 64, 192, 5, 5, stride=1, pad=2))
    s = compile_model(g, SNOWFLAKE, paper_faithful=True)
    ls = s.layer("c")
    ct = ls.conv_tiling
    if ct.overlap_frac > 0:
        maps = 27 * 27 * 64 * 2
        assert ls.notes["materialize_roundtrip"] == pytest.approx(
            (2 + ct.overlap_frac) * maps)
        assert ls.traffic_bytes >= ls.notes["materialize_roundtrip"]


def test_choose_conv_dataflow_picks_min():
    df, traffic, alts = choose_conv_dataflow(
        1e6, 2e5, 8e5, n_map_tiles=8, n_kernel_tiles=4,
        overlap_frac=0.1, strip_storage="virtual")
    assert traffic == min(alts.values())
    assert df in (Dataflow.MAPS_RESIDENT, Dataflow.WEIGHTS_RESIDENT)


def test_schedule_records_fusion_and_storage():
    from repro.configs import CNN_REGISTRY
    from repro.core import compile_model
    from repro.models.cnn import to_graph
    g = to_graph(CNN_REGISTRY["alexnet-owt"], batch=1)
    s = compile_model(g, TPU_V5E)
    conv0 = s.layer("conv_00")
    assert conv0.notes.get("fused_pool") == {"window": 3, "stride": 2,
                                             "pad": 0, "op": "max"}
    assert conv0.notes.get("strip_storage") == "virtual"
    pool1 = s.layer("maxpool_01")
    assert pool1.traffic_bytes == 0.0           # runs in conv_00's epilogue
    assert pool1.notes.get("fused_into") == "conv_00"
    # paper-faithful pins the Snowflake scheme, where the pool is NOT
    # fused (ops.py pools separately on the materialized path): the
    # pool layer keeps its own traffic there.
    s_sf = compile_model(g, SNOWFLAKE, paper_faithful=True)
    assert s_sf.layer("conv_00").notes.get("strip_storage") == "materialized"
    assert "fused_pool" not in s_sf.layer("conv_00").notes
    assert s_sf.layer("maxpool_01").traffic_bytes > 0.0

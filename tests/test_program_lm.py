"""LM lowering to Programs: transformer graph -> schedule -> regions ->
Program -> executor parity with the legacy scan forward, the §5.1
allocator pinning the residual stream across each block, the executor
dispatching the ``flash_attention`` kernel id with the schedule's
blocks, and the serving fast path round-tripping token requests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.core.ir import DepLabel, LayerKind
from repro.models import init_params, transformer
from repro.runtime import executor

K0 = jax.random.PRNGKey(0)


def _cfg(name="smollm-360m", **over):
    cfg = REGISTRY[name].smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _setup(cfg, batch=2, seq=16):
    params = init_params(transformer.param_defs(cfg), K0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq),
                              0, cfg.vocab)
    program = transformer.compile_program(cfg, batch=batch, seq=seq)
    return params, toks, program


# --- end-to-end parity -------------------------------------------------------------
@pytest.mark.parametrize("name", ["smollm-360m", "llama3-8b", "olmo-1b"])
def test_program_matches_legacy_forward(name):
    """GQA + gated MLP (smollm/llama3) and MHA + nonparametric LN
    (olmo) all lower to Programs matching the scan forward <= 1e-5."""
    cfg = _cfg(name)
    params, toks, program = _setup(cfg)
    out = executor.run(program, params, toks, impl="reference")
    ref = transformer.forward(params, toks, cfg, impl="reference")["logits"]
    assert out.shape == ref.shape == (2, 16, cfg.vocab)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


def test_program_forward_wrapper_and_cache():
    cfg = _cfg()
    params, toks, program = _setup(cfg)
    fwd = transformer.program_forward(params, toks, cfg, impl="reference")
    out = executor.run(program, params, toks, impl="reference")
    np.testing.assert_allclose(np.asarray(fwd), np.asarray(out),
                               rtol=0, atol=1e-5)
    assert transformer.compile_program(cfg, batch=2, seq=16) is program
    assert transformer.compile_program(cfg, batch=2, seq=32) is not program


def test_tied_embeddings_head():
    cfg = _cfg(tie_embeddings=True)
    params, toks, program = _setup(cfg, batch=1, seq=8)
    head = program.op("lm_head")
    assert head.transpose_w and head.param_key == "embed"
    out = executor.run(program, params, toks, impl="reference")
    ref = transformer.forward(params, toks, cfg, impl="reference")["logits"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


@pytest.mark.pallas
def test_program_pallas_interpret_parity():
    """The Pallas kernels (matmul + flash attention) execute the LM
    program with the schedule's exact blocks, matching the reference
    forward."""
    cfg = _cfg(n_layers=1)
    params, toks, program = _setup(cfg, batch=1, seq=16)
    ref = transformer.forward(params, toks, cfg, impl="reference")["logits"]
    out = executor.run(program, params, toks, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_family_gating_on_transformer_graph():
    """transformer.to_graph is the dense/MoE lowering: MoE configs lower
    here now; SSM configs raise (they lower via their own family module,
    dispatched at compile_program_pair); vlm remains gated and the
    blocker message names *every* blocker, not just the first."""
    g = transformer.to_graph(REGISTRY["granite-moe-1b-a400m"].smoke())
    assert any(n.kind is LayerKind.MOE for n in g.nodes)
    with pytest.raises(NotImplementedError):
        transformer.to_graph(REGISTRY["rwkv6-7b"].smoke())
    with pytest.raises(NotImplementedError) as ei:
        transformer.to_graph(REGISTRY["llama-3.2-vision-11b"].smoke())
    msg = str(ei.value)
    for blocker in ("family=vlm", "cross-attention", "vision-encoder"):
        assert blocker in msg


# --- graph + schedule --------------------------------------------------------------
def test_graph_marks_residual_sinks_on_projections():
    """Both residual adds of every block fuse into the o-/down-proj
    writeback (the paper's VMOV-on-writeback), never a standalone op."""
    cfg = _cfg()
    g = transformer.to_graph(cfg, batch=1, seq=8)
    g.mark_residuals()
    for i in range(cfg.n_layers):
        wo = g.get(f"l{i}.wo")
        down = g.get(f"l{i}.w_down")
        assert wo.dep is DepLabel.RESIDUAL_SINK
        assert down.dep is DepLabel.RESIDUAL_SINK
        assert down.bypass_of == wo.name
        assert wo.bypass_of == ("embed" if i == 0 else f"l{i-1}.w_down")
    assert not any(n.kind is LayerKind.ELEMENTWISE
                   and n.meta.get("op") == "add" for n in g)


def test_attention_schedule_blocks_are_pinned_into_op():
    """The flash_attention op carries the compiler's (block_q, block_kv)
    and the config's head geometry — the executor re-derives nothing."""
    from repro.core.tiling import select_attention_blocks
    from repro.core.hw import TPU_V5E
    cfg = _cfg()
    _, _, program = _setup(cfg, batch=2, seq=16)
    op = program.op("l0.attn")
    assert op.kernel == "flash_attention"
    a = op.attn
    assert (a.heads, a.kv_heads, a.head_dim) == (cfg.n_heads,
                                                 cfg.n_kv_heads, cfg.hd)
    assert a.causal and a.rope_theta == cfg.rope_theta
    want = select_attention_blocks(16, 16, cfg.hd, 4, TPU_V5E)
    assert (a.block_q, a.block_kv) == want
    # distinct q/k/v regions resolved by the allocator
    assert len({op.in_region, op.k_region, op.v_region}) == 3


# --- region allocator --------------------------------------------------------------
def test_regions_pin_residual_stream_across_block():
    """The residual stream entering a block (previous w_down / embed) is
    read again by that block's o-projection bypass — the allocator must
    pin it; the post-attention stream (wo) likewise for the MLP add."""
    cfg = _cfg()
    _, _, program = _setup(cfg)
    plan = program.plan
    for i in range(cfg.n_layers):
        src = "embed" if i == 0 else f"l{i-1}.w_down"
        rid = plan.out_region[src]
        assert plan.region(rid).kind == "pinned"
        assert program.op(f"l{i}.wo").bypass_region == rid
        wo_rid = plan.out_region[f"l{i}.wo"]
        assert plan.region(wo_rid).kind == "pinned"
        assert program.op(f"l{i}.w_down").bypass_region == wo_rid


def test_regions_pin_qkv_for_attention_and_reuse():
    """wq/wk outputs cross more than one step to the attention op ->
    pinned; wv feeds the next op -> ping-pong.  Pinned regions are
    reused across blocks instead of growing with depth."""
    cfg = _cfg()
    _, _, program = _setup(cfg)
    plan = program.plan
    for i in range(cfg.n_layers):
        attn = program.op(f"l{i}.attn")
        assert plan.region(attn.in_region).kind == "pinned"     # wq
        assert plan.region(attn.k_region).kind == "pinned"      # wk
        assert plan.region(attn.v_region).kind == "pingpong"    # wv
    # depth-independent footprint: deeper config, same region count
    deep = dataclasses.replace(cfg, name=cfg.name + "-deep", n_layers=4)
    shallow = dataclasses.replace(cfg, name=cfg.name + "-shallow",
                                  n_layers=2)
    p_deep = transformer.compile_program(deep, batch=2, seq=16)
    p_shallow = transformer.compile_program(shallow, batch=2, seq=16)
    assert len(p_deep.plan.regions) == len(p_shallow.plan.regions)


# --- executor dispatch -------------------------------------------------------------
def test_executor_dispatches_flash_attention_kernel(monkeypatch):
    cfg = _cfg()
    params, toks, program = _setup(cfg)
    calls = []
    real = executor.flash_attention

    def spy(q, k, v, **kw):
        calls.append((q.shape, k.shape, kw["block_q"], kw["block_kv"]))
        return real(q, k, v, **kw)

    monkeypatch.setattr(executor, "flash_attention", spy)
    executor.run(program, params, toks, impl="reference")
    assert len(calls) == cfg.n_layers
    qshape, kshape, bq, bkv = calls[0]
    assert qshape == (2, cfg.n_heads, 16, cfg.hd)
    assert kshape == (2, cfg.n_kv_heads, 16, cfg.hd)
    assert (bq, bkv) == (program.op("l0.attn").attn.block_q,
                         program.op("l0.attn").attn.block_kv)


def test_listing_is_paper_style_lm_trace():
    cfg = _cfg()
    _, _, program = _setup(cfg)
    listing = program.listing()
    assert "program smollm-360m-smoke" in listing
    assert "%00 embed" in listing
    assert "flash_attention" in listing and "bq=" in listing
    assert "+bypass" in listing and "+silu" in listing
    assert len(listing.splitlines()) == len(program.ops) + 1


# --- serving fast path -------------------------------------------------------------
def test_serving_lm_program_fast_path_round_trip():
    """Engine tokens == a greedy recompute loop over the legacy forward:
    the program path serves exactly what the model would generate."""
    from repro.serving import Request, ServingEngine
    cfg = _cfg(n_layers=2)
    params = init_params(transformer.param_defs(cfg), K0)
    max_len, max_new = 16, 4
    eng = ServingEngine(cfg, params, slots=2, max_len=max_len,
                        impl="reference", use_program=True)
    assert eng.program is not None
    prompts = [[3, 1, 4], [15]]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new))
    done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
    assert len(done) == 2 and all(r.done for r in done)
    for req, prompt in zip(done, prompts):
        toks = list(prompt)
        want = []
        for _ in range(max_new):
            padded = np.zeros((1, max_len), np.int32)
            padded[0, :len(toks)] = toks
            logits = transformer.forward(
                params, jnp.asarray(padded), cfg,
                impl="reference")["logits"]
            nxt = int(np.argmax(np.asarray(logits)[0, len(toks) - 1]))
            want.append(nxt)
            toks.append(nxt)
        assert req.out_tokens == want


def test_serving_lm_program_long_prompt_slides_window():
    """A prompt longer than max_len conditions on the most recent
    max_len tokens (the rolling-cache analogue) and still honors
    max_new_tokens instead of retiring after one token."""
    from repro.serving import Request, ServingEngine
    cfg = _cfg(n_layers=1)
    params = init_params(transformer.param_defs(cfg), K0)
    max_len, max_new = 8, 3
    eng = ServingEngine(cfg, params, slots=1, max_len=max_len,
                        impl="reference", use_program=True)
    prompt = list(range(1, 13))                       # 12 > max_len
    eng.submit(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                       max_new_tokens=max_new))
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].out_tokens) == max_new
    toks, want = list(prompt), []
    for _ in range(max_new):
        win = toks[-max_len:]
        logits = transformer.forward(
            params, jnp.asarray(np.asarray(win, np.int32)[None]), cfg,
            impl="reference")["logits"]
        nxt = int(np.argmax(np.asarray(logits)[0, len(win) - 1]))
        want.append(nxt)
        toks.append(nxt)
    assert done[0].out_tokens == want


def test_serving_lm_program_rejects_empty_prompt():
    from repro.serving import Request, ServingEngine
    cfg = _cfg(n_layers=1)
    params = init_params(transformer.param_defs(cfg), K0)
    eng = ServingEngine(cfg, params, slots=1, max_len=8,
                        impl="reference", use_program=True)
    eng.submit(Request(uid=0, prompt=np.asarray([], np.int32)))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.step()
